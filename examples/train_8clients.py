"""End-to-end driver: the paper's §V experiment shape — 8 clients, momentum
SGD, per-layer truncated quantization — on the synthetic shapes dataset.

Run:  PYTHONPATH=src python examples/train_8clients.py --method tnqsgd --rounds 120
"""
import argparse
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks.common import train_clients  # noqa: E402


def _adaptive_leaf_plan(method: str, bits: int):
    """Per-leaf bit plan from a probe gradient: fit one power-law tail per
    gradient leaf, then water-fill wire bits under the uniform-``bits``
    budget.  Returns (bits_plan, table_markdown)."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import make_templates, client_batches
    from repro.adaptive.controller import allocate_bits
    from repro.core import fit_power_law_tail
    from repro.core.compressors import CompressorConfig, wire_bytes
    from repro.core.distributions import fit_empirical_density
    from repro.launch.report import adaptive_table
    from repro.models.smallnet import init_smallnet, smallnet_loss

    params = init_smallnet(jax.random.key(0))
    templates = make_templates(jax.random.key(42))
    imgs, labels = client_batches(templates, jnp.uint32(0), 1, 64)
    grads = jax.grad(smallnet_loss)(params, imgs[0], labels[0])
    leaves = jax.tree.leaves(grads)
    tails = [fit_power_law_tail(g) for g in leaves]
    dens = [fit_empirical_density(g) for g in leaves]
    sizes = [g.size for g in leaves]
    ccfg = CompressorConfig(method=method, bits=bits)
    plan = allocate_bits(tails, sizes, wire_bytes(ccfg, sizes), ccfg, dens=dens)
    table = adaptive_table(sizes, plan.bits, plan.alphas,
                           gammas=[float(t.gamma) for t in tails],
                           rhos=[float(t.rho) for t in tails])
    return plan, table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="tnqsgd",
                    choices=["dsgd", "qsgd", "nqsgd", "tqsgd", "tnqsgd", "tbqsgd"])
    ap.add_argument("--bits", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--ef", action="store_true",
                    help="error feedback: compensate truncation bias with the client residual")
    ap.add_argument("--adaptive", action="store_true",
                    help="per-layer wire bits from fitted tails at the uniform-bits budget")
    args = ap.parse_args()
    bits_plan = None
    if args.adaptive and args.method != "dsgd":
        plan, table = _adaptive_leaf_plan(args.method, args.bits)
        bits_plan = plan.bits
        print(f"adaptive per-layer plan ({plan.spend_bytes}/{plan.budget_bytes} wire B):")
        print(table)
    acc, hist = train_clients(args.method, args.bits, rounds=args.rounds,
                              n_clients=args.clients, error_feedback=args.ef,
                              bits_plan=bits_plan)
    tag = args.method + ("+ef" if args.ef else "") + ("+adaptive" if bits_plan else "")
    print(f"loss: {hist[0]:.3f} -> {hist[-1]:.3f} over {args.rounds} rounds")
    print(f"test accuracy ({tag}, b={args.bits}, N={args.clients}): {acc:.4f}")


if __name__ == "__main__":
    main()
