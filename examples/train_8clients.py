"""End-to-end driver: the paper's §V experiment shape — 8 clients, momentum
SGD, per-layer truncated quantization — on the synthetic shapes dataset.

Run:  PYTHONPATH=src python examples/train_8clients.py --method tnqsgd --rounds 120
"""
import argparse
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks.common import train_clients  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="tnqsgd",
                    choices=["dsgd", "qsgd", "nqsgd", "tqsgd", "tnqsgd", "tbqsgd"])
    ap.add_argument("--bits", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--ef", action="store_true",
                    help="error feedback: compensate truncation bias with the client residual")
    args = ap.parse_args()
    acc, hist = train_clients(args.method, args.bits, rounds=args.rounds,
                              n_clients=args.clients, error_feedback=args.ef)
    tag = f"{args.method}+ef" if args.ef else args.method
    print(f"loss: {hist[0]:.3f} -> {hist[-1]:.3f} over {args.rounds} rounds")
    print(f"test accuracy ({tag}, b={args.bits}, N={args.clients}): {acc:.4f}")


if __name__ == "__main__":
    main()
