"""Quickstart: the two-stage quantizer as a library, end to end.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    CompressorConfig,
    compress_decompress,
    fit_power_law_tail,
    sample_power_law,
)
from repro.core.compressors import plan, encode, decode, wire_bytes


def main():
    # 1. A heavy-tailed "gradient" with a known power-law tail.
    g = sample_power_law(jax.random.key(0), (1_000_000,), gamma=4.0, g_min=0.01, rho=0.1)

    # 2. Fit the tail (Hill estimator) — paper Eq. 10 + §V.
    tail = fit_power_law_tail(g)
    print(f"fitted tail: gamma={float(tail.gamma):.2f} g_min={float(tail.g_min):.4f} "
          f"rho={float(tail.rho):.3f}")

    # 3. Compare every scheme at b=3 (the paper's headline setting).
    for method in ("qsgd", "nqsgd", "tqsgd", "tnqsgd", "tbqsgd"):
        cfg = CompressorConfig(method=method, bits=3)
        out = compress_decompress(cfg, g, jax.random.key(1))
        mse = float(jnp.mean((out - g) ** 2))
        meta = plan(cfg, g)
        bytes_per = wire_bytes(cfg, g.size) / g.size
        print(f"{method:8s} alpha={float(meta.alpha):.4f} mse={mse:.3e} "
              f"wire={bytes_per:.3f} B/elem (fp32: 4.0)")

    # 4. Wire-format round trip (what the collectives actually move).
    cfg = CompressorConfig(method="tnqsgd", bits=3)
    meta = plan(cfg, g)
    payload = encode(cfg, g, meta, jax.random.key(2))
    g_hat = decode(cfg, payload, meta, g.shape)
    print(f"payload: {payload.size * 4} bytes for {g.size} elements "
          f"({payload.size * 32 / g.size:.2f} bits/elem), "
          f"recon err={float(jnp.mean((g_hat - g) ** 2)):.3e}")


if __name__ == "__main__":
    main()
