"""Serving example: batched prefill + decode with KV/SSM caches.

Runs a reduced llama (or any --arch) on CPU: prefill a batch of prompts,
then greedily decode tokens step by step.

Run:  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-2.7b --tokens 16
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, reduced
from repro.data.synthetic import lm_batch
from repro.models import decode_step, init_lm, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    params, _ = init_lm(jax.random.key(0), cfg)
    batch = lm_batch(cfg, jnp.uint32(0), args.batch, args.prompt_len)

    logits, caches = jax.jit(lambda p, b: prefill(cfg, p, b))(params, batch)
    print(f"prefilled {args.batch} x {args.prompt_len}; logits {logits.shape}")

    step = jax.jit(lambda p, t, c, pos: decode_step(cfg, p, t, c, pos))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(args.tokens - 1):
        logits, caches = step(params, tok, caches, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    seqs = jnp.concatenate(out, axis=1)
    print("greedy decode:")
    for row in seqs:
        print("  ", row.tolist())


if __name__ == "__main__":
    main()
