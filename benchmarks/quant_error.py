"""Quantization-error benchmark: empirical E_TQ vs the paper's closed forms.

- alpha-sweep for the truncated uniform quantizer, showing the Eq. 12 optimum;
- per-method MSE vs Eq. 11 / Thm-bound predictions on synthetic power-law
  gradients with known (gamma, g_min, rho).
CSV rows: quant_error,<case>,0,<value>.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import CompressorConfig, compress_decompress, fit_power_law_tail, sample_power_law
from repro.core import optimal as O
from repro.core import theory as T
from repro.core.quantizers import QuantMeta, quantize, uniform_levels


def main(quick: bool = False):
    n = 100_000 if quick else 400_000
    g = sample_power_law(jax.random.key(0), (n,), gamma=4.0, g_min=0.01, rho=0.1)
    tail = fit_power_law_tail(g)
    rows = [f"quant_error,gamma_hat,0,{float(tail.gamma):.3f}"]

    # alpha sweep (b=3)
    a_star = float(O.solve_alpha_uniform(tail, bits=3))
    rows.append(f"quant_error,alpha_star,0,{a_star:.5f}")
    for mult in (0.25, 0.5, 1.0, 2.0, 4.0):
        a = a_star * mult
        meta = QuantMeta(levels=uniform_levels(jnp.float32(a), 3), alpha=jnp.float32(a))
        mse = float(jnp.mean((quantize(g, meta, jax.random.key(1)) - g) ** 2))
        rows.append(f"quant_error,alpha_sweep_x{mult},0,{mse:.3e}")

    # empirical vs theory per method
    pred_u = float(T.e_tq_uniform(tail, jnp.float32(a_star), 3))
    rows.append(f"quant_error,tqsgd_theory_eq11,0,{pred_u:.3e}")
    for m in ("qsgd", "nqsgd", "tqsgd", "tnqsgd", "tbqsgd"):
        out = compress_decompress(CompressorConfig(method=m, bits=3), g, jax.random.key(2))
        rows.append(f"quant_error,{m}_mse_b3,0,{float(jnp.mean((out - g) ** 2)):.3e}")

    # bound scaling in s (Thm 1): error ~ s^{(6-2*gamma)/(gamma-1)}
    for b in (2, 3, 4, 5):
        rows.append(
            f"quant_error,bound_b{b},0,{float(T.e_tq_bound(tail, jnp.float32(1.0), b)):.3e}"
        )
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
