"""Shared benchmark utilities: the paper's N-client training loop + timing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.compressors import CompressorConfig, compress_decompress
from repro.core.error_feedback import compress_with_feedback, init_error
from repro.data.synthetic import client_batches, make_templates, shapes_batch
from repro.models.smallnet import accuracy, init_smallnet, smallnet_loss
from repro.optim.optimizers import momentum_sgd


def time_us(fn, *args, repeats: int = 20, warmup: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats * 1e6


def train_clients(
    method: str,
    bits: int,
    *,
    rounds: int = 80,
    n_clients: int = 8,
    batch: int = 32,
    lr: float = 0.01,
    momentum: float = 0.9,
    weight_decay: float = 5e-4,
    seed: int = 0,
    eval_batch: int = 2048,
    error_feedback: bool = False,
):
    """Paper §V setting: N=8 clients, momentum SGD (0.01/0.9/5e-4), per-layer
    compression of conv and fc groups.  ``error_feedback`` carries one EF
    residual tree per client (``core.error_feedback`` semantics).
    Returns (accuracy, loss_history)."""
    templates = make_templates(jax.random.key(42))
    params = init_smallnet(jax.random.key(seed))
    opt = momentum_sgd(lr=lr, momentum=momentum, weight_decay=weight_decay)
    state = opt.init(params)
    ccfg = CompressorConfig(method=method, bits=bits)

    @jax.jit
    def round_step(p, s, errs, i):
        imgs, labels = client_batches(templates, i, n_clients, batch)

        def one_client(c, e):
            loss, g = jax.value_and_grad(smallnet_loss)(p, imgs[c], labels[c])
            if method != "dsgd":
                key = jax.random.fold_in(jax.random.key(7), i * n_clients + c)
                if error_feedback:
                    g, e = compress_with_feedback(ccfg, g, e, key)
                else:
                    leaves, treedef = jax.tree.flatten(g)
                    enc = [
                        compress_decompress(ccfg, leaf, jax.random.fold_in(key, j))
                        for j, leaf in enumerate(leaves)
                    ]
                    g = jax.tree.unflatten(treedef, enc)
            return loss, g, e

        losses, grads, new_errs = zip(
            *[one_client(jnp.uint32(c), e) for c, e in enumerate(errs)])
        gmean = jax.tree.map(lambda *gs: sum(gs) / n_clients, *grads)
        p, s = opt.update(p, gmean, s, i)
        return p, s, list(new_errs), sum(losses) / n_clients

    hist = []
    p, s = params, state
    errs = [init_error(params) for _ in range(n_clients)]
    for i in range(rounds):
        p, s, errs, l = round_step(p, s, errs, jnp.uint32(i))
        hist.append(float(l))
    imgs, labels = shapes_batch(templates, jnp.uint32(10_000), eval_batch)
    acc = float(accuracy(p, imgs, labels))
    return acc, hist
