"""Shared benchmark utilities: the paper's N-client training loop + timing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.compressors import CompressorConfig, compress_decompress
from repro.core.error_feedback import compress_with_feedback, init_error
from repro.data.synthetic import client_batches, make_templates, shapes_batch
from repro.models.smallnet import accuracy, init_smallnet, smallnet_loss
from repro.optim.optimizers import momentum_sgd


def time_us(fn, *args, repeats: int = 20, warmup: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats * 1e6


def train_clients(
    method: str,
    bits: int,
    *,
    rounds: int = 80,
    n_clients: int = 8,
    batch: int = 32,
    lr: float = 0.01,
    momentum: float = 0.9,
    weight_decay: float = 5e-4,
    seed: int = 0,
    eval_batch: int = 2048,
    error_feedback: bool = False,
    bits_plan=None,
):
    """Paper §V setting: N=8 clients, momentum SGD (0.01/0.9/5e-4), per-layer
    compression of conv and fc groups.  ``error_feedback`` carries one EF
    residual tree per client (``core.error_feedback`` semantics).
    ``bits_plan`` (one wire width per gradient leaf, traversal order)
    overrides the uniform ``bits`` — the adaptive per-layer allocation.
    Returns (accuracy, loss_history)."""
    import dataclasses

    templates = make_templates(jax.random.key(42))
    params = init_smallnet(jax.random.key(seed))
    opt = momentum_sgd(lr=lr, momentum=momentum, weight_decay=weight_decay)
    state = opt.init(params)
    ccfg = CompressorConfig(method=method, bits=bits)
    n_leaves = len(jax.tree.leaves(params))
    if bits_plan is not None and len(bits_plan) != n_leaves:
        raise ValueError(f"bits_plan has {len(bits_plan)} entries for {n_leaves} leaves")
    leaf_cfgs = [ccfg if bits_plan is None else dataclasses.replace(ccfg, bits=int(b))
                 for b in (bits_plan if bits_plan is not None else [bits] * n_leaves)]

    @jax.jit
    def round_step(p, s, errs, i):
        imgs, labels = client_batches(templates, i, n_clients, batch)

        def one_client(c, e):
            loss, g = jax.value_and_grad(smallnet_loss)(p, imgs[c], labels[c])
            if method != "dsgd":
                key = jax.random.fold_in(jax.random.key(7), i * n_clients + c)
                if error_feedback and bits_plan is None:
                    g, e = compress_with_feedback(ccfg, g, e, key)
                elif error_feedback:
                    # per-leaf widths: EF residual handled leaf-by-leaf
                    leaves, treedef = jax.tree.flatten(g)
                    errs_l = treedef.flatten_up_to(e)
                    outs, new_e = [], []
                    for j, (leaf, el) in enumerate(zip(leaves, errs_l)):
                        corrected = leaf.astype(jnp.float32) + el
                        cc = compress_decompress(leaf_cfgs[j], corrected,
                                                 jax.random.fold_in(key, j))
                        outs.append(cc.astype(leaf.dtype))
                        new_e.append(corrected - cc.astype(jnp.float32))
                    g = jax.tree.unflatten(treedef, outs)
                    e = jax.tree.unflatten(treedef, new_e)
                else:
                    leaves, treedef = jax.tree.flatten(g)
                    enc = [
                        compress_decompress(leaf_cfgs[j], leaf, jax.random.fold_in(key, j))
                        for j, leaf in enumerate(leaves)
                    ]
                    g = jax.tree.unflatten(treedef, enc)
            return loss, g, e

        losses, grads, new_errs = zip(
            *[one_client(jnp.uint32(c), e) for c, e in enumerate(errs)])
        gmean = jax.tree.map(lambda *gs: sum(gs) / n_clients, *grads)
        p, s = opt.update(p, gmean, s, i)
        return p, s, list(new_errs), sum(losses) / n_clients

    hist = []
    p, s = params, state
    errs = [init_error(params) for _ in range(n_clients)]
    for i in range(rounds):
        p, s, errs, l = round_step(p, s, errs, jnp.uint32(i))
        hist.append(float(l))
    imgs, labels = shapes_batch(templates, jnp.uint32(10_000), eval_batch)
    acc = float(accuracy(p, imgs, labels))
    return acc, hist
