"""Validate the OBS.json summary ``python -m repro.obs report --json`` writes.

CI runs this right after the obs smoke train (5 steps with ``--obs-dir``) so
a malformed summary, an empty metrics stream, or an in-graph metric landing
outside its physical range fails the job instead of archiving garbage.

Schema (produced by ``repro.obs.report.summarize``): ``{"version": 1,
"n_events": N, "n_steps": N, "threshold": x, "buckets": [{"bucket": b,
"bits": n, "rank": n, "alpha": x, "clip_frac": x, "ef_norm": x,
"wire_bytes": x, "realized_mse": x, "predicted_mse": x, "ratio": x|null,
"flagged": bool}], "phases": [{"name": str, "count": N, "total_s": x,
"mean_s": x, "max_s": x}], "drift": [...], "flagged": [b...]}``.

Guards:

- at least one metrics step and one bucket made it into the summary;
- per bucket: ``bits`` in [0, 32], ``wire_bytes > 0``, ``clip_frac`` in
  [0, 1], ``realized_mse >= 0``, ``predicted_mse >= 0``, all finite;
- ``ratio`` is consistent with realized/predicted and ``flagged`` with
  ``ratio > threshold``; the top-level ``flagged`` list matches the rows;
- predicted-vs-realized sanity: at least one bucket carries a positive
  prediction whose realized/predicted ratio lies in [1e-3, 1e3] — the
  error model and the measurement are at least on the same planet;
- phase rows have positive counts and non-negative durations.

Usage: ``python -m benchmarks.check_obs OBS.json [more.json ...]``.  Exits
non-zero listing every violation.
"""
from __future__ import annotations

import json
import math
import pathlib
import sys

_BUCKET_FIELDS = ("bits", "rank", "alpha", "clip_frac", "ef_norm",
                  "wire_bytes", "realized_mse", "predicted_mse")
_SANITY_LO, _SANITY_HI = 1e-3, 1e3


def _is_num(x) -> bool:
    return isinstance(x, int | float) and not isinstance(x, bool)


def check_summary(summary, errors: list[str]) -> int:
    """Schema + guard checks; returns the number of checks performed."""
    n = 0

    def req(cond: bool, msg: str) -> None:
        nonlocal n
        n += 1
        if not cond:
            errors.append(msg)

    req(isinstance(summary, dict), "top level is not an object")
    if not isinstance(summary, dict):
        return n
    req(summary.get("version") == 1,
        f"version must be 1, got {summary.get('version')!r}")
    req(_is_num(summary.get("n_steps")) and summary.get("n_steps", 0) >= 1,
        "n_steps must be >= 1 (no metrics events made it into the summary)")
    req(_is_num(summary.get("threshold")) and summary.get("threshold", 0) > 0,
        "threshold must be a positive number")
    threshold = summary.get("threshold", 0)
    buckets = summary.get("buckets")
    req(isinstance(buckets, list) and buckets, "buckets must be a non-empty list")
    sane = 0
    flagged_rows = []
    for row in buckets or []:
        if not isinstance(row, dict):
            req(False, f"bucket row is not an object: {row!r}")
            continue
        b = row.get("bucket")
        where = f"bucket {b}"
        for f in _BUCKET_FIELDS:
            req(_is_num(row.get(f)) and math.isfinite(row.get(f, math.nan)),
                f"{where}: {f} must be a finite number, got {row.get(f)!r}")
        if not all(_is_num(row.get(f)) for f in _BUCKET_FIELDS):
            continue
        req(0 <= row["bits"] <= 32, f"{where}: bits {row['bits']} outside [0, 32]")
        req(row["rank"] >= 0, f"{where}: negative rank")
        req(row["wire_bytes"] > 0, f"{where}: wire_bytes must be positive")
        req(0.0 <= row["clip_frac"] <= 1.0,
            f"{where}: clip_frac {row['clip_frac']} outside [0, 1]")
        req(row["realized_mse"] >= 0.0, f"{where}: negative realized_mse")
        req(row["predicted_mse"] >= 0.0, f"{where}: negative predicted_mse")
        req(row["ef_norm"] >= 0.0, f"{where}: negative ef_norm")
        ratio = row.get("ratio")
        if row["predicted_mse"] > 0:
            want = row["realized_mse"] / row["predicted_mse"]
            req(_is_num(ratio) and abs(ratio - want) <= 1e-6 * max(1.0, want),
                f"{where}: ratio {ratio!r} inconsistent with realized/predicted {want}")
            if _is_num(ratio) and _SANITY_LO <= ratio <= _SANITY_HI:
                sane += 1
        else:
            req(ratio is None, f"{where}: ratio must be null without a prediction")
        want_flag = bool(_is_num(ratio) and ratio > threshold)
        req(row.get("flagged") == want_flag,
            f"{where}: flagged={row.get('flagged')!r} disagrees with "
            f"ratio {ratio!r} vs threshold {threshold}")
        if row.get("flagged"):
            flagged_rows.append(b)
    req(summary.get("flagged") == flagged_rows,
        f"top-level flagged {summary.get('flagged')!r} disagrees with the "
        f"rows ({flagged_rows})")
    req(sane >= 1,
        f"predicted-vs-realized sanity: no bucket with a positive prediction "
        f"has realized/predicted within [{_SANITY_LO:g}, {_SANITY_HI:g}]")
    for p in summary.get("phases", []) if isinstance(summary.get("phases"), list) else ():
        req(isinstance(p, dict) and isinstance(p.get("name"), str)
            and _is_num(p.get("count")) and p.get("count", 0) >= 1
            and all(_is_num(p.get(k)) and p.get(k, -1) >= 0
                    for k in ("total_s", "mean_s", "max_s")),
            f"phase row malformed: {p!r}")
    return n


def check_file(path: pathlib.Path) -> list[str]:
    errors: list[str] = []
    try:
        summary = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable: {e}"]
    n = check_summary(summary, errors)
    if not errors:
        print(f"{path}: OK ({n} checks)")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: check_obs.py OBS.json [...]", file=sys.stderr)
        return 2
    failed = False
    for arg in argv:
        for msg in check_file(pathlib.Path(arg)):
            failed = True
            print(f"{arg}: FAIL: {msg}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
