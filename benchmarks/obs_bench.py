"""Observability overhead: in-graph metrics cost + collective neutrality.
CSV rows: obs,<case>,<us>,<derived>.

Two properties of ``TrainStepConfig.metrics_compression`` are measured:

- **wall-clock overhead** of the metric computation itself, timed through
  :func:`repro.dist.reference.reference_sync_state` (the single-device
  replica of the mesh sync, so the numbers isolate the codec + metric math
  from dispatch noise).  ``sync_metrics_on``'s derived column is the
  on/off time ratio — expected close to 1: the metric sums reuse the
  encode's residual and stats, and the α/E_TQ recomputation CSEs with the
  encode's own plan.
- **collective neutrality**, counted on a real (2,2) data×model mesh in a
  fake-device subprocess (mirroring ``adaptive_bench``): per sync mode the
  traced collective count with metrics on minus metrics off, asserted and
  reported as the derived column (must be 0 — the metric sums share the
  gnorm psum).

A third row times the host-side report pipeline (JSONL round-trip +
EMA summarize) over the synthetic event stream the first case produced.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from benchmarks.common import time_us
from repro.core.compressors import CompressorConfig
from repro.dist.reference import reference_sync_state
from repro.dist.train_step import TrainStepConfig
from repro.obs import metrics_event
from repro.obs.report import summarize

ROOT = pathlib.Path(__file__).resolve().parents[1]

LEAF_SHAPES = [(64, 48), (37, 61), (2048,), (999,)]
N_CLIENTS = 4

_COUNT_DEMO = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, AxisType
from repro.analysis.jaxpr_lint import count_collectives
from repro.core.compressors import CompressorConfig
from repro.dist.train_step import TrainStepConfig, _make_sync_fn

mesh = jax.make_mesh((2, 2), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
leaves = [jnp.ones((2,) + s, jnp.float32) for s in [(64, 48), (2048,), (999,)]]
pspecs = [P() for _ in leaves]
key = jax.random.key(0)
for sync in ("dsgd", "two_phase", "hierarchical", "faithful"):
    n = {}
    for comp in (False, True):
        ts = TrainStepConfig(sync=sync, bucket_mb=1.0 / 64.0,
                             compressor=CompressorConfig(method="tnqsgd", bits=3),
                             metrics_compression=comp)
        fn = _make_sync_fn(ts, mesh, pspecs, list(leaves))
        n[comp] = sum(count_collectives(jax.make_jaxpr(fn)(list(leaves), key)).values())
    delta = n[True] - n[False]
    assert delta == 0, (sync, n)
    print(f"obs,{sync}_metrics_collective_delta,0,{delta}")
"""


def _collective_rows() -> list[str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_COUNT_DEMO)],
                       capture_output=True, text=True, timeout=1200, env=env)
    if r.returncode != 0:  # pragma: no cover - surfaced as a bench row
        tail = (r.stderr.strip().splitlines() or ["?"])[-1][:80]
        return [f"obs,collectives_demo_error,0,{tail}"]
    return [line for line in r.stdout.splitlines() if line.startswith("obs,")]


def _ts(metrics: bool) -> TrainStepConfig:
    return TrainStepConfig(sync="faithful", bucket_mb=1.0 / 64.0,
                           compressor=CompressorConfig(method="tnqsgd", bits=3),
                           error_feedback=True, metrics_compression=metrics)


def _grads(key) -> tuple:
    return tuple(
        (jax.random.normal(jax.random.fold_in(key, i), (N_CLIENTS,) + s) * 0.05
         ).astype(jnp.float32)
        for i, s in enumerate(LEAF_SHAPES))


def main(quick: bool = False) -> list[str]:
    rows = []
    rounds = 20 if quick else 100
    key = jax.random.key(11)
    leaves = _grads(key)
    skey = jax.random.key(3)

    fns = {}
    for metrics in (False, True):
        ts = _ts(metrics)
        fn = jax.jit(lambda k, ls, ts=ts: reference_sync_state(ts, list(ls),
                                                               (N_CLIENTS,), k))
        fn(skey, leaves)  # compile
        fns[metrics] = fn
    us_off = time_us(lambda: fns[False](skey, leaves), repeats=rounds)
    us_on = time_us(lambda: fns[True](skey, leaves), repeats=rounds)
    rows.append(f"obs,sync_metrics_off,{us_off:.0f},")
    rows.append(f"obs,sync_metrics_on,{us_on:.0f},{us_on / us_off:.3f}")

    # host-side pipeline: events -> JSONL text -> parse -> EMA summary
    cm = jax.device_get(fns[True](skey, leaves)[3])
    events = [metrics_event(i, cm) for i in range(16)]
    text = "\n".join(json.dumps(ev) for ev in events)

    def pipeline():
        evs = [json.loads(line) for line in text.splitlines()]
        return summarize(evs)

    n_buckets = len(pipeline()["buckets"])
    us_rep = time_us(pipeline, repeats=rounds)
    rows.append(f"obs,report_pipeline_16ev,{us_rep:.0f},{n_buckets}")

    rows.extend(_collective_rows())
    return rows
