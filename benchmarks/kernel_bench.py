"""Kernel micro-benchmarks: fused Pallas encode/decode (interpret on CPU —
timings are correctness-path numbers, not TPU perf) vs the jnp reference.
CSV rows: kernels,<name>,<us_per_call>,<gbps_effective>.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sample_power_law
from repro.kernels import ops, ref
from repro.kernels.ops import _to_2d

from .common import time_us


def main(quick: bool = False):
    n = 2**18 if quick else 2**20
    g = sample_power_law(jax.random.key(0), (n,), gamma=4.0, g_min=0.01, rho=0.1)
    alpha = jnp.float32(0.05)
    key = jax.random.key(1)
    levels = jnp.linspace(-0.05, 0.05, 8)
    rows = []

    f_kern = jax.jit(lambda g: ops.uniform_encode(g, alpha, 3, key))
    us = time_us(f_kern, g, repeats=5)
    rows.append(f"kernels,pallas_uniform_encode_{n},{us:.0f},{n*4/us/1e3:.2f}")

    g2, _ = _to_2d(g)
    rnd = jax.random.uniform(key, g2.shape)
    f_ref = jax.jit(lambda g2: ref.uniform_encode(g2, alpha, 3, rnd))
    us = time_us(f_ref, g2, repeats=5)
    rows.append(f"kernels,ref_uniform_encode_{n},{us:.0f},{n*4/us/1e3:.2f}")

    # fused encode->bit-pack vs encode + separate pack_codes pass
    from repro.core.quantizers import pack_codes

    f_fused = jax.jit(lambda g: ops.uniform_encode_packed(g, alpha, 3, key)[0])
    us = time_us(f_fused, g, repeats=5)
    rows.append(f"kernels,pallas_uniform_encode_packed_{n},{us:.0f},{n*4/us/1e3:.2f}")

    f_twopass = jax.jit(lambda g: pack_codes(ops.uniform_encode(g, alpha, 3, key), 3))
    us = time_us(f_twopass, g, repeats=5)
    rows.append(f"kernels,encode_then_pack_{n},{us:.0f},{n*4/us/1e3:.2f}")

    f_kern2 = jax.jit(lambda g: ops.codebook_encode(g, levels, key))
    us = time_us(f_kern2, g, repeats=5)
    rows.append(f"kernels,pallas_codebook_encode_{n},{us:.0f},{n*4/us/1e3:.2f}")

    codes = f_kern(g)
    f_dec = jax.jit(lambda c: ops.codebook_decode(c, levels))
    us = time_us(f_dec, codes, repeats=5)
    rows.append(f"kernels,pallas_codebook_decode_{n},{us:.0f},{n/us/1e3:.2f}")

    rows.extend(_decode_reduce_rows(quick))
    return rows


def _decode_reduce_rows(quick: bool) -> list:
    """Fused decode-reduce vs the unfused unpack→dequant→mean pipeline.

    Derived column: effective GB/s over the decode-side HBM traffic model
    (``dist.collectives.decode_hbm_bytes``).  The equal-results contract is
    asserted here (maxdiff row): fused and unfused decode the same wire to
    the same mean up to summation-order ulps.
    """
    from repro.core.quantizers import pack_codes, unpack_codes
    from repro.dist.collectives import decode_hbm_bytes
    from repro.core.compressors import CompressorConfig

    bits, peers = 3, 8
    n = 2**16 if quick else 2**18
    key = jax.random.key(5)
    codes = jax.random.randint(key, (peers, n), 0, 2**bits).astype(jnp.uint8)
    words = jnp.stack([pack_codes(codes[j], bits) for j in range(peers)])
    levels = jnp.sort(jax.random.uniform(jax.random.fold_in(key, 1), (peers, 2**bits),
                                         minval=-0.1, maxval=0.1), axis=1)
    alphas = levels[:, -1]
    cfg = CompressorConfig(method="tnqsgd", bits=bits)
    hbm_fused = decode_hbm_bytes(cfg, n, peers, fused=True)
    hbm_unfused = decode_hbm_bytes(cfg, n, peers, fused=False)
    rows = [f"kernels,decode_hbm_fused_vs_unfused_{n},0,{hbm_unfused / hbm_fused:.2f}"]

    f_fused = jax.jit(lambda w, lv: ops.codebook_decode_reduce(w, lv, n, bits))
    us = time_us(f_fused, words, levels, repeats=5)
    rows.append(f"kernels,pallas_codebook_decode_reduce_{n}x{peers},{us:.0f},"
                f"{hbm_fused / us / 1e3:.2f}")

    @jax.jit
    def unfused(w, lv):
        c = jax.vmap(lambda row: unpack_codes(row, n, bits))(w)
        return jnp.mean(jax.vmap(lambda cc, l: jnp.take(l, cc.astype(jnp.int32)))(c, lv),
                        axis=0)

    us = time_us(unfused, words, levels, repeats=5)
    rows.append(f"kernels,unfused_decode_mean_{n}x{peers},{us:.0f},"
                f"{hbm_unfused / us / 1e3:.2f}")

    diff = float(jnp.max(jnp.abs(f_fused(words, levels) - unfused(words, levels))))
    rows.append(f"kernels,decode_fused_vs_unfused_maxdiff,0,{diff:.1e}")
    assert diff < 1e-6, f"fused decode-reduce diverged from the unfused mean: {diff}"

    f_uni = jax.jit(lambda w, a: ops.uniform_decode_reduce(w, a, n, bits))
    us = time_us(f_uni, words, alphas, repeats=5)
    rows.append(f"kernels,pallas_uniform_decode_reduce_{n}x{peers},{us:.0f},"
                f"{hbm_fused / us / 1e3:.2f}")

    # the rows (no-reduce) kernel writes the full (peers, n) output — its
    # traffic model is the fused wire read plus that payload, not the (n,)
    # mean the reduce model charges
    hbm_rows = hbm_fused - 4.0 * n + 4.0 * peers * n
    f_rows = jax.jit(lambda w, lv: ops.codebook_decode_rows(w, lv, n, bits))
    us = time_us(f_rows, words, levels, repeats=5)
    rows.append(f"kernels,pallas_codebook_decode_rows_{n}x{peers},{us:.0f},"
                f"{hbm_rows / us / 1e3:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
