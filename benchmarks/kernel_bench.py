"""Kernel micro-benchmarks: fused Pallas encode/decode (interpret on CPU —
timings are correctness-path numbers, not TPU perf) vs the jnp reference.
CSV rows: kernels,<name>,<us_per_call>,<gbps_effective>.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sample_power_law
from repro.kernels import ops, ref
from repro.kernels.ops import _to_2d

from .common import time_us


def main(quick: bool = False):
    n = 2**18 if quick else 2**20
    g = sample_power_law(jax.random.key(0), (n,), gamma=4.0, g_min=0.01, rho=0.1)
    alpha = jnp.float32(0.05)
    key = jax.random.key(1)
    levels = jnp.linspace(-0.05, 0.05, 8)
    rows = []

    f_kern = jax.jit(lambda g: ops.uniform_encode(g, alpha, 3, key))
    us = time_us(f_kern, g, repeats=5)
    rows.append(f"kernels,pallas_uniform_encode_{n},{us:.0f},{n*4/us/1e3:.2f}")

    g2, _ = _to_2d(g)
    rnd = jax.random.uniform(key, g2.shape)
    f_ref = jax.jit(lambda g2: ref.uniform_encode(g2, alpha, 3, rnd))
    us = time_us(f_ref, g2, repeats=5)
    rows.append(f"kernels,ref_uniform_encode_{n},{us:.0f},{n*4/us/1e3:.2f}")

    # fused encode->bit-pack vs encode + separate pack_codes pass
    from repro.core.quantizers import pack_codes

    f_fused = jax.jit(lambda g: ops.uniform_encode_packed(g, alpha, 3, key)[0])
    us = time_us(f_fused, g, repeats=5)
    rows.append(f"kernels,pallas_uniform_encode_packed_{n},{us:.0f},{n*4/us/1e3:.2f}")

    f_twopass = jax.jit(lambda g: pack_codes(ops.uniform_encode(g, alpha, 3, key), 3))
    us = time_us(f_twopass, g, repeats=5)
    rows.append(f"kernels,encode_then_pack_{n},{us:.0f},{n*4/us/1e3:.2f}")

    f_kern2 = jax.jit(lambda g: ops.codebook_encode(g, levels, key))
    us = time_us(f_kern2, g, repeats=5)
    rows.append(f"kernels,pallas_codebook_encode_{n},{us:.0f},{n*4/us/1e3:.2f}")

    codes = f_kern(g)
    f_dec = jax.jit(lambda c: ops.codebook_decode(c, levels))
    us = time_us(f_dec, codes, repeats=5)
    rows.append(f"kernels,pallas_codebook_decode_{n},{us:.0f},{n/us/1e3:.2f}")

    rows.extend(_decode_reduce_rows(quick))
    rows.extend(_encode_rows(quick))
    return rows


def _encode_rows(quick: bool) -> list:
    """Fused encode side (EF-correct→stats + quantize→pack→residual) vs the
    seed multi-pass pipeline (leaf EF add → stats sweep → sort-based plan →
    encode → pack → own-decode → residual).

    Rows report the modeled per-step encode-side HBM traffic
    (``dist.collectives.encode_hbm_bytes``: sweep count × bucket bytes) for
    seed vs fused at the headline config (4 MB bucket, 3 bits, EF+adaptive
    on), plus wall time of both jnp pipelines (what CPU actually runs; the
    Pallas kernels are bit-compared in ``tests/test_encode_kernels.py``).
    The asserts double as the CI bench guard: the job fails if the fused
    path's modeled bytes or wall time exceed the unfused path's.
    """
    from repro.adaptive.telemetry import correct_stats
    from repro.core.compressors import CompressorConfig, plan, plan_from_stats
    from repro.core.quantizers import pack_codes, stochastic_encode
    from repro.dist import sharded_codec as sc
    from repro.dist.collectives import encode_hbm_bytes

    bits = 3
    n = 2**18 if quick else 2**20
    cfg = CompressorConfig(method="tnqsgd", bits=bits)
    key = jax.random.key(7)
    g = sample_power_law(jax.random.key(8), (n,), gamma=3.7, g_min=0.01, rho=0.12)
    e = 0.2 * sample_power_law(jax.random.key(9), (n,), gamma=4.2, g_min=0.005, rho=0.1)

    # modeled HBM traffic at the headline config (4 MB bucket -> n = 1M)
    nb = 1 << 20
    hbm_fused = encode_hbm_bytes(cfg, nb, fused=True)
    hbm_seed = encode_hbm_bytes(cfg, nb, fused=False)
    rows = [
        f"kernels,encode_hbm_seed_4mb_b3_ef_adaptive,0,{hbm_seed:.3e}",
        f"kernels,encode_hbm_fused_4mb_b3_ef_adaptive,0,{hbm_fused:.3e}",
        f"kernels,encode_hbm_fused_vs_seed_4mb_b3,0,{hbm_seed / hbm_fused:.2f}",
        # per-step sweep counts over the bucket bytes (count x 4n bytes)
        f"kernels,encode_sweeps_seed,0,{hbm_seed / (4.0 * nb):.2f}",
        f"kernels,encode_sweeps_fused,0,{hbm_fused / (4.0 * nb):.2f}",
    ]
    assert hbm_fused < hbm_seed, (hbm_fused, hbm_seed)
    assert hbm_seed / hbm_fused >= 3.0, (hbm_seed, hbm_fused)

    # wall time: fused one-pass pipeline vs the seed multi-pass pipeline
    # Both pipelines return the full telemetry stats tuple alongside the
    # wire + residual — the real train step consumes every row (EMA
    # histogram, Hill sums, max, moments), and returning them stops XLA
    # from dead-code-eliminating part of either side's stats sweep.
    @jax.jit
    def fused(g, e):
        c, st = correct_stats(g, e)                       # EF add + all stats
        meta = plan_from_stats(cfg, st[0], st[1], st[2])  # histogram-driven plan
        words, resid = sc.encode_pack_residual(cfg, c, meta, key, False)
        return words, resid, st

    @jax.jit
    def seed(g, e):
        c = g + e                                         # leaf-wise EF add
        telem = correct_stats(c)[1]                       # telemetry stats sweep
        meta = plan(cfg, c)                               # sort-based plan
        codes = stochastic_encode(c, meta, key)           # encode
        words = pack_codes(codes, bits)                   # separate pack pass
        own = jnp.take(meta.levels, codes.astype(jnp.int32))  # own-decode
        return words, c - own, telem                      # residual pass

    us_fused = time_us(fused, g, e, repeats=5)
    us_seed = time_us(seed, g, e, repeats=5)
    # rate columns use the modeled bytes of the *timed* size (quick mode
    # times a smaller n than the 4 MB model rows above)
    hbm_fused_n = encode_hbm_bytes(cfg, n, fused=True)
    hbm_seed_n = encode_hbm_bytes(cfg, n, fused=False)
    rows.append(f"kernels,fused_encode_pipeline_{n},{us_fused:.0f},"
                f"{hbm_fused_n / us_fused / 1e3:.2f}")
    rows.append(f"kernels,seed_encode_pipeline_{n},{us_seed:.0f},"
                f"{hbm_seed_n / us_seed / 1e3:.2f}")
    # 10% slack absorbs scheduler noise on shared CI runners (5-repeat
    # wall-clock); the quiet-machine margin is ~1.5x, so a real regression
    # still trips the guard
    assert us_fused <= 1.1 * us_seed, (us_fused, us_seed)

    # equal-results contract: both pipelines produce valid wire words for
    # the same corrected tensor; the fused residual matches own-decode of
    # its own wire bit-for-bit (codebook lookup is exact).
    w, r, _ = fused(g, e)  # noqa: F841 - st unused here
    from repro.kernels import ref as kref

    c, st = correct_stats(g, e)
    meta = plan_from_stats(cfg, st[0], st[1], st[2])
    w2, r2 = kref.codebook_encode_pack_residual(c, meta.levels, bits, key)
    assert int(jnp.sum(w != w2)) == 0, "wire words diverged between pipelines"
    diff = float(jnp.max(jnp.abs(r - r2)))
    rows.append(f"kernels,encode_fused_vs_oracle_maxdiff,0,{diff:.1e}")
    assert diff < 1e-6, diff
    return rows


def _decode_reduce_rows(quick: bool) -> list:
    """Fused decode-reduce vs the unfused unpack→dequant→mean pipeline.

    Derived column: effective GB/s over the decode-side HBM traffic model
    (``dist.collectives.decode_hbm_bytes``).  The equal-results contract is
    asserted here (maxdiff row): fused and unfused decode the same wire to
    the same mean up to summation-order ulps.
    """
    from repro.core.quantizers import pack_codes, unpack_codes
    from repro.dist.collectives import decode_hbm_bytes
    from repro.core.compressors import CompressorConfig

    bits, peers = 3, 8
    n = 2**16 if quick else 2**18
    key = jax.random.key(5)
    codes = jax.random.randint(key, (peers, n), 0, 2**bits).astype(jnp.uint8)
    words = jnp.stack([pack_codes(codes[j], bits) for j in range(peers)])
    levels = jnp.sort(jax.random.uniform(jax.random.fold_in(key, 1), (peers, 2**bits),
                                         minval=-0.1, maxval=0.1), axis=1)
    alphas = levels[:, -1]
    cfg = CompressorConfig(method="tnqsgd", bits=bits)
    hbm_fused = decode_hbm_bytes(cfg, n, peers, fused=True)
    hbm_unfused = decode_hbm_bytes(cfg, n, peers, fused=False)
    rows = [f"kernels,decode_hbm_fused_vs_unfused_{n},0,{hbm_unfused / hbm_fused:.2f}"]

    f_fused = jax.jit(lambda w, lv: ops.codebook_decode_reduce(w, lv, n, bits))
    us = time_us(f_fused, words, levels, repeats=5)
    rows.append(f"kernels,pallas_codebook_decode_reduce_{n}x{peers},{us:.0f},"
                f"{hbm_fused / us / 1e3:.2f}")

    @jax.jit
    def unfused(w, lv):
        c = jax.vmap(lambda row: unpack_codes(row, n, bits))(w)
        return jnp.mean(jax.vmap(lambda cc, l: jnp.take(l, cc.astype(jnp.int32)))(c, lv),
                        axis=0)

    us = time_us(unfused, words, levels, repeats=5)
    rows.append(f"kernels,unfused_decode_mean_{n}x{peers},{us:.0f},"
                f"{hbm_unfused / us / 1e3:.2f}")

    diff = float(jnp.max(jnp.abs(f_fused(words, levels) - unfused(words, levels))))
    rows.append(f"kernels,decode_fused_vs_unfused_maxdiff,0,{diff:.1e}")
    assert diff < 1e-6, f"fused decode-reduce diverged from the unfused mean: {diff}"

    f_uni = jax.jit(lambda w, a: ops.uniform_decode_reduce(w, a, n, bits))
    us = time_us(f_uni, words, alphas, repeats=5)
    rows.append(f"kernels,pallas_uniform_decode_reduce_{n}x{peers},{us:.0f},"
                f"{hbm_fused / us / 1e3:.2f}")

    # the rows (no-reduce) kernel writes the full (peers, n) output — its
    # traffic model is the fused wire read plus that payload, not the (n,)
    # mean the reduce model charges
    hbm_rows = hbm_fused - 4.0 * n + 4.0 * peers * n
    f_rows = jax.jit(lambda w, lv: ops.codebook_decode_rows(w, lv, n, bits))
    us = time_us(f_rows, words, levels, repeats=5)
    rows.append(f"kernels,pallas_codebook_decode_rows_{n}x{peers},{us:.0f},"
                f"{hbm_rows / us / 1e3:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
