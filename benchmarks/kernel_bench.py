"""Kernel micro-benchmarks: fused Pallas encode/decode (interpret on CPU —
timings are correctness-path numbers, not TPU perf) vs the jnp reference.
CSV rows: kernels,<name>,<us_per_call>,<gbps_effective>.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sample_power_law
from repro.kernels import ops, ref
from repro.kernels.ops import _to_2d

from .common import time_us


def main(quick: bool = False):
    n = 2**18 if quick else 2**20
    g = sample_power_law(jax.random.key(0), (n,), gamma=4.0, g_min=0.01, rho=0.1)
    alpha = jnp.float32(0.05)
    key = jax.random.key(1)
    levels = jnp.linspace(-0.05, 0.05, 8)
    rows = []

    f_kern = jax.jit(lambda g: ops.uniform_encode(g, alpha, 3, key))
    us = time_us(f_kern, g, repeats=5)
    rows.append(f"kernels,pallas_uniform_encode_{n},{us:.0f},{n*4/us/1e3:.2f}")

    g2, _ = _to_2d(g)
    rnd = jax.random.uniform(key, g2.shape)
    f_ref = jax.jit(lambda g2: ref.uniform_encode(g2, alpha, 3, rnd))
    us = time_us(f_ref, g2, repeats=5)
    rows.append(f"kernels,ref_uniform_encode_{n},{us:.0f},{n*4/us/1e3:.2f}")

    # fused encode->bit-pack vs encode + separate pack_codes pass
    from repro.core.quantizers import pack_codes

    f_fused = jax.jit(lambda g: ops.uniform_encode_packed(g, alpha, 3, key)[0])
    us = time_us(f_fused, g, repeats=5)
    rows.append(f"kernels,pallas_uniform_encode_packed_{n},{us:.0f},{n*4/us/1e3:.2f}")

    f_twopass = jax.jit(lambda g: pack_codes(ops.uniform_encode(g, alpha, 3, key), 3))
    us = time_us(f_twopass, g, repeats=5)
    rows.append(f"kernels,encode_then_pack_{n},{us:.0f},{n*4/us/1e3:.2f}")

    f_kern2 = jax.jit(lambda g: ops.codebook_encode(g, levels, key))
    us = time_us(f_kern2, g, repeats=5)
    rows.append(f"kernels,pallas_codebook_encode_{n},{us:.0f},{n*4/us/1e3:.2f}")

    codes = f_kern(g)
    f_dec = jax.jit(lambda c: ops.codebook_decode(c, levels))
    us = time_us(f_dec, codes, repeats=5)
    rows.append(f"kernels,pallas_codebook_decode_{n},{us:.0f},{n/us/1e3:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
