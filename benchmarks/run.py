"""Benchmark harness: one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows and writes the same numbers as
machine-readable JSON (``BENCH_core.json`` by default, ``--json PATH`` to
move it, ``--json ""`` to disable) so CI can archive the perf trajectory.
``--adaptive`` swaps in the adaptive-allocation suite
(``benchmarks/adaptive_bench.py``) and defaults to ``BENCH_adaptive.json``.
``--full`` uses the paper-scale round counts (slow on CPU); the default
quick mode (also spelled ``--quick``, the flag CI passes) validates the
orderings.

Runs both as ``python -m benchmarks.run`` and as ``python benchmarks/run.py``
(the script form bootstraps the repo root + ``src`` onto ``sys.path``).
"""
import argparse
import json
import pathlib
import sys
import time

if __package__ in (None, ""):  # script invocation: python benchmarks/run.py
    _ROOT = pathlib.Path(__file__).resolve().parents[1]
    for p in (str(_ROOT), str(_ROOT / "src")):
        if p not in sys.path:
            sys.path.insert(0, p)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale round counts")
    ap.add_argument("--quick", action="store_true",
                    help="quick mode (the default; ignored with --full)")
    ap.add_argument("--only", default=None, help="comma-separated subset")
    ap.add_argument("--adaptive", action="store_true",
                    help="run the adaptive-allocation suite instead (default "
                         "output: BENCH_adaptive.json)")
    ap.add_argument("--json", default=None, dest="json_path",
                    help="machine-readable output path (empty string disables)")
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (
        adaptive_bench, collectives_bench, elastic_bench, fig1_grad_density,
        fig3_accuracy, fig4_tradeoff, kernel_bench, lowrank_bench, obs_bench,
        quant_error,
    )

    suites = {"adaptive": adaptive_bench.main} if args.adaptive else {
        "quant_error": quant_error.main,
        "kernels": kernel_bench.main,
        "collectives": collectives_bench.main,
        "elastic": elastic_bench.main,
        "lowrank": lowrank_bench.main,
        "obs": obs_bench.main,
        "fig1_grad_density": fig1_grad_density.main,
        "fig3_accuracy": fig3_accuracy.main,
        "fig4_tradeoff": fig4_tradeoff.main,
    }
    if args.only:
        keep = set(args.only.split(","))
        unknown = sorted(keep - set(suites))
        if unknown:
            ap.error(f"unknown --only suite(s): {', '.join(unknown)}; "
                     f"valid names: {', '.join(sorted(suites))}")
        suites = {k: v for k, v in suites.items() if k in keep}
    if args.json_path is None:
        # default artifact name: BENCH_adaptive for the adaptive suite,
        # BENCH_<suite> for a single --only selection, BENCH_core otherwise
        if args.adaptive:
            args.json_path = "BENCH_adaptive.json"
        elif args.only and len(suites) == 1:
            args.json_path = f"BENCH_{next(iter(suites))}.json"
        else:
            args.json_path = "BENCH_core.json"

    print("name,us_per_call,derived")
    report: dict = {"mode": "full" if args.full else "quick", "suites": {}}
    for name, fn in suites.items():
        t0 = time.perf_counter()
        try:
            rows = fn(quick=quick)
        except Exception as e:  # pragma: no cover
            print(f"{name},ERROR,0,{type(e).__name__}:{e}", flush=True)
            raise
        cases = {}
        for r in rows:
            print(r, flush=True)
            parts = r.split(",")
            if len(parts) >= 3:
                case = parts[1]
                try:
                    us = float(parts[2])
                except ValueError:
                    us = None
                cases[case] = {"us_per_call": us}
                if len(parts) > 3 and parts[3]:
                    try:
                        cases[case]["derived"] = float(parts[3])
                    except ValueError:
                        cases[case]["derived"] = parts[3]
        total_us = (time.perf_counter() - t0) * 1e6
        print(f"{name}__total,{total_us:.0f},", flush=True)
        report["suites"][name] = {"us_total": round(total_us), "cases": cases}
    if args.json_path:
        pathlib.Path(args.json_path).write_text(json.dumps(report, indent=2) + "\n")
        print(f"wrote {args.json_path}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
