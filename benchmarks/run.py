"""Benchmark harness: one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` uses the paper-scale
round counts (slow on CPU); the default quick mode (also spelled ``--quick``,
the flag CI passes) validates the orderings.

Runs both as ``python -m benchmarks.run`` and as ``python benchmarks/run.py``
(the script form bootstraps the repo root + ``src`` onto ``sys.path``).
"""
import argparse
import pathlib
import sys
import time

if __package__ in (None, ""):  # script invocation: python benchmarks/run.py
    _ROOT = pathlib.Path(__file__).resolve().parents[1]
    for p in (str(_ROOT), str(_ROOT / "src")):
        if p not in sys.path:
            sys.path.insert(0, p)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale round counts")
    ap.add_argument("--quick", action="store_true",
                    help="quick mode (the default; ignored with --full)")
    ap.add_argument("--only", default=None, help="comma-separated subset")
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (
        collectives_bench, fig1_grad_density, fig3_accuracy, fig4_tradeoff, kernel_bench, quant_error,
    )

    suites = {
        "quant_error": quant_error.main,
        "kernels": kernel_bench.main,
        "collectives": collectives_bench.main,
        "fig1_grad_density": fig1_grad_density.main,
        "fig3_accuracy": fig3_accuracy.main,
        "fig4_tradeoff": fig4_tradeoff.main,
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.perf_counter()
        try:
            rows = fn(quick=quick)
        except Exception as e:  # pragma: no cover
            print(f"{name},ERROR,0,{type(e).__name__}:{e}", flush=True)
            raise
        for r in rows:
            print(r, flush=True)
        print(f"{name}__total,{(time.perf_counter()-t0)*1e6:.0f},", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
