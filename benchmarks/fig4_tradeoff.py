"""Fig. 4 reproduction: communication budget (bits) vs test accuracy.
CSV rows: fig4_tradeoff,<method>@b<bits>,<wire_bytes_per_param>,<accuracy>.
"""
from __future__ import annotations

from repro.core.compressors import CompressorConfig, wire_bytes

from .common import train_clients

METHODS = ("qsgd", "tqsgd", "tnqsgd")
BITS = (2, 3, 4)


def main(quick: bool = False):
    rounds = 25 if quick else 100
    rows = []
    for m in METHODS:
        for b in BITS:
            acc, _ = train_clients(m, bits=b, rounds=rounds)
            bpp = wire_bytes(CompressorConfig(method=m, bits=b), 100_000) / 100_000
            rows.append(f"fig4_tradeoff,{m}@b{b},0,{acc:.4f}")
            rows.append(f"fig4_tradeoff,{m}@b{b}_bytes_per_param,0,{bpp:.4f}")
    acc, _ = train_clients("dsgd", bits=8, rounds=rounds)  # bits unused for dsgd
    rows.append(f"fig4_tradeoff,dsgd@b32,0,{acc:.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
