"""Fig. 3 reproduction: test accuracy of DSGD vs Q/NQ/TQ/TNQ/TBQ-SGD at b=3,
N=8 clients, momentum SGD — the paper's headline comparison.
CSV rows: fig3_accuracy,<method>,<us_per_round>,<accuracy>.
"""
from __future__ import annotations

import time

from .common import train_clients

METHODS = ("dsgd", "qsgd", "nqsgd", "tqsgd", "tnqsgd", "tbqsgd")


def main(quick: bool = False):
    rounds = 30 if quick else 120
    rows = []
    for m in METHODS:
        t0 = time.perf_counter()
        acc, hist = train_clients(m, bits=3, rounds=rounds)
        us = (time.perf_counter() - t0) / rounds * 1e6
        rows.append(f"fig3_accuracy,{m},{us:.0f},{acc:.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
