"""Gradient-sync wire accounting + (when dry-run artifacts exist) measured
collective bytes per mode from the compiled HLO.
CSV rows: collectives,<case>,0,<bytes or ratio>.

Also demonstrates the bucketed codec on a real host mesh (subprocess with
fake devices): the per-leaf path issues O(leaves) collectives per step, the
bucketed path a mode-bounded handful, while both produce the same mean up to
quantization noise.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import textwrap

from repro.core.compressors import CompressorConfig
from repro.dist.collectives import decode_hbm_bytes, encode_hbm_bytes, wire_bytes_per_device

RUNS = pathlib.Path(__file__).resolve().parents[1] / "runs" / "dryrun"
ROOT = pathlib.Path(__file__).resolve().parents[1]

_BUCKETED_DEMO = """
import collections, jax, jax.numpy as jnp
from jax.sharding import AxisType
from repro.configs import get_config, reduced
from repro.core.compressors import CompressorConfig
from repro.dist import sharding
from repro.dist.train_step import TrainStepConfig, _make_sync_fn
from repro.models import init_lm

mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
cfg = reduced(get_config("llama3.2-1b")).replace(fsdp=False)
params0, logical = init_lm(jax.random.key(0), cfg)
pspecs = sharding.param_pspecs(logical, mesh, False, params0)
grads = jax.tree.map(lambda x: jnp.tile((jax.random.normal(jax.random.key(1), x.shape) * 0.05
                                          ).astype(jnp.float32)[None], (4,) + (1,) * x.ndim), params0)
grads_like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
key = jax.random.key(3)

COLLECTIVES = {"all_to_all", "all_gather", "psum", "ppermute", "all_gather_invariant"}
def count(jaxpr, acc):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in COLLECTIVES:
            acc[eqn.primitive.name] += 1
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                count(v.jaxpr, acc)
            elif hasattr(v, "eqns"):
                count(v, acc)
    return acc

n_leaves = len(jax.tree.leaves(params0))
print(f"collectives,n_grad_leaves,0,{n_leaves}")
for sync in ("two_phase", "faithful"):
    out, n_coll = {}, {}
    for name, mb in [("leaf", 0.0), ("bucket", 4.0)]:
        ts = TrainStepConfig(sync=sync, compressor=CompressorConfig(method="tqsgd", bits=4),
                             bucket_mb=mb, metrics_gnorm=False)
        jfn = jax.jit(_make_sync_fn(ts, mesh, pspecs, grads_like))
        n_coll[name] = sum(count(jfn.trace(grads, key).jaxpr.jaxpr, collections.Counter()).values())
        out[name] = jfn(grads, key)
        print(f"collectives,{sync}_{name}_n_collectives,0,{n_coll[name]}")
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(out["leaf"]), jax.tree.leaves(out["bucket"])))
    scale = max(float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(out["leaf"]))
    print(f"collectives,{sync}_bucket_vs_leaf_maxdiff,0,{diff:.4f}")
    # same mean up to quantization noise, mode-bounded collective count
    assert diff < 0.5 * scale + 0.02, (sync, diff, scale)
    assert n_coll["bucket"] == (2 if sync == "two_phase" else 1), (sync, n_coll)
    assert n_coll["leaf"] >= n_leaves, (sync, n_coll, n_leaves)
print("collectives,bucketed_demo,0,OK")
"""


def _bucketed_demo_rows() -> list[str]:
    """Run the leaf-vs-bucket demo in a 4-fake-device subprocess.

    The script asserts the acceptance properties itself (same mean within
    quantization tolerance; 2/1 collectives for bucketed two_phase/faithful
    vs >= n_leaves per-leaf) and reports them as rows; the tier-1 test
    ``tests/test_dist.py::test_bucketed_matches_per_leaf_mean`` reuses this
    exact script, so bench and test cannot drift.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_BUCKETED_DEMO)],
                       capture_output=True, text=True, timeout=1200, env=env)
    if r.returncode != 0:  # pragma: no cover - surfaced as a bench row
        tail = (r.stderr.strip().splitlines() or ["?"])[-1][:80]
        return [f"collectives,bucketed_demo_error,0,{tail}"]
    return [line for line in r.stdout.splitlines() if line.startswith("collectives,")]


def main(quick: bool = False):
    rows = []
    n = 1_000_000_000  # 1B-element gradient
    shards = 16
    fp32 = wire_bytes_per_device(CompressorConfig(method="dsgd"), n, shards, "dsgd")
    rows.append(f"collectives,dsgd_fp32_bytes_1B,0,{fp32:.3e}")
    for bits in (2, 3, 4, 8):
        cfg = CompressorConfig(method="tnqsgd", bits=bits)
        for mode in ("faithful", "two_phase"):
            b = wire_bytes_per_device(cfg, n, shards, mode)
            rows.append(f"collectives,tnqsgd_b{bits}_{mode}_bytes_1B,0,{b:.3e}")
            rows.append(f"collectives,tnqsgd_b{bits}_{mode}_vs_fp32,0,{fp32/b:.2f}")

    # adaptive heterogeneous wire format: same 1B elements split across four
    # buckets at the controller-style mixed widths — the accounting is the
    # per-bucket sum (one codebook each), averaging to 3 bits/element here,
    # so the cost matches the uniform-3-bit fused wire to within metadata.
    cfg = CompressorConfig(method="tnqsgd", bits=3)
    bsizes = [n // 4] * 4
    for mode in ("faithful", "two_phase"):
        het = wire_bytes_per_device(cfg, bsizes, shards, mode, bits=[2, 2, 4, 4])
        uni = wire_bytes_per_device(cfg, bsizes, shards, mode)
        rows.append(f"collectives,adaptive_2244_{mode}_bytes_1B,0,{het:.3e}")
        rows.append(f"collectives,adaptive_2244_{mode}_vs_uniform3,0,{uni/het:.4f}")

    # decode-side HBM traffic: the fused unpack→dequant→reduce kernels read
    # the packed wire once and write the (n,) mean, vs the unfused path that
    # round-trips the (peers, n) unpacked code and value tensors through HBM.
    cfg = CompressorConfig(method="tnqsgd", bits=3)
    for bits in (2, 3, 4, 8):
        un = decode_hbm_bytes(cfg, n, shards, fused=False, bits=bits)
        fu = decode_hbm_bytes(cfg, n, shards, fused=True, bits=bits)
        rows.append(f"collectives,decode_b{bits}_unfused_hbm_1B,0,{un:.3e}")
        rows.append(f"collectives,decode_b{bits}_fused_hbm_1B,0,{fu:.3e}")
        rows.append(f"collectives,decode_b{bits}_fused_vs_unfused,0,{un / fu:.2f}")
    # the adaptive heterogeneous wire decodes bucket-by-bucket through the
    # same fused kernels — the accounting is the per-bucket sum
    un = decode_hbm_bytes(cfg, bsizes, shards, fused=False, bits=[2, 2, 4, 4])
    fu = decode_hbm_bytes(cfg, bsizes, shards, fused=True, bits=[2, 2, 4, 4])
    rows.append(f"collectives,decode_adaptive_2244_fused_vs_unfused,0,{un / fu:.2f}")

    # encode-side HBM traffic: the fused EF-correct→stats +
    # quantize→pack→residual kernels vs the seed multi-pass pipeline
    # (leaf EF add, telemetry sweep, sort-based plan, encode, pack,
    # own-decode, residual, EF split/restack).  4 MB buckets = 1M elements.
    nb4 = 1 << 20
    for bits in (2, 3, 4, 8):
        un = encode_hbm_bytes(cfg, nb4, fused=False, bits=bits)
        fu = encode_hbm_bytes(cfg, nb4, fused=True, bits=bits)
        rows.append(f"collectives,encode_b{bits}_unfused_hbm_4mb,0,{un:.3e}")
        rows.append(f"collectives,encode_b{bits}_fused_hbm_4mb,0,{fu:.3e}")
        rows.append(f"collectives,encode_b{bits}_fused_vs_unfused,0,{un / fu:.2f}")
    # without EF/telemetry the fused path still wins (the one-pass stats
    # read replaces the subsampled sort at better statistics)
    un = encode_hbm_bytes(cfg, nb4, fused=False, ef=False, adaptive=False)
    fu = encode_hbm_bytes(cfg, nb4, fused=True, ef=False, adaptive=False)
    rows.append(f"collectives,encode_b3_noef_fused_vs_unfused,0,{un / fu:.2f}")
    # heterogeneous adaptive wire: per-bucket sum
    un = encode_hbm_bytes(cfg, bsizes, fused=False, bits=[2, 2, 4, 4])
    fu = encode_hbm_bytes(cfg, bsizes, fused=True, bits=[2, 2, 4, 4])
    rows.append(f"collectives,encode_adaptive_2244_fused_vs_unfused,0,{un / fu:.2f}")

    # bucketed codec vs per-leaf codec on a live 4-device host mesh — skipped
    # in quick mode (CI smoke): the tier-1 test job runs the same script via
    # tests/test_dist.py, so quick mode gains nothing from repeating it.
    if not quick:
        rows.extend(_bucketed_demo_rows())

    # measured per-device collective bytes from dry-run artifacts, if present
    if RUNS.exists():
        for f in sorted(RUNS.glob("*train_4k*16x16*.json"))[:12]:
            rec = json.loads(f.read_text())
            r = rec.get("roofline", {})
            rows.append(
                f"collectives,measured/{rec['arch']}_{rec.get('sync')},0,{r.get('collective_bytes', 0):.3e}"
            )
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
