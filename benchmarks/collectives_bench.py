"""Gradient-sync wire accounting + (when dry-run artifacts exist) measured
collective bytes per mode from the compiled HLO.
CSV rows: collectives,<case>,0,<bytes or ratio>.
"""
from __future__ import annotations

import json
import pathlib

from repro.core.compressors import CompressorConfig
from repro.dist.collectives import wire_bytes_per_device

RUNS = pathlib.Path(__file__).resolve().parents[1] / "runs" / "dryrun"


def main(quick: bool = False):
    rows = []
    n = 1_000_000_000  # 1B-element gradient
    shards = 16
    fp32 = wire_bytes_per_device(CompressorConfig(method="dsgd"), n, shards, "dsgd")
    rows.append(f"collectives,dsgd_fp32_bytes_1B,0,{fp32:.3e}")
    for bits in (2, 3, 4, 8):
        cfg = CompressorConfig(method="tnqsgd", bits=bits)
        for mode in ("faithful", "two_phase"):
            b = wire_bytes_per_device(cfg, n, shards, mode)
            rows.append(f"collectives,tnqsgd_b{bits}_{mode}_bytes_1B,0,{b:.3e}")
            rows.append(f"collectives,tnqsgd_b{bits}_{mode}_vs_fp32,0,{fp32/b:.2f}")

    # measured per-device collective bytes from dry-run artifacts, if present
    if RUNS.exists():
        for f in sorted(RUNS.glob("*train_4k*16x16*.json"))[:12]:
            rec = json.loads(f.read_text())
            r = rec.get("roofline", {})
            rows.append(
                f"collectives,measured/{rec['arch']}_{rec.get('sync')},0,{r.get('collective_bytes', 0):.3e}"
            )
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
