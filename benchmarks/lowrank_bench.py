"""PowerSGD vs equal-wire-byte quantization on a 2-D-dominant bucket mix.
CSV rows: lowrank,<case>,<us>,<derived>.

The paper's quantizers spend wire on per-element codes; PowerSGD spends it
on rank-r factors.  This suite pits them at (approximately) matched wire
bytes on the workload low-rank compression is built for — buckets dominated
by matrix-shaped gradients with correlated rows (a rank-q signal plus
per-client noise) — and reports the final-round sync MSE of each
against the exact client mean, both run for a few error-feedback rounds
through :func:`repro.dist.reference.reference_sync_state` (the
single-device replica of the mesh codec).  The rank is chosen as the
largest whose factor wire stays under the 3-bit quantizer's wire for the
same buckets, so the comparison is bytes-for-bytes in the quantizer's
favor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_us
from repro.core.compressors import CompressorConfig, plan_buckets, wire_bytes
from repro.core.lowrank import matrix_shape
from repro.dist import sharded_codec as sc
from repro.dist.reference import reference_sync_state
from repro.dist.train_step import TrainStepConfig

# 2-D-dominant mix: three matrix leaves + one small vector tail.  Power-of-
# two widths line up with ``matrix_shape``'s factorization target, so the
# bucketed codec's flatten→reshape preserves each leaf's row structure (at
# BUCKET_MB = 1/64 the matrix leaves each get their own bucket).
LEAF_SHAPES = [(64, 32), (96, 32), (128, 64), (999,)]
BUCKET_MB = 1.0 / 64.0
SIGNAL_RANK = 2
NOISE = 0.1


def _client_grads(sig_key, noise_key, n_clients: int) -> list[jax.Array]:
    """Stacked (n_clients, *shape) gradients: shared low-rank signal per
    matrix leaf + per-client noise (the regime where the gradient mean has
    an approximately low-rank structure worth factorizing).  The signal is
    fixed across rounds (``sig_key``) while the noise redraws
    (``noise_key``), mimicking a slowly-moving dominant subspace."""
    leaves = []
    for i, shape in enumerate(LEAF_SHAPES):
        kl = jax.random.fold_in(sig_key, i)
        kn = jax.random.fold_in(noise_key, i)
        if len(shape) == 2:
            ka, kb = jax.random.split(kl)
            sig = (jax.random.normal(ka, (shape[0], SIGNAL_RANK))
                   @ jax.random.normal(kb, (SIGNAL_RANK, shape[1]))) / SIGNAL_RANK
            noise = NOISE * jax.random.normal(kn, (n_clients,) + shape)
            leaves.append((sig[None] + noise).astype(jnp.float32))
        else:
            leaves.append((NOISE * jax.random.normal(kn, (n_clients,) + shape)
                           ).astype(jnp.float32))
    return leaves


def _sync_mse(ts: TrainStepConfig, n_clients: int, rounds: int) -> tuple[float, float]:
    """(final-round MSE vs the exact client mean, us_per_call).

    Both codecs run with error feedback over a few rounds — PowerSGD's
    operating point (the warm-started Q rides the EF state's aux tail),
    and EF helps the quantizer symmetrically, so the comparison stays fair.
    """
    sizes = [int(jnp.zeros(s).size) for s in LEAF_SHAPES]
    bp = plan_buckets(sizes, ts.bucket_elements)
    st_sizes = sc.bucket_state_sizes(ts.compressor, bp.sizes, ts.bits_plan)
    ef = [jnp.zeros((n_clients, s), jnp.float32) for s in st_sizes]
    fn = jax.jit(lambda ls, e, k: reference_sync_state(
        ts, list(ls), (n_clients,), k, ef=list(e))[:2])
    sig_key = jax.random.key(11)
    mse = us = 0.0
    for t in range(rounds):
        leaves = _client_grads(sig_key, jax.random.fold_in(jax.random.key(17), t),
                               n_clients)
        key = jax.random.fold_in(jax.random.key(0x10), t)
        got, ef = fn(tuple(leaves), tuple(ef), key)
        if t == rounds - 1:
            us = time_us(fn, tuple(leaves), tuple(ef), key, repeats=3, warmup=1)
            exact = [jnp.mean(g, axis=0) for g in leaves]
            num = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(got, exact))
            mse = num / sum(b.size for b in exact)
    return mse, us


def main(quick: bool = True) -> list[str]:
    n_clients = 4 if quick else 8
    rounds = 4 if quick else 8
    sizes = [int(jnp.zeros(s).size) for s in LEAF_SHAPES]
    ts0 = TrainStepConfig(sync="faithful", bucket_mb=BUCKET_MB, error_feedback=True,
                          compressor=CompressorConfig(method="tnqsgd", bits=3))
    bp = plan_buckets(sizes, ts0.bucket_elements)
    q_wire = int(sum(wire_bytes(ts0.compressor, m) for m in bp.sizes))

    # mixed per-bucket plan: each matrix-shaped bucket gets the largest
    # rank whose factor wire stays under that bucket's 3-bit quantizer
    # wire; non-matrix buckets keep the 3-bit codebook.  The comparison is
    # therefore bytes-for-bytes per bucket, in the quantizer's favor.
    entries = []
    for m in bp.sizes:
        rows_m, cols_m = matrix_shape(m)
        best = None
        for r in (1, 2, 4, 8):
            cfg_r = CompressorConfig(method="powersgd", rank=r)
            if (r <= min(rows_m, cols_m)
                    and wire_bytes(cfg_r, m) <= wire_bytes(ts0.compressor, m)):
                best = r
        entries.append(("powersgd", best) if best else 3)
    ts_p = TrainStepConfig(sync="faithful", bucket_mb=BUCKET_MB, error_feedback=True,
                           bits_plan=tuple(entries),
                           compressor=CompressorConfig(method="tnqsgd", bits=3))
    p_wire = int(sum(wire_bytes(ts0.compressor, m, e)
                     for m, e in zip(bp.sizes, entries)))

    mse_q, us_q = _sync_mse(ts0, n_clients, rounds)
    mse_p, us_p = _sync_mse(ts_p, n_clients, rounds)

    plan_str = "|".join("psgd_r%d" % e[1] if isinstance(e, tuple) else "b%d" % e
                        for e in entries)
    rows = [
        f"lowrank,bucket_mix,0,{'x'.join(str(m) for m in bp.sizes)}",
        f"lowrank,matrix_shape_b0,0,{'x'.join(map(str, matrix_shape(bp.sizes[0])))}",
        f"lowrank,mixed_plan,0,{plan_str}",
        f"lowrank,wire_bytes_tnqsgd_3bit,0,{q_wire}",
        f"lowrank,wire_bytes_mixed_plan,0,{p_wire}",
        f"lowrank,sync_mse_tnqsgd_3bit,{us_q:.0f},{mse_q:.3e}",
        f"lowrank,sync_mse_powersgd_mixed,{us_p:.0f},{mse_p:.3e}",
        f"lowrank,mse_ratio_quant_over_powersgd,0,{mse_q / mse_p:.2f}",
    ]
    # guards: the rank search honored the per-bucket wire budget, at least
    # one bucket actually went low-rank, and on this low-rank-dominant mix
    # the factor codec beats the equal-wire quantizer
    assert p_wire <= q_wire, (p_wire, q_wire)
    assert any(isinstance(e, tuple) for e in entries), entries
    assert mse_p > 0.0 and mse_q > 0.0, (mse_p, mse_q)
    assert mse_p < mse_q, (mse_p, mse_q)
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
