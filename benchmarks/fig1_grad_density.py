"""Fig. 1 reproduction: gradient density vs Gaussian / Laplace / power-law.

Trains the small conv net briefly, collects per-element gradients, fits all
three models, and reports tail negative-log-likelihoods — the paper's claim
is that Gaussian/Laplace tails are far too thin and a power law fits.
Outputs CSV rows: fig1,<group>,<model>,<tail NLL per element>.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributions import fit_power_law_tail
from repro.data.synthetic import client_batches, make_templates
from repro.models.smallnet import grad_groups, init_smallnet, smallnet_loss
from repro.optim.optimizers import momentum_sgd


def collect_gradients(rounds: int = 20, n_clients: int = 8, batch: int = 32):
    templates = make_templates(jax.random.key(42))
    params = init_smallnet(jax.random.key(0))
    opt = momentum_sgd(lr=0.01)
    state = opt.init(params)

    @jax.jit
    def step(p, s, i):
        imgs, labels = client_batches(templates, i, n_clients, batch)
        loss, g = jax.value_and_grad(smallnet_loss)(p, imgs.reshape(-1, 28, 28, 1), labels.reshape(-1))
        p, s = opt.update(p, g, s, i)
        return p, s, g

    p, s = params, state
    g = None
    for i in range(rounds):
        p, s, g = step(p, s, jnp.uint32(i))
    return g


def tail_nll(x: np.ndarray, q: float = 0.9) -> dict:
    """NLL of |x| beyond its q-quantile under each fitted model (per element)."""
    ax = np.abs(x)
    gmin = np.quantile(ax, q)
    tail = ax[ax > gmin]
    out = {}
    # Gaussian fitted on all of x: tail density 2*N(t;0,sigma)
    sigma = x.std()
    out["gaussian"] = float(np.mean(0.5 * (tail / sigma) ** 2 + np.log(sigma) + 0.5 * np.log(2 * np.pi) - np.log(2)))
    # Laplace with matched variance (paper Fig. 1 caption)
    b = x.std() / np.sqrt(2)
    out["laplace"] = float(np.mean(tail / b + np.log(2 * b) - np.log(2)))
    # Power law (conditional on exceeding gmin): (gamma-1)/gmin * (t/gmin)^-gamma
    fit = fit_power_law_tail(jnp.asarray(x), gmin_quantile=q)
    gamma = float(fit.gamma)
    out["powerlaw"] = float(np.mean(gamma * np.log(tail / gmin) - np.log((gamma - 1) / gmin)))
    out["gamma_hat"] = gamma
    return out


def main(quick: bool = False):
    rows = []
    g = collect_gradients(rounds=8 if quick else 20)
    for group, tensors in grad_groups(g).items():
        x = np.concatenate([np.asarray(t).ravel() for t in tensors])
        nll = tail_nll(x)
        for model in ("gaussian", "laplace", "powerlaw"):
            rows.append(f"fig1_grad_density,{group}/{model},0,{nll[model]:.4f}")
        rows.append(f"fig1_grad_density,{group}/gamma_hat,0,{nll['gamma_hat']:.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
