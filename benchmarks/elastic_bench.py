"""Elastic partial-participation suite: schedule statistics, pro-rata wire
accounting, the fp16 small-bucket tier, and the three scripted chaos traces
(flap / partition / solo-survivor) replayed through the reference codec with
error feedback.  CSV rows: ``elastic,<case>,0,<derived>``.

The derived values feed the elastic guards in ``benchmarks.check_bench``:

- ``*live_fraction*`` cases must land in [0, 1];
- ``wire_live_<k>of<n>_ratio`` must equal k/n exactly (the wire accounting
  is pro-rata in the live count — dead peers' zeroed rows compress away);
- ``ef_backlog_drain_ratio`` must be < 1.0 — a rejoining peer's stale-EF
  backlog shrinks once it participates again;
- ``dead_peer_oracle_maxdiff`` must be <= 1e-5 — perturbing a dead peer's
  gradient cannot move the synced mean (its wire is masked to zero and an
  all-zero wire decodes to exactly zero).
"""
from __future__ import annotations

import numpy as np

from repro.core.compressors import CompressorConfig
from repro.dist.collectives import wire_bytes_per_device


def _schedule_rows(quick: bool) -> list[str]:
    from repro.elastic import ElasticConfig, expected_live_fraction

    rows = []
    window = 100 if quick else 1000
    for rate in (0.1, 0.25, 0.5):
        cfg = ElasticConfig(rate=rate, seed=0xBE7)
        frac = expected_live_fraction(cfg, 16, 0, window)
        rows.append(f"elastic,schedule_live_fraction_rate{int(rate * 100)},0,{frac:.4f}")
        # the counter hash realizes the configured rate to a few percent
        assert abs(frac - (1.0 - rate)) < 0.08, (rate, frac)
    return rows


def _wire_rows() -> list[str]:
    rows = []
    n, shards = 1_000_000, 16
    cfg = CompressorConfig(method="tnqsgd", bits=3)
    full = wire_bytes_per_device(cfg, n, shards, "faithful")
    for k in (1, 8, 15):
        b = wire_bytes_per_device(cfg, n, shards, "faithful", live=k)
        rows.append(f"elastic,wire_live_{k}of{shards}_ratio,0,{b / full:.6f}")
        assert abs(b / full - k / shards) < 1e-9, (k, b, full)
    # the size-adaptive fp16 tier: 2 bytes/element on the wire vs fp32 dsgd
    fp16 = wire_bytes_per_device(CompressorConfig(method="fp16"), n, shards, "faithful")
    fp32 = wire_bytes_per_device(CompressorConfig(method="dsgd"), n, shards, "dsgd")
    rows.append(f"elastic,fp16_tier_vs_fp32_wire,0,{fp32 / fp16:.2f}")
    return rows


def _chaos_rows(quick: bool) -> list[str]:
    """Replay the three scripted traces through the reference codec with EF.

    Constant per-peer gradients make the stale-EF contract measurable in a
    handful of steps: a dark peer's residual row accumulates one full
    gradient per missed step, then drains once the trace brings it back.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.compressors import plan_buckets
    from repro.dist import sharded_codec as sc
    from repro.dist.reference import reference_sync_state
    from repro.dist.train_step import TrainStepConfig
    from repro.elastic import flap, live_mask, partition, solo_survivor

    del quick  # the replays are a few host-mesh-free steps either way
    n = 4
    shapes = [(2048,), (257,)]
    ts = TrainStepConfig(sync="faithful", bucket_mb=1.0 / 64.0,
                         error_feedback=True,
                         compressor=CompressorConfig(method="tnqsgd", bits=3))
    key0 = jax.random.key(0xC4A5)
    leaves = [
        (jax.random.normal(jax.random.fold_in(key0, i), (n,) + s) * 0.05
         ).astype(jnp.float32)
        for i, s in enumerate(shapes)
    ]
    bp = plan_buckets([int(np.prod(s)) for s in shapes], ts.bucket_elements)
    st = sc.bucket_state_sizes(ts.compressor, bp.sizes, ts.bits_plan)

    rows = []
    dark_steps, up_steps = 3, 2
    traces = {
        "flap": flap(n, peer=1, period=2),
        "partition": partition(n, down=(0,), down_steps=dark_steps,
                               up_steps=up_steps),
        "solo_survivor": solo_survivor(n, survivor=2, steps=2),
    }
    for name, trace in traces.items():
        cfg_el = trace.elastic()
        ef = [jnp.zeros((n, m), jnp.float32) for m in st]
        fracs, backlog, drained = [], None, None
        for step in range(trace.n_steps):
            lv = live_mask(cfg_el, step, n)
            fracs.append(float(np.asarray(lv).mean()))
            _, ef, _, _ = reference_sync_state(
                ts, leaves, (n,), jax.random.fold_in(key0, 100 + step),
                ef=ef, live=lv)
            if name == "partition" and step == dark_steps - 1:
                backlog = [float(jnp.linalg.norm(e[0])) for e in ef]
        if name == "partition":
            drained = [float(jnp.linalg.norm(e[0])) for e in ef]
        rows.append(f"elastic,chaos_{name}_live_fraction,0,{np.mean(fracs):.4f}")
        if name == "partition":
            ratio = max(d / max(b, 1e-12) for d, b in zip(drained, backlog))
            rows.append(f"elastic,ef_backlog_drain_ratio,0,{ratio:.4f}")
            assert ratio < 1.0, (drained, backlog)

    # dead-peer invariance oracle: under the solo-survivor mask, scaling the
    # three dead peers' gradients must leave the synced means bit-identical
    lv = jnp.asarray(solo_survivor(n, survivor=2).rows[0], jnp.float32)
    ef = [jnp.zeros((n, m), jnp.float32) for m in st]
    key = jax.random.fold_in(key0, 999)
    means, _, _, _ = reference_sync_state(ts, leaves, (n,), key, ef=ef, live=lv)
    poked = [l.at[0].mul(-5.0).at[1].mul(3.0).at[3].mul(-0.5) for l in leaves]
    means2, _, _, _ = reference_sync_state(ts, poked, (n,), key, ef=ef, live=lv)
    diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(means, means2))
    rows.append(f"elastic,dead_peer_oracle_maxdiff,0,{diff:.2e}")
    assert diff == 0.0, diff
    return rows


def main(quick: bool = False):
    rows = []
    rows.extend(_schedule_rows(quick))
    rows.extend(_wire_rows())
    rows.extend(_chaos_rows(quick))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
