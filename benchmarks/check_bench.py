"""Validate BENCH_*.json artifacts: schema + fused-beats-unfused guards.

CI runs this right after each benchmark upload so a malformed artifact or a
perf regression that slips past the in-suite asserts (e.g. a suite edited to
stop asserting, or an artifact truncated mid-write) fails the job instead of
silently archiving garbage.

Schema: ``{"mode": "quick"|"full", "suites": {name: {"us_total": number,
"cases": {case: {"us_per_call": number|null, "derived"?: number|str}}}}}``.

Guards (keyed on the repo's case-naming conventions):

- ratio cases (``*fused_vs_unfused*``, ``*fused_vs_seed*``,
  ``mse_ratio_quant_over_powersgd``): derived ratio >= 1.0 — the fused
  kernel / low-rank codec is no worse than its baseline on the modeled
  metric.
- modeled-bytes pairs (``..fused..`` with a ``..seed..`` / ``..unfused..``
  counterpart): fused derived <= counterpart derived.
- equal-results contracts (``*fused*maxdiff`` / ``*oracle*maxdiff``):
  derived <= 1e-5 (float32-ulp scale; quantization-error maxdiffs such as
  ``bucket_vs_leaf_maxdiff`` are intentionally not held to this).
- wall-time pair ``fused_encode_pipeline_N`` vs ``seed_encode_pipeline_N``:
  us_per_call(fused) <= 1.5x us_per_call(seed) (slack for CI timer noise;
  the in-suite assert is the tight 1.1x check).
- lowrank wire parity: ``wire_bytes_mixed_plan`` <=
  ``wire_bytes_tnqsgd_3bit`` — the rank search honored the byte budget.
- elastic schedule: ``*live_fraction*`` cases are fractions in [0, 1].
- elastic wire pro-rata: ``wire_live_<k>of<n>_ratio`` == k/n (to 1e-6) —
  dead peers' zeroed wire rows cost nothing on the modeled interconnect.
- elastic recovery: ``ef_backlog_drain_ratio`` < 1.0 — a rejoining peer's
  stale-EF backlog shrinks once it participates again.  (The dead-peer
  invariance case ``dead_peer_oracle_maxdiff`` rides the existing
  equal-results maxdiff guard.)

Usage: ``python -m benchmarks.check_bench BENCH_core.json [more.json ...]``
(also runs as a script).  Exits non-zero listing every violation.
"""
from __future__ import annotations

import json
import pathlib
import re
import sys

_RATIO_RE = re.compile(r"fused_vs_(unfused|seed)|mse_ratio_quant_over_powersgd")
_MAXDIFF_RE = re.compile(r"(fused|oracle).*maxdiff|maxdiff.*(fused|oracle)")
_MAXDIFF_TOL = 1e-5
_PIPELINE_SLACK = 1.5
_LIVE_FRAC_RE = re.compile(r"live_fraction")
_WIRE_LIVE_RE = re.compile(r"wire_live_(\d+)of(\d+)_ratio")
_WIRE_LIVE_TOL = 1e-6


def _is_num(x) -> bool:
    return isinstance(x, int | float) and not isinstance(x, bool)


def check_schema(report, errors: list[str]) -> int:
    """Structural checks; returns the number of checks performed."""
    n = 0

    def req(cond: bool, msg: str) -> None:
        nonlocal n
        n += 1
        if not cond:
            errors.append(msg)

    req(isinstance(report, dict), "top level is not an object")
    if not isinstance(report, dict):
        return n
    req(report.get("mode") in ("quick", "full"),
        f"mode must be 'quick' or 'full', got {report.get('mode')!r}")
    suites = report.get("suites")
    req(isinstance(suites, dict) and suites, "suites must be a non-empty object")
    for sname, suite in (suites or {}).items() if isinstance(suites, dict) else ():
        req(isinstance(suite, dict), f"suite {sname!r} is not an object")
        if not isinstance(suite, dict):
            continue
        req(_is_num(suite.get("us_total")) and suite["us_total"] >= 0,
            f"suite {sname!r}: us_total must be a non-negative number")
        cases = suite.get("cases")
        req(isinstance(cases, dict) and cases,
            f"suite {sname!r}: cases must be a non-empty object")
        for cname, case in (cases or {}).items() if isinstance(cases, dict) else ():
            req(isinstance(case, dict) and ("us_per_call" in case),
                f"case {sname}/{cname}: missing us_per_call")
            if isinstance(case, dict):
                us = case.get("us_per_call")
                req(us is None or (_is_num(us) and us >= 0),
                    f"case {sname}/{cname}: us_per_call must be null or >= 0")
    return n


def check_guards(report, errors: list[str]) -> int:
    """Perf/contract guards over case derived values; returns #guards run."""
    n = 0
    for sname, suite in report.get("suites", {}).items():
        cases = suite.get("cases", {}) if isinstance(suite, dict) else {}
        derived = {c: v.get("derived") for c, v in cases.items()
                   if isinstance(v, dict)}
        us = {c: v.get("us_per_call") for c, v in cases.items()
              if isinstance(v, dict)}
        for cname, d in derived.items():
            if _RATIO_RE.search(cname):
                n += 1
                if not (_is_num(d) and d >= 1.0):
                    errors.append(f"{sname}/{cname}: fused/low-rank ratio "
                                  f"{d!r} < 1.0 — baseline beat the optimized path")
            if _MAXDIFF_RE.search(cname):
                n += 1
                if not (_is_num(d) and d <= _MAXDIFF_TOL):
                    errors.append(f"{sname}/{cname}: equal-results maxdiff "
                                  f"{d!r} exceeds {_MAXDIFF_TOL}")
            if _LIVE_FRAC_RE.search(cname):
                n += 1
                if not (_is_num(d) and 0.0 <= d <= 1.0):
                    errors.append(f"{sname}/{cname}: live fraction {d!r} "
                                  f"outside [0, 1]")
            m_wl = _WIRE_LIVE_RE.fullmatch(cname)
            if m_wl:
                n += 1
                want = int(m_wl.group(1)) / int(m_wl.group(2))
                if not (_is_num(d) and abs(d - want) <= _WIRE_LIVE_TOL):
                    errors.append(f"{sname}/{cname}: pro-rata wire ratio "
                                  f"{d!r} != {want} — dead peers' wire is "
                                  f"being billed")
            if cname == "ef_backlog_drain_ratio":
                n += 1
                if not (_is_num(d) and d < 1.0):
                    errors.append(f"{sname}/{cname}: drain ratio {d!r} >= "
                                  f"1.0 — the stale-EF backlog did not "
                                  f"shrink on rejoin")
            # modeled-bytes pair: a "fused" case whose seed/unfused twin exists
            if "fused" in cname and "unfused" not in cname and "_vs_" not in cname:
                for alt in ("unfused", "seed"):
                    twin = derived.get(cname.replace("fused", alt))
                    if _is_num(d) and _is_num(twin):
                        n += 1
                        if d > twin:
                            errors.append(
                                f"{sname}/{cname}: fused modeled metric {d} > "
                                f"{alt} counterpart {twin}")
        for cname, t in us.items():
            m = re.fullmatch(r"fused_encode_pipeline_(\d+)", cname)
            if m:
                seed_t = us.get(f"seed_encode_pipeline_{m.group(1)}")
                if _is_num(t) and _is_num(seed_t) and seed_t > 0:
                    n += 1
                    if t > _PIPELINE_SLACK * seed_t:
                        errors.append(
                            f"{sname}/{cname}: fused pipeline {t}us > "
                            f"{_PIPELINE_SLACK}x seed pipeline {seed_t}us")
        if _is_num(derived.get("wire_bytes_mixed_plan")) and \
                _is_num(derived.get("wire_bytes_tnqsgd_3bit")):
            n += 1
            if derived["wire_bytes_mixed_plan"] > derived["wire_bytes_tnqsgd_3bit"]:
                errors.append(f"{sname}: mixed-plan wire "
                              f"{derived['wire_bytes_mixed_plan']} exceeds the "
                              f"quantizer budget {derived['wire_bytes_tnqsgd_3bit']}")
    return n


def check_file(path: pathlib.Path) -> list[str]:
    errors: list[str] = []
    try:
        report = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable: {e}"]
    n_schema = check_schema(report, errors)
    n_guards = check_guards(report, errors) if not errors else 0
    if not errors:
        print(f"{path}: OK ({n_schema} schema checks, {n_guards} guards)")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: check_bench.py BENCH_*.json [...]", file=sys.stderr)
        return 2
    failed = False
    for arg in argv:
        for msg in check_file(pathlib.Path(arg)):
            failed = True
            print(f"{arg}: FAIL: {msg}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
