"""Adaptive wire-budget allocation vs fixed uniform bits, at equal bytes.

Two demonstrations, reported as CSV rows (``adaptive,<case>,0,<derived>``):

1. **Sync error at equal wire bytes** (in-process): heterogeneous synthetic
   buckets (mixed tail indices γ, scales, masses) are quantized (a) with the
   fixed uniform 3-bit plan and (b) with the bit plan the controller
   water-fills from *telemetry-estimated* tails under the fixed plan's byte
   budget.  The adaptive plan must spend no more bytes and achieve a lower
   mean-squared error.
2. **Collective counts** (subprocess, 4 fake devices): tracing the bucketed
   sync with a heterogeneous bit plan *and* telemetry threading must issue
   exactly the PR 2 collective counts — 1 for faithful, 2 for two_phase,
   3 for hierarchical — telemetry adds zero collectives.
"""
from __future__ import annotations

import dataclasses
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.adaptive import telemetry as T
from repro.adaptive.controller import allocate_bits
from repro.core import sample_power_law
from repro.core.compressors import CompressorConfig, compress_decompress, wire_bytes

ROOT = pathlib.Path(__file__).resolve().parents[1]

# (size_exp, gamma, g_min, rho): heavy + thin tails across scales, the
# regime where a uniform bit width provably misallocates resolution.
SPECS = [
    (17, 3.2, 0.02, 0.20),
    (17, 5.0, 0.001, 0.05),
    (16, 3.6, 0.01, 0.15),
    (16, 4.8, 0.002, 0.05),
]

_COUNT_DEMO = """
import jax, jax.numpy as jnp
from jax.sharding import AxisType
from repro.analysis.jaxpr_lint import check_budget, count_collectives
from repro.configs import get_config, reduced
from repro.core.codecs import get_codec
from repro.core.compressors import CompressorConfig
from repro.dist import sharding
from repro.dist.train_step import (TrainStepConfig, _make_sync_fn, init_telemetry_state,
                                   local_bucket_sizes)
from repro.adaptive.controller import AdaptiveConfig
from repro.models import init_lm

cfg = reduced(get_config("llama3.2-1b")).replace(fsdp=False)
params0, logical = init_lm(jax.random.key(0), cfg)
key = jax.random.key(3)
for sync, axes in [("faithful", ("data",)), ("two_phase", ("data",)),
                   ("hierarchical", ("pod", "data"))]:
    shape = (4,) if len(axes) == 1 else (2, 2)
    mesh = jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    pspecs = sharding.param_pspecs(logical, mesh, False, params0)
    ts0 = TrainStepConfig(sync=sync, compressor=CompressorConfig(method="tqsgd", bits=3,
                                                                 approx_gmin=True),
                          bucket_mb=1.0, adaptive=AdaptiveConfig())
    nb = len(local_bucket_sizes(params0, mesh, pspecs, ts0))
    bits = tuple(2 + (i % 3) for i in range(nb))          # heterogeneous plan
    ts = TrainStepConfig(sync=sync, compressor=ts0.compressor, bucket_mb=1.0,
                         adaptive=AdaptiveConfig(), bits_plan=bits)
    grads = jax.tree.map(lambda x: jnp.zeros((4,) + x.shape, jnp.float32), params0)
    grads_like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
    tstate = init_telemetry_state(params0, mesh, pspecs, ts)
    jfn = jax.jit(_make_sync_fn(ts, mesh, pspecs, grads_like))
    closed = jfn.trace(grads, key, tstate).jaxpr
    # the registry-declared budget is the want: 1/2/3 for faithful/
    # two_phase/hierarchical, telemetry + heterogeneous bits add nothing
    budget = get_codec("tqsgd").collective_budget(sync, nb)
    n = sum(count_collectives(closed).values())
    print(f"adaptive,{sync}_hetero_n_collectives,0,{n}")
    assert not check_budget(closed, budget, sync), (sync, n, budget)
    assert n == budget, (sync, n, budget)
print("adaptive,collectives_unchanged,0,OK")
"""


def _count_rows() -> list[str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_COUNT_DEMO)],
                       capture_output=True, text=True, timeout=1200, env=env)
    if r.returncode != 0:  # pragma: no cover - surfaced as a bench row
        tail = (r.stderr.strip().splitlines() or ["?"])[-1][:80]
        return [f"adaptive,collectives_demo_error,0,{tail}"]
    return [line for line in r.stdout.splitlines() if line.startswith("adaptive,")]


def main(quick: bool = False):
    rows = []
    shrink = 2 if quick else 0
    keys = jax.random.split(jax.random.key(0), len(SPECS))
    buckets = [sample_power_law(k, (1 << (e - shrink),), gamma=ga, g_min=gm, rho=r)
               for k, (e, ga, gm, r) in zip(keys, SPECS)]
    sizes = [b.size for b in buckets]

    st = T.init_telemetry(len(buckets))
    for _ in range(3):
        st = T.update_telemetry(st, buckets, decay=0.9)
    tails = T.estimate_tails(st)
    for b, (_, ga, _, _) in enumerate(SPECS):
        rows.append(f"adaptive,telemetry_gamma_b{b},0,"
                    f"{float(tails.gamma[b]):.2f}(true {ga})")

    ccfg = CompressorConfig(method="tqsgd", bits=3)
    budget = wire_bytes(ccfg, sizes)
    plan = allocate_bits(tails, sizes, budget, ccfg)
    rows.append(f"adaptive,bits_plan,0,{'/'.join(map(str, plan.bits))}")
    rows.append(f"adaptive,wire_bytes_fixed3,0,{budget}")
    rows.append(f"adaptive,wire_bytes_adaptive,0,{plan.spend_bytes}")
    assert plan.spend_bytes <= budget, (plan.spend_bytes, budget)

    def total_mse(bits_list):
        tot, n = 0.0, 0
        for b, (g, k) in enumerate(zip(buckets, bits_list)):
            c = compress_decompress(dataclasses.replace(ccfg, bits=k), g,
                                    jax.random.fold_in(jax.random.key(9), b))
            tot += float(jnp.sum((c - g) ** 2))
            n += g.size
        return tot / n

    mse_fixed = total_mse([ccfg.bits] * len(buckets))
    mse_adapt = total_mse(plan.bits)
    rows.append(f"adaptive,mse_fixed3,0,{mse_fixed:.4e}")
    rows.append(f"adaptive,mse_adaptive,0,{mse_adapt:.4e}")
    rows.append(f"adaptive,mse_ratio_fixed_over_adaptive,0,{mse_fixed / mse_adapt:.3f}")
    # the acceptance property: lower error at no more wire bytes
    assert mse_adapt < mse_fixed, (mse_adapt, mse_fixed)
    rows.append("adaptive,beats_fixed_at_equal_bytes,0,OK")

    rows.extend(_count_rows())
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
