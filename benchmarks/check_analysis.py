"""Validate the ANALYSIS.json artifact ``python -m repro.analysis`` writes.

CI runs this right after the analyzer so a malformed or internally
inconsistent report (truncated mid-write, a pass silently skipped, findings
that disagree with the ``clean`` flag or the VMEM table) fails the
``static-analysis`` job instead of archiving garbage.

Schema: ``{"version": 1, "passes": {"jaxpr": {"traces": N, "per_trace":
{label: {"collectives": {prim: n}, "budget"?: N, "n_buckets"?: N}}, "ast":
{"files": N}, "vmem": {"kernels": N, "table": [...]}}, "findings":
[{"code": "REPROxxx", "where": str, "message": str}], "clean": bool}``.

Guards:

- ``clean`` is exactly ``findings == []``;
- every budgeted trace row satisfies ``sum(collectives) <= budget`` unless
  a matching REPRO101 finding reports the excess;
- every VMEM table row satisfies ``vmem_bytes <= budget_bytes`` unless a
  matching REPRO301 finding reports the excess;
- all three passes ran (``--pass``-restricted local runs are fine, but the
  CI artifact must cover the full surface).

Usage: ``python -m benchmarks.check_analysis ANALYSIS.json``.  Exits
non-zero listing every violation.
"""
from __future__ import annotations

import json
import pathlib
import re
import sys

_CODE_RE = re.compile(r"REPRO\d{3}\Z")
_REQUIRED_PASSES = ("jaxpr", "ast", "vmem")


def _is_count(x) -> bool:
    return isinstance(x, int) and not isinstance(x, bool) and x >= 0


def check_schema(report, errors: list[str]) -> int:
    """Structural checks; returns the number of checks performed."""
    n = 0

    def req(cond: bool, msg: str) -> None:
        nonlocal n
        n += 1
        if not cond:
            errors.append(msg)

    req(isinstance(report, dict), "top level is not an object")
    if not isinstance(report, dict):
        return n
    req(report.get("version") == 1, f"version must be 1, got {report.get('version')!r}")
    req(isinstance(report.get("clean"), bool), "clean must be a bool")
    findings = report.get("findings")
    req(isinstance(findings, list), "findings must be a list")
    for i, f in enumerate(findings or []):
        req(isinstance(f, dict) and _CODE_RE.match(str(f.get("code", "")))
            and isinstance(f.get("where"), str) and isinstance(f.get("message"), str),
            f"finding[{i}] must be {{code: REPROxxx, where: str, message: str}}, got {f!r}")
    passes = report.get("passes")
    req(isinstance(passes, dict), "passes must be an object")
    if not isinstance(passes, dict):
        return n
    for name in _REQUIRED_PASSES:
        req(name in passes, f"pass {name!r} missing from the report")
    jx = passes.get("jaxpr")
    if isinstance(jx, dict):
        per = jx.get("per_trace")
        req(_is_count(jx.get("traces")) and jx.get("traces", 0) >= 1,
            "jaxpr: traces must be a positive count")
        req(isinstance(per, dict) and len(per) == jx.get("traces"),
            "jaxpr: per_trace must be an object with one row per trace")
        for label, row in (per or {}).items() if isinstance(per, dict) else ():
            coll = row.get("collectives") if isinstance(row, dict) else None
            req(isinstance(coll, dict) and all(_is_count(v) for v in (coll or {}).values()),
                f"jaxpr trace {label!r}: collectives must map primitive -> count")
    ast_pass = passes.get("ast")
    if isinstance(ast_pass, dict):
        req(_is_count(ast_pass.get("files")) and ast_pass.get("files", 0) >= 1,
            "ast: files must be a positive count")
    vm = passes.get("vmem")
    if isinstance(vm, dict):
        table = vm.get("table")
        req(isinstance(table, list) and vm.get("kernels") == len(table or []),
            "vmem: kernels must equal the table length")
        for i, row in enumerate(table or []):
            req(isinstance(row, dict) and isinstance(row.get("wrapper"), str)
                and isinstance(row.get("kernel"), str)
                and _is_count(row.get("vmem_bytes"))
                and _is_count(row.get("budget_bytes")),
                f"vmem table[{i}] must carry wrapper/kernel/vmem_bytes/budget_bytes")
    return n


def check_guards(report, errors: list[str]) -> int:
    """Cross-consistency guards; returns the number of guards run."""
    n = 0
    findings = report.get("findings", [])
    codes_by_where = {(f.get("code"), f.get("where")) for f in findings
                      if isinstance(f, dict)}
    n += 1
    if report.get("clean") is not (not findings):
        errors.append(f"clean={report.get('clean')!r} disagrees with "
                      f"{len(findings)} finding(s)")
    per = report.get("passes", {}).get("jaxpr", {}).get("per_trace", {})
    for label, row in per.items() if isinstance(per, dict) else ():
        if not isinstance(row, dict) or "budget" not in row:
            continue
        n += 1
        total = sum(row.get("collectives", {}).values())
        reported = ("REPRO101", label) in codes_by_where
        if total > row["budget"] and not reported:
            errors.append(f"jaxpr trace {label!r}: {total} collectives over "
                          f"budget {row['budget']} with no REPRO101 finding")
    table = report.get("passes", {}).get("vmem", {}).get("table", [])
    for row in table if isinstance(table, list) else ():
        if not isinstance(row, dict):
            continue
        n += 1
        where = f"vmem:{row.get('wrapper')}/{row.get('kernel')}"
        reported = ("REPRO301", where) in codes_by_where
        if row.get("vmem_bytes", 0) > row.get("budget_bytes", 0) and not reported:
            errors.append(f"{where}: {row.get('vmem_bytes')} B over the "
                          f"{row.get('budget_bytes')} B budget with no "
                          "REPRO301 finding")
    return n


def check_file(path: pathlib.Path) -> list[str]:
    errors: list[str] = []
    try:
        report = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable: {e}"]
    n_schema = check_schema(report, errors)
    n_guards = check_guards(report, errors) if not errors else 0
    if not errors:
        print(f"{path}: OK ({n_schema} schema checks, {n_guards} guards)")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: check_analysis.py ANALYSIS.json [...]", file=sys.stderr)
        return 2
    failed = False
    for arg in argv:
        for msg in check_file(pathlib.Path(arg)):
            failed = True
            print(f"{arg}: FAIL: {msg}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
