"""Cross-mesh invariance suite for the compressed gradient collectives.

Parameterized sweep over mesh shapes × sync methods asserting, for every
combination, that

(a) all peers of the collective hold **bitwise-identical** synced gradients
    (the peer-agreement contract every mode promises), and
(b) the synced gradient equals the **single-device reference**
    (``repro.dist.reference``) — bit-for-bit for the codebook-method modes,
    which share every local codec helper with the mesh path, and within
    tight float tolerance for ``dsgd`` (the partitioner owns the all-reduce
    order) and the uniform-method decode (ulp-level FMA-contraction
    discretion, see ``test_decode_kernels``).

One subprocess per mesh shape (fake host devices); each subprocess sweeps
the sync modes, a uniform-codebook method, a heterogeneous per-bucket
``bits_plan``, and the per-leaf (``bucket_mb=0``) codec.  Replaces the
single-mesh spot check the old ``test_dist.py::test_sharded_codec_units``
provided.  ``REPRO_TEST_USE_PALLAS=1`` (the CI ``--interpret`` job) runs the
same sweep through the Pallas decode/encode kernels instead of the jnp
fallbacks.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]

MESHES = [
    ((1,), ("data",)),
    ((2,), ("data",)),
    ((4,), ("data",)),
    ((2, 2), ("pod", "data")),
    ((2, 2, 2), ("pod", "data", "model")),
]
MESH_IDS = ["data1", "data2", "data4", "pod2x2", "pod2x2x2"]

_SCRIPT = """
import os
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, AxisType
from repro.core.compressors import CompressorConfig, plan_buckets
from repro.adaptive.controller import AdaptiveConfig
from repro.adaptive.telemetry import init_telemetry
from repro.dist import reference, sharded_codec as sc, sharding
from repro.dist.train_step import TrainStepConfig, _sync_buckets, _sync_leaf

MESH_SHAPE = %(shape)r
AXES = %(axes)r
USE_PALLAS = os.environ.get("REPRO_TEST_USE_PALLAS", "0") not in ("", "0")

mesh = jax.make_mesh(MESH_SHAPE, AXES, axis_types=(AxisType.Auto,) * len(AXES))
dp = sharding.manual_axes(mesh)
dp_sizes = tuple(mesh.shape[a] for a in dp)
n = 1
for s in dp_sizes:
    n *= s

# Sizes chosen so plan_buckets at bucket_mb=1/64 MB (4096 elements) coalesces
# them into three buckets of (3072, 2257, 3047) elements — mixed ragged tails.
leaf_shapes = [(64, 48), (37, 61), (2048,), (999,)]
key0 = jax.random.key(5)
leaves = [
    (jax.random.normal(jax.random.fold_in(key0, i), (n,) + s) * 0.05 * (i + 1)
     ).astype(jnp.float32)
    for i, s in enumerate(leaf_shapes)
]
skey = jax.random.key(123)
BP = plan_buckets([int(np.prod(s)) for s in leaf_shapes], 4096)
# bucket-resident EF state: one stacked (n, m_b) array per codec bucket
ef0 = [
    (jax.random.normal(jax.random.fold_in(key0, 100 + b), (n, m)) * 0.01
     ).astype(jnp.float32)
    for b, m in enumerate(BP.sizes)
]


def run_mesh(ts):
    def body(key, *stacked):
        vals = [x[0] for x in stacked]
        if ts.bucket_mb > 0:
            out, _, _, _, _ = _sync_buckets(ts, vals, key, dp)
        else:
            out = [_sync_leaf(ts, g, jax.random.fold_in(key, i), dp)
                   for i, g in enumerate(vals)]
        return tuple(o[None] for o in out)

    smap = jax.shard_map(
        body, mesh=mesh, in_specs=(P(),) + (P(dp),) * len(leaves),
        out_specs=tuple(P(dp) for _ in leaves),
        axis_names=set(mesh.axis_names), check_vma=False)
    return jax.jit(smap)(skey, *leaves)


def assert_peer_rows(name, what, leaf_i, g, w, exact):
    # (a) every peer decoded identical bytes to identical values
    for peer in range(1, n):
        np.testing.assert_array_equal(
            g[0], g[peer], err_msg=f"{name}: peer {peer} diverges on {what} {leaf_i}")
    # (b) the mesh result is the single-device reference
    if exact:
        np.testing.assert_array_equal(
            g[0], np.asarray(w), err_msg=f"{name}: reference mismatch on {what} {leaf_i}")
    else:
        np.testing.assert_allclose(
            g[0], np.asarray(w), atol=1e-6, rtol=1e-6,
            err_msg=f"{name}: reference mismatch on {what} {leaf_i}")


def check(name, ts, exact):
    got = run_mesh(ts)
    want = jax.jit(lambda key, *ls: tuple(
        reference.reference_sync(ts, list(ls), dp_sizes, key)))(skey, *leaves)
    for leaf_i, (g, w) in enumerate(zip(got, want)):
        assert_peer_rows(name, "leaf", leaf_i, np.asarray(g), w, exact)
    print("OK", name)


def check_state(name, ts, exact):
    # EF + adaptive over the bucket-resident state layout: the mesh body
    # threads the stacked EF bucket arrays and the telemetry rows exactly as
    # _make_sync_fn does; means must agree bitwise across peers, and the
    # per-peer residual/telemetry rows must equal the reference's.
    # Rank-based codec buckets carry a codec-opaque aux tail after the
    # residual (state_extra); a random non-zero tail exercises the
    # warm-started power iteration on both sides.  Quantizer buckets reuse
    # the exact ef0 arrays, keeping the pre-registry cases bit-identical.
    st_sizes = sc.bucket_state_sizes(ts.compressor, BP.sizes, ts.bits_plan)
    ef = [ef0[b] if st == BP.sizes[b] else
          (jax.random.normal(jax.random.fold_in(key0, 200 + b), (n, st)) * 0.01
           ).astype(jnp.float32)
          for b, st in enumerate(st_sizes)]
    t0 = jax.tree.map(lambda x: jnp.tile(x[None], (n,) + (1,) * x.ndim),
                      init_telemetry(BP.n_buckets))

    def body(key, tstate, *stacked_and_ef):
        stacked, ef = stacked_and_ef[:len(leaves)], stacked_and_ef[len(leaves):]
        vals = [x[0] for x in stacked]
        t_in = jax.tree.map(lambda x: x[0], tstate)
        out, resid, new_t, _, _ = _sync_buckets(ts, vals, key, dp,
                                                [e[0] for e in ef], t_in)
        return (tuple(o[None] for o in out), tuple(r[None] for r in resid),
                jax.tree.map(lambda x: x[None], new_t))

    t_spec = jax.tree.map(lambda _: P(dp), t0)
    smap = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(), t_spec) + (P(dp),) * (len(leaves) + len(ef)),
        out_specs=(tuple(P(dp) for _ in leaves), tuple(P(dp) for _ in ef), t_spec),
        axis_names=set(mesh.axis_names), check_vma=False)
    means, resids, new_t = jax.jit(smap)(skey, t0, *leaves, *ef)

    w_means, w_resids, w_t, _ = jax.jit(
        lambda key, t, ls, e: reference.reference_sync_state(
            ts, list(ls), dp_sizes, key, ef=list(e), tstate=t)
    )(skey, t0, tuple(leaves), tuple(ef))

    for leaf_i, (g, w) in enumerate(zip(means, w_means)):
        assert_peer_rows(name, "leaf", leaf_i, np.asarray(g), w, exact)
    for b, (r, w) in enumerate(zip(resids, w_resids)):
        r, w = np.asarray(r), np.asarray(w)
        if exact:
            np.testing.assert_array_equal(r, w, err_msg=f"{name}: resid bucket {b}")
        else:
            np.testing.assert_allclose(r, w, atol=1e-6, rtol=1e-6,
                                       err_msg=f"{name}: resid bucket {b}")
    for got_leaf, want_leaf in zip(jax.tree.leaves(new_t), jax.tree.leaves(w_t)):
        np.testing.assert_allclose(np.asarray(got_leaf), np.asarray(want_leaf),
                                   atol=1e-5, rtol=1e-5,
                                   err_msg=f"{name}: telemetry rows diverge")
    print("OK", name)


def ts_for(sync, method="tnqsgd", bits=3, bucket_mb=1.0 / 64.0, bits_plan=None,
           rank=4, **kw):
    return TrainStepConfig(
        sync=sync, bucket_mb=bucket_mb, bits_plan=bits_plan,
        compressor=CompressorConfig(method=method, bits=bits, rank=rank,
                                    use_pallas=USE_PALLAS),
        **kw)


# Every mesh runs the four sync modes; the auxiliary surfaces (uniform-
# codebook decode, heterogeneous bits_plan, per-leaf codec) get their full
# sweep on the cheap 2-peer mesh and one targeted case elsewhere, keeping the
# per-mesh subprocess inside the tier-1 budget.
FULL = (n == 2)

for sync in ("dsgd", "two_phase", "hierarchical", "faithful"):
    check(f"bucketed/{sync}/tnqsgd", ts_for(sync), exact=sync != "dsgd")

# uniform-codebook decode branch (alpha-formula dequant; near-exact — the
# dequant multiply-add's FMA contraction is compiler-discretionary between
# the mesh and reference graphs, see tests/test_decode_kernels.py)
for sync in ("two_phase", "faithful") if FULL or n == 4 and len(dp_sizes) == 1 else ():
    check(f"bucketed/{sync}/tqsgd", ts_for(sync, method="tqsgd", bits=4), exact=False)

# heterogeneous per-bucket wire widths through the fused decode path
het = ("two_phase", "hierarchical", "faithful") if FULL else (
    ("hierarchical",) if len(dp_sizes) > 1 else ())
for sync in het:
    check(f"bucketed/{sync}/bits_plan", ts_for(sync, bits_plan=(2, 4, 3)), exact=True)

# per-leaf codec (bucket_mb=0): ring-mean / all-gather decode sites
per_leaf = ("two_phase", "hierarchical", "faithful") if FULL else (
    ("hierarchical",) if len(dp_sizes) > 1 and MESH_SHAPE[-1] > 1 else
    ("faithful",) if n == 1 else ())
for sync in per_leaf:
    check(f"per_leaf/{sync}/tnqsgd", ts_for(sync, bucket_mb=0.0), exact=True)

# EF + adaptive over the bucket-resident state layout (residual + telemetry
# ride the sync exactly as in _make_sync_fn); full sweep on the cheap 2-peer
# mesh, one hierarchical case on the pod meshes.
ef_sweep = ("faithful", "two_phase") if FULL else (
    ("hierarchical",) if len(dp_sizes) > 1 else ())
for sync in ef_sweep:
    acfg = AdaptiveConfig(ema=0.9)
    check_state(f"bucketed_state/{sync}/tnqsgd",
                ts_for(sync, error_feedback=True, adaptive=acfg), exact=True)
if FULL:
    check_state("bucketed_state/faithful/bits_plan",
                ts_for("faithful", bits_plan=(2, 4, 3), error_feedback=True,
                       adaptive=AdaptiveConfig(ema=0.9)), exact=True)

# powersgd through the registry: the non-chunkable wire rides every sync
# mode (two_phase tiles the full factor pair into each all-to-all row).
# Peer agreement stays bitwise (assert_peer_rows part (a)); the reference
# comparison is allclose — the factor matmuls' FMA contraction is
# compiler-discretionary between the mesh and reference graphs.
psgd = ("two_phase", "faithful") if FULL else (
    ("hierarchical",) if len(dp_sizes) > 1 else ())
for sync in psgd:
    check(f"bucketed/{sync}/powersgd", ts_for(sync, method="powersgd", rank=4),
          exact=False)
# mixed per-bucket method plan: quantized buckets next to a low-rank one
# in the same fused wire (plan entries resolve through the codec registry)
mixed_plan = (3, ("powersgd", 4), 2)
mixed = ("faithful", "two_phase") if FULL else (
    ("hierarchical",) if len(dp_sizes) > 1 and MESH_SHAPE[-1] > 1 else ())
for sync in mixed:
    check(f"bucketed/{sync}/mixed_plan", ts_for(sync, bits_plan=mixed_plan),
          exact=False)
# EF + adaptive with the rank-based codec: the state rows grow the
# codec-opaque aux tail (warm-started Q), threaded by _sync_buckets.
if FULL:
    check_state("bucketed_state/faithful/powersgd",
                ts_for("faithful", method="powersgd", error_feedback=True,
                       adaptive=AdaptiveConfig(ema=0.9)), exact=False)
    check_state("bucketed_state/two_phase/mixed_plan",
                ts_for("two_phase", bits_plan=mixed_plan, error_feedback=True,
                       adaptive=AdaptiveConfig(ema=0.9)), exact=False)

# --- elastic: k-of-n live subsets must replay bit-for-bit under the same
# mask, and a dead peer's gradient must be unable to move the mean (the
# straggler contract: its encode runs, its wire contribution is zeroed).


def masks_for(n):
    ks = sorted({1, max(n // 2, 1), max(n - 1, 1)})
    out = []
    for k in ks:
        m = [1.0] * k + [0.0] * (n - k)
        out.append(tuple(m))
        if k < n:  # a non-prefix subset too — liveness is not positional
            out.append(tuple(reversed(m)))
    return sorted(set(out))


def run_mesh_live(ts, live, leaves_in):
    def body(key, lv, *stacked):
        vals = [x[0] for x in stacked]
        out, _, _, _, _ = _sync_buckets(ts, vals, key, dp, live=lv)
        return tuple(o[None] for o in out)

    smap = jax.shard_map(
        body, mesh=mesh, in_specs=(P(), P()) + (P(dp),) * len(leaves_in),
        out_specs=tuple(P(dp) for _ in leaves_in),
        axis_names=set(mesh.axis_names), check_vma=False)
    return jax.jit(smap)(skey, jnp.asarray(live, jnp.float32), *leaves_in)


def check_elastic(name, ts, live, exact):
    got = run_mesh_live(ts, live, leaves)
    want = jax.jit(lambda key, lv, *ls: tuple(
        reference.reference_sync(ts, list(ls), dp_sizes, key, live=lv)))(
        skey, jnp.asarray(live, jnp.float32), *leaves)
    for leaf_i, (g, w) in enumerate(zip(got, want)):
        assert_peer_rows(name, "leaf", leaf_i, np.asarray(g), w, exact)
    print("OK", name)


def check_state_elastic(name, ts, live, exact):
    # EF + adaptive under a live mask: dropped peers' residual rows must
    # accumulate the whole corrected bucket (stale-EF), live peers' rows
    # must match full-participation semantics — both pinned against the
    # reference replay of the same mask.
    lv = jnp.asarray(live, jnp.float32)
    st_sizes = sc.bucket_state_sizes(ts.compressor, BP.sizes, ts.bits_plan)
    ef = [ef0[b] if st == BP.sizes[b] else
          (jax.random.normal(jax.random.fold_in(key0, 200 + b), (n, st)) * 0.01
           ).astype(jnp.float32)
          for b, st in enumerate(st_sizes)]
    t0 = jax.tree.map(lambda x: jnp.tile(x[None], (n,) + (1,) * x.ndim),
                      init_telemetry(BP.n_buckets))

    def body(key, lvr, tstate, *stacked_and_ef):
        stacked, efr = stacked_and_ef[:len(leaves)], stacked_and_ef[len(leaves):]
        vals = [x[0] for x in stacked]
        t_in = jax.tree.map(lambda x: x[0], tstate)
        out, resid, new_t, _, _ = _sync_buckets(ts, vals, key, dp,
                                                [e[0] for e in efr], t_in, lvr)
        return (tuple(o[None] for o in out), tuple(r[None] for r in resid),
                jax.tree.map(lambda x: x[None], new_t))

    t_spec = jax.tree.map(lambda _: P(dp), t0)
    smap = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), t_spec) + (P(dp),) * (len(leaves) + len(ef)),
        out_specs=(tuple(P(dp) for _ in leaves), tuple(P(dp) for _ in ef), t_spec),
        axis_names=set(mesh.axis_names), check_vma=False)
    means, resids, new_t = jax.jit(smap)(skey, lv, t0, *leaves, *ef)

    w_means, w_resids, w_t, _ = jax.jit(
        lambda key, lvr, t, ls, e: reference.reference_sync_state(
            ts, list(ls), dp_sizes, key, ef=list(e), tstate=t, live=lvr)
    )(skey, lv, t0, tuple(leaves), tuple(ef))

    for leaf_i, (g, w) in enumerate(zip(means, w_means)):
        assert_peer_rows(name, "leaf", leaf_i, np.asarray(g), w, exact)
    for b, (r, w) in enumerate(zip(resids, w_resids)):
        r, w = np.asarray(r), np.asarray(w)
        if exact:
            np.testing.assert_array_equal(r, w, err_msg=f"{name}: resid bucket {b}")
        else:
            np.testing.assert_allclose(r, w, atol=1e-6, rtol=1e-6,
                                       err_msg=f"{name}: resid bucket {b}")
    print("OK", name)


# Full k-of-n sweep (k in {1, n/2, n-1}, prefix + reversed subsets) on the
# cheap 2-peer mesh and the 1-D 4-peer mesh; the pod meshes (also n=4) run
# the first two sorted masks — which include a fully-dead pod, the
# hierarchical-specific renormalization case — keeping each subprocess
# inside the tier-1 budget.
elastic_masks = masks_for(n) if (FULL or (n == 4 and len(dp_sizes) == 1)) else (
    masks_for(n)[:2] if n > 1 else [])
for sync in ("dsgd", "two_phase", "hierarchical", "faithful"):
    for mask in elastic_masks:
        k = int(sum(mask))
        check_elastic(f"elastic/{sync}/live{k}of{n}/{mask}", ts_for(sync), mask,
                      exact=sync != "dsgd")

# EF + adaptive under the mask (cheap meshes full, pods one hierarchical)
el_state = ("faithful", "two_phase") if FULL else (
    ("hierarchical",) if len(dp_sizes) > 1 else ())
for sync in el_state:
    for mask in elastic_masks[:3]:
        k = int(sum(mask))
        check_state_elastic(
            f"elastic_state/{sync}/live{k}of{n}",
            ts_for(sync, error_feedback=True, adaptive=AdaptiveConfig(ema=0.9)),
            mask, exact=True)

# the fp16 size-adaptive tier rides the same contract: the smallest bucket
# (2257 elements) ships raw half precision on both sides
if FULL:
    for sync in ("two_phase", "faithful"):
        check_elastic(f"elastic/{sync}/fp16_tier",
                      ts_for(sync, fp16_threshold=2500), (1.0, 0.0), exact=True)
        check(f"bucketed/{sync}/fp16_tier", ts_for(sync, fp16_threshold=2500),
              exact=True)

# straggler pin: perturbing a dead peer's gradient cannot move the mean —
# its encode still runs (side-effect-free), its wire row is zeroed.
if n > 1:
    mask = (1.0,) * (n - 1) + (0.0,)
    for sync in ("two_phase", "faithful"):
        ts = ts_for(sync)
        base = run_mesh_live(ts, mask, leaves)
        poked = [l.at[n - 1].mul(-3.7) for l in leaves]
        got = run_mesh_live(ts, mask, poked)
        for leaf_i, (a, b) in enumerate(zip(base, got)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"straggler/{sync}: dead peer moved the mean (leaf {leaf_i})")
        print("OK", f"straggler/{sync}")

print("ALL_OK")
"""


_COUNT_SCRIPT = """
import dataclasses
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, AxisType
from repro.analysis.jaxpr_lint import count_collectives
from repro.core.compressors import CompressorConfig
from repro.dist.train_step import TrainStepConfig, _make_sync_fn
from repro.elastic import ElasticConfig

mesh = jax.make_mesh((2, 2), ("pod", "data"),
                     axis_types=(AxisType.Auto, AxisType.Auto))
leaf_shapes = [(64, 48), (37, 61), (2048,), (999,)]
grads_like = [jax.ShapeDtypeStruct((4,) + s, jnp.float32) for s in leaf_shapes]
pspecs = [P() for _ in leaf_shapes]
key = jax.ShapeDtypeStruct((), jax.random.key(0).dtype)
live = jax.ShapeDtypeStruct((4,), jnp.float32)

for sync in ("dsgd", "two_phase", "hierarchical", "faithful"):
    base = TrainStepConfig(
        sync=sync, bucket_mb=1.0 / 64.0,
        compressor=CompressorConfig(method="tnqsgd", bits=3))
    el = dataclasses.replace(base, elastic=ElasticConfig(rate=0.3))
    fn_off = _make_sync_fn(base, mesh, pspecs, grads_like)
    fn_on = _make_sync_fn(el, mesh, pspecs, grads_like)
    c_off = count_collectives(jax.make_jaxpr(fn_off)(grads_like, key))
    c_on = count_collectives(jax.make_jaxpr(fn_on)(grads_like, key, live))
    assert c_on == c_off, (sync, dict(c_on), dict(c_off))
    print("OK", sync, dict(c_on))
print("ALL_OK")
"""


def test_elastic_keeps_collective_counts():
    """The live mask is a replicated in-graph value: enabling elastic must
    not add (or remove) a single traced collective in any sync mode."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_COUNT_SCRIPT)],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "ALL_OK" in r.stdout, r.stdout


@pytest.mark.parametrize("shape,axes", MESHES, ids=MESH_IDS)
def test_sync_matches_reference(shape, axes):
    n_dev = 1
    for s in shape:
        n_dev *= s
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_SCRIPT % {"shape": shape, "axes": axes})],
        capture_output=True, text=True, timeout=1800, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "ALL_OK" in r.stdout, r.stdout
