"""Data pipeline, optimizer, checkpoint, and config-registry tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import latest_step, load_checkpoint, save_checkpoint
from repro.configs import ARCHS, INPUT_SHAPES, get_config, get_shape, reduced, variant_for_shape
from repro.data.synthetic import client_batches, lm_batch, make_templates, shapes_batch
from repro.launch.specs import abstract_init, count_active_params, count_params
from repro.optim.optimizers import adamw, momentum_sgd


# -- configs ----------------------------------------------------------------

EXPECTED = {
    "granite-20b": dict(num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1, d_ff=24576, vocab=49152),
    "qwen2-vl-2b": dict(num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, d_ff=8960, vocab=151936),
    "llama3.2-1b": dict(num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8, d_ff=8192, vocab=128256),
    "qwen3-moe-235b-a22b": dict(num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, vocab=151936),
    "gemma-7b": dict(num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16, d_ff=24576, vocab=256000),
    "minitron-8b": dict(num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, d_ff=16384, vocab=256000),
    "whisper-base": dict(num_layers=6, d_model=512, num_heads=8, d_ff=2048, vocab=51865),
    "phi3.5-moe-42b-a6.6b": dict(num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, d_ff=6400, vocab=32064),
    "mamba2-2.7b": dict(num_layers=64, d_model=2560, vocab=50280),
    "jamba-1.5-large-398b": dict(num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, d_ff=24576, vocab=65536),
}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_config_matches_assignment(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_moe_configs():
    q = get_config("qwen3-moe-235b-a22b")
    assert q.moe.num_experts == 128 and q.moe.top_k == 8
    p = get_config("phi3.5-moe-42b-a6.6b")
    assert p.moe.num_experts == 16 and p.moe.top_k == 2
    j = get_config("jamba-1.5-large-398b")
    assert j.moe.num_experts == 16 and j.moe.top_k == 2 and j.attn_period == 8


def test_param_counts_in_ballpark():
    """Full-size configs match their nameplate scale (abstract init only)."""
    expected_b = {
        "llama3.2-1b": (1.0, 1.9),
        "qwen3-moe-235b-a22b": (180, 260),
        "phi3.5-moe-42b-a6.6b": (35, 50),
        "mamba2-2.7b": (2.2, 3.2),
        "jamba-1.5-large-398b": (330, 430),
        "gemma-7b": (7.5, 10.5),
        "minitron-8b": (7.5, 10.5),
        "granite-20b": (18, 31),
        "whisper-base": (0.05, 0.12),
        "qwen2-vl-2b": (1.2, 2.4),
    }
    for arch, (lo, hi) in expected_b.items():
        params_like, logical = abstract_init(get_config(arch))
        n = count_params(params_like) / 1e9
        assert lo <= n <= hi, (arch, n)


def test_active_params_moe():
    cfg = get_config("qwen3-moe-235b-a22b")
    pl, lg = abstract_init(cfg)
    total = count_params(pl)
    active = count_active_params(cfg, pl, lg)
    assert active < 0.25 * total  # top-8 of 128


def test_input_shapes_table():
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["decode_32k"].kind == "decode"
    assert INPUT_SHAPES["long_500k"].seq_len == 524288


def test_reduced_constraints():
    for arch in ARCHS:
        r = reduced(get_config(arch))
        assert r.d_model <= 512
        assert r.num_layers <= max(2, r.attn_period)
        if r.moe:
            assert r.moe.num_experts <= 4


# -- data -------------------------------------------------------------------


def test_lm_batch_deterministic():
    cfg = reduced(get_config("llama3.2-1b"))
    b1 = lm_batch(cfg, jnp.uint32(3), 4, 32)
    b2 = lm_batch(cfg, jnp.uint32(3), 4, 32)
    np.testing.assert_array_equal(np.asarray(b1.tokens), np.asarray(b2.tokens))
    b3 = lm_batch(cfg, jnp.uint32(4), 4, 32)
    assert not np.array_equal(np.asarray(b1.tokens), np.asarray(b3.tokens))
    assert int(b1.tokens.max()) < cfg.vocab
    # labels are next-token with last masked
    np.testing.assert_array_equal(np.asarray(b1.labels[:, :-1]), np.asarray(b1.tokens[:, 1:]))
    assert int(b1.labels[0, -1]) == -1


def test_vlm_audio_batch_extras():
    v = reduced(get_config("qwen2-vl-2b"))
    b = lm_batch(v, jnp.uint32(0), 2, 64)
    assert b.patches.shape == (2, v.vlm_patches, v.vlm_vision_dim)
    assert b.positions.shape == (3, 2, 64)
    assert bool(jnp.all(b.labels[:, : v.vlm_patches] == -1))
    a = reduced(get_config("whisper-base"))
    b = lm_batch(a, jnp.uint32(0), 2, 64)
    assert b.frames.shape == (2, a.enc_seq, a.d_model)


def test_shapes_dataset_heavy_tail():
    tmpl = make_templates(jax.random.key(0))
    imgs, labels = shapes_batch(tmpl, jnp.uint32(0), 256)
    assert imgs.shape == (256, 28, 28, 1)
    assert int(labels.max()) < 10
    ci, cl = client_batches(tmpl, jnp.uint32(0), 8, 16)
    assert ci.shape == (8, 16, 28, 28, 1)


# -- optimizers ---------------------------------------------------------------


def test_momentum_sgd_quadratic():
    opt = momentum_sgd(lr=0.05, momentum=0.9, weight_decay=0.0)
    p = {"w": jnp.array([5.0, -3.0])}
    s = opt.init(p)
    for i in range(200):
        g = jax.tree.map(lambda w: 2 * w, p)
        p, s = opt.update(p, g, s, jnp.uint32(i))
    assert float(jnp.abs(p["w"]).max()) < 1e-2


def test_adamw_quadratic():
    opt = adamw(lr=0.1, weight_decay=0.0)
    p = {"w": jnp.array([[5.0, -3.0]])}
    s = opt.init(p)
    for i in range(120):
        g = jax.tree.map(lambda w: 2 * w, p)
        p, s = opt.update(p, g, s, jnp.uint32(i))
    assert float(jnp.abs(p["w"]).max()) < 5e-2


# -- checkpoint ---------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    save_checkpoint(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    restored, step = load_checkpoint(tmp_path, tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_resume_training(tmp_path):
    """seed-addressable pipeline + checkpoint => bitwise resume."""
    cfg = reduced(get_config("llama3.2-1b"), layers=2, d_model=64, vocab=128)
    from repro.models import init_lm, loss_fn

    params, _ = init_lm(jax.random.key(0), cfg)
    opt = momentum_sgd(lr=0.05)
    state = opt.init(params)

    @jax.jit
    def step(p, s, i):
        b = lm_batch(cfg, i, 2, 32)
        loss, g = jax.value_and_grad(lambda pp: loss_fn(cfg, pp, b))(p)
        p, s = opt.update(p, g, s, i)
        return p, s, loss

    # run 4 steps straight
    p1, s1 = params, state
    for i in range(4):
        p1, s1, _ = step(p1, s1, jnp.uint32(i))
    # run 2, checkpoint, restore, run 2 more
    p2, s2 = params, state
    for i in range(2):
        p2, s2, _ = step(p2, s2, jnp.uint32(i))
    save_checkpoint(tmp_path, 2, (p2, s2))
    (p2r, s2r), st = load_checkpoint(tmp_path, (p2, s2))
    for i in range(st, 4):
        p2r, s2r, _ = step(p2r, s2r, jnp.uint32(i))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2r)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6)


# -- variant selection ---------------------------------------------------------


def test_variant_for_shape():
    for arch in ARCHS:
        cfg = get_config(arch)
        v = variant_for_shape(cfg, get_shape("long_500k"))
        if cfg.family in ("ssm", "hybrid"):
            assert v.sliding_window is None
        else:
            assert v.sliding_window == 4096
        assert variant_for_shape(cfg, get_shape("train_4k")) == cfg
