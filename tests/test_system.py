"""End-to-end behaviour tests: single-device training runs of the paper's
system (Alg. 1) — loss decreases under every compressor at b=3, and the
paper's headline ordering holds on a small real model."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.core.compressors import CompressorConfig, tree_compress_decompress
from repro.data.synthetic import lm_batch
from repro.models import init_lm, loss_fn
from repro.optim.optimizers import momentum_sgd


def _train(cfg, method, bits, steps=12, lr=0.05, n_clients=4):
    """Single-process DSGD simulation: N client grads on disjoint batches,
    compressed independently (Alg. 1), averaged, applied."""
    params, _ = init_lm(jax.random.key(0), cfg)
    opt = momentum_sgd(lr=lr)
    state = opt.init(params)
    ccfg = CompressorConfig(method=method, bits=bits)

    @jax.jit
    def step(p, s, i):
        def client_grad(c):
            b = lm_batch(cfg, i * n_clients + c, 2, 64)
            loss, g = jax.value_and_grad(lambda pp: loss_fn(cfg, pp, b))(p)
            g = tree_compress_decompress(ccfg, g, jax.random.fold_in(jax.random.key(3), i * n_clients + c))
            return loss, g

        losses, grads = zip(*[client_grad(jnp.uint32(c)) for c in range(n_clients)])
        gmean = jax.tree.map(lambda *gs: sum(gs) / n_clients, *grads)
        p, s = opt.update(p, gmean, s, i)
        return p, s, sum(losses) / n_clients

    losses = []
    p, s = params, state
    for i in range(steps):
        p, s, l = step(p, s, jnp.uint32(i))
        losses.append(float(l))
    return losses


@pytest.fixture(scope="module")
def tiny_cfg():
    return reduced(get_config("llama3.2-1b"), layers=2, d_model=128, vocab=256)


@pytest.mark.parametrize("method", ["dsgd", "tqsgd", "tnqsgd", "tbqsgd"])
def test_training_converges_all_methods(tiny_cfg, method):
    losses = _train(tiny_cfg, method, bits=3)
    assert losses[-1] < losses[0] - 0.3, (method, losses)


def test_truncated_tracks_dsgd_at_low_bits(tiny_cfg):
    """At b=2 the truncated scheme must stay close to uncompressed DSGD and
    not be materially worse than untruncated QSGD.  (The dramatic Fig. 3
    QSGD *divergence* needs AlexNet-scale heavy tails; the per-gradient MSE
    ordering — the mechanism behind Fig. 3 — is asserted quantitatively in
    test_powerlaw.test_mse_ordering_of_methods and in §Claims of
    EXPERIMENTS.md via benchmarks/fig3.)"""
    l_dsgd = _train(tiny_cfg, "dsgd", bits=2)[-1]
    l_tq = _train(tiny_cfg, "tqsgd", bits=2)[-1]
    l_q = _train(tiny_cfg, "qsgd", bits=2)[-1]
    assert l_tq <= l_q + 0.05, (l_tq, l_q)
    assert abs(l_tq - l_dsgd) < 0.5, (l_tq, l_dsgd)
