"""Per-architecture smoke tests (reduced configs) + model-component tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.data.synthetic import lm_batch, make_mrope_positions
from repro.models import Batch, decode_step, init_lm, loss_fn, prefill
from repro.models.moe import dense_moe_apply, moe_apply, moe_init
from repro.models.ssm import naive_recurrence, ssd_chunked
from repro.models.transformer import embed_fn, head_fn, outer_params, unit_fn
from repro.models.attention import flash_attention


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch):
    """REDUCED variant: one forward + one SGD step; shapes + finiteness."""
    cfg = reduced(get_config(arch))
    params, logical = init_lm(jax.random.key(0), cfg)
    batch = lm_batch(cfg, jnp.uint32(0), 2, 128)
    loss, grads = jax.jit(jax.value_and_grad(lambda p: loss_fn(cfg, p, batch)))(params)
    assert jnp.isfinite(loss), arch
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads)), arch
    new = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    loss2 = jax.jit(lambda p: loss_fn(cfg, p, batch))(new)
    assert jnp.isfinite(loss2), arch
    # logical tree matches params structure
    assert jax.tree.structure(jax.tree.map(lambda *_: 0, params)) == jax.tree.structure(
        jax.tree.map(lambda _: 0, logical, is_leaf=lambda x: isinstance(x, tuple))
    )


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_prefill_decode(arch):
    cfg = reduced(get_config(arch))
    params, _ = init_lm(jax.random.key(0), cfg)
    batch = lm_batch(cfg, jnp.uint32(0), 2, 64)
    logits, caches = jax.jit(lambda p, b: prefill(cfg, p, b))(params, batch)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, caches2 = jax.jit(lambda p, t, c, pos: decode_step(cfg, p, t, c, pos))(
        params, tok, caches, jnp.int32(64)
    )
    assert logits2.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_decode_consistent_with_forward():
    """Greedy decode from prefill caches matches teacher-forced forward."""
    cfg = reduced(get_config("llama3.2-1b"))
    params, _ = init_lm(jax.random.key(0), cfg)
    b = lm_batch(cfg, jnp.uint32(0), 2, 33)
    # full forward logits at position t computed via prefill on prefix
    prefix = Batch(tokens=b.tokens[:, :32], labels=b.labels[:, :32])
    logits_prefill, caches = prefill(cfg, params, prefix, capacity=40)
    # decode the 33rd token
    logits_dec, _ = decode_step(cfg, params, b.tokens[:, 32:33], caches, jnp.int32(32))
    # reference: prefill on all 33 tokens -> last logits
    logits_ref, _ = prefill(cfg, params, b)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_ref), rtol=2e-3, atol=2e-3)


def test_ssm_decode_consistent_with_forward():
    cfg = reduced(get_config("mamba2-2.7b"))
    params, _ = init_lm(jax.random.key(0), cfg)
    b = lm_batch(cfg, jnp.uint32(0), 2, 33)
    prefix = Batch(tokens=b.tokens[:, :32], labels=b.labels[:, :32])
    _, caches = prefill(cfg, params, prefix)
    logits_dec, _ = decode_step(cfg, params, b.tokens[:, 32:33], caches, jnp.int32(32))
    logits_ref, _ = prefill(cfg, params, b)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_ref), rtol=2e-3, atol=2e-3)


def test_ssd_chunked_exact():
    key = jax.random.key(0)
    B, S, H, P, G, N = 2, 96, 4, 8, 2, 16
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    ld = -jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.5
    bs = jax.random.normal(ks[2], (B, S, G, N)) * 0.3
    cs = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    y1, fin = ssd_chunked(x, ld, bs, cs, chunk=16)
    y2 = naive_recurrence(x, ld, bs, cs)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    # continuation across a split point
    ya, fa = ssd_chunked(x[:, :48], ld[:, :48], bs[:, :48], cs[:, :48], chunk=16)
    yb, _ = ssd_chunked(x[:, 48:], ld[:, 48:], bs[:, 48:], cs[:, 48:], chunk=16, init_state=fa)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([ya, yb], 1)), np.asarray(y2), atol=2e-5)


def test_flash_attention_matches_naive():
    key = jax.random.key(1)
    B, S, H, D = 2, 128, 4, 16
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D)) for i in range(3))
    out = flash_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    # naive reference
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((S, S), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_attention_sliding_window():
    key = jax.random.key(2)
    B, S, H, D = 1, 64, 2, 8
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D)) for i in range(3))
    win = 16
    out = flash_attention(q, k, v, causal=True, window=win, q_chunk=16, kv_chunk=16)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
    qpos, kpos = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    mask = (qpos >= kpos) & (qpos - kpos < win)
    scores = jnp.where(mask[None, None], scores, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_moe_sort_matches_dense_at_high_capacity():
    cfg = reduced(get_config("phi3.5-moe-42b-a6.6b"))
    cfg = cfg.replace(moe=cfg.moe.__class__(num_experts=4, top_k=2, capacity_factor=8.0, every=1, d_ff=64))
    p, _ = moe_init(jax.random.key(0), cfg, cfg.d_model)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.5
    y1, aux1 = moe_apply(cfg, p, x)
    y2, aux2 = dense_moe_apply(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg = reduced(get_config("phi3.5-moe-42b-a6.6b"))
    cfg = cfg.replace(moe=cfg.moe.__class__(num_experts=4, top_k=2, capacity_factor=0.1, every=1, d_ff=64))
    p, _ = moe_init(jax.random.key(0), cfg, cfg.d_model)
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model))
    y, _ = moe_apply(cfg, p, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    # with tiny capacity most tokens must be dropped -> many zero rows
    zero_rows = jnp.mean((jnp.abs(y).sum(-1) == 0).astype(jnp.float32))
    assert float(zero_rows) > 0.3


def test_mrope_positions_shape_and_text_equivalence():
    pos = make_mrope_positions(2, 64, 16, grid=4)
    assert pos.shape == (3, 2, 64)
    # text positions identical across the three streams
    np.testing.assert_array_equal(np.asarray(pos[0, :, 16:]), np.asarray(pos[1, :, 16:]))
    np.testing.assert_array_equal(np.asarray(pos[1, :, 16:]), np.asarray(pos[2, :, 16:]))


def test_decomposed_train_path_matches_loss_fn():
    """embed_fn -> unit_fn scan -> head_fn == loss_fn (streamed-step math)."""
    from repro.models.transformer import AUX_LOSS_WEIGHT

    cfg = reduced(get_config("llama3.2-1b"))
    params, _ = init_lm(jax.random.key(0), cfg)
    batch = lm_batch(cfg, jnp.uint32(0), 2, 64)
    want = loss_fn(cfg, params, batch)
    outer = outer_params(params)
    h = embed_fn(cfg, outer, batch)
    positions = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))

    def f(carry, p_u):
        h, aux = carry
        h2, aux_u = unit_fn(cfg, p_u, h, positions)
        return (h2, aux + aux_u), None

    (h, aux), _ = jax.lax.scan(f, (h, jnp.float32(0.0)), params["blocks"])
    got = head_fn(cfg, outer, h, batch) + AUX_LOSS_WEIGHT * aux
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_long_decode_variant_sliding_window():
    from repro.configs import get_shape, variant_for_shape

    cfg = get_config("llama3.2-1b")
    v = variant_for_shape(cfg, get_shape("long_500k"))
    assert v.sliding_window == 4096
    # ssm/hybrid unchanged
    assert variant_for_shape(get_config("mamba2-2.7b"), get_shape("long_500k")).sliding_window is None
