"""Pin the analytic wire accounting of ``repro.dist.collectives``.

The benchmark prints these numbers; the tests make them load-bearing:
dsgd is the fp32 ring chunk, compressed modes follow the
``core.compressors.wire_bytes`` chunking, costs are monotone in bits, and
every compressed mode beats fp32 at every supported bit-width.

``core.compressors.wire_bytes`` is the single source of truth for
payload + metadata accounting (a former duplicate in ``core.quantizers``
charged ``levels+1`` metadata words instead of ``s+2`` and had no callers);
the tests below pin its exact decomposition and the per-element view.
"""
import pytest

from repro.core.compressors import CompressorConfig, wire_bits_per_element, wire_bytes
from repro.core.quantizers import num_levels, packed_size
from repro.dist.collectives import MODES, decode_hbm_bytes, wire_bytes_per_device

N = 1_000_000
SHARDS = 16


def test_dsgd_is_fp32_chunk():
    cfg = CompressorConfig(method="dsgd")
    assert wire_bytes_per_device(cfg, N, SHARDS, "dsgd") == pytest.approx(4.0 * N / SHARDS)
    # a dsgd-method compressor is uncompressed regardless of the sync mode
    for mode in MODES:
        assert wire_bytes_per_device(cfg, N, SHARDS, mode) == pytest.approx(4.0 * N / SHARDS)


def test_two_phase_matches_wire_bytes_chunking():
    for bits in (2, 3, 4, 8):
        cfg = CompressorConfig(method="tnqsgd", bits=bits)
        chunk = -(-N // SHARDS)
        assert wire_bytes_per_device(cfg, N, SHARDS, "two_phase") == pytest.approx(
            wire_bytes(cfg, chunk))


def test_faithful_is_sharded_full_tensor():
    for bits in (2, 3, 4, 8):
        cfg = CompressorConfig(method="tnqsgd", bits=bits)
        assert wire_bytes_per_device(cfg, N, SHARDS, "faithful") == pytest.approx(
            wire_bytes(cfg, N) / SHARDS)


def test_monotone_in_bits():
    for mode in ("two_phase", "faithful", "hierarchical"):
        costs = [wire_bytes_per_device(CompressorConfig(method="tnqsgd", bits=b), N, SHARDS, mode)
                 for b in range(1, 9)]
        assert costs == sorted(costs), (mode, costs)


def test_compressed_beats_fp32_at_all_bit_widths():
    fp32 = wire_bytes_per_device(CompressorConfig(method="dsgd"), N, SHARDS, "dsgd")
    for bits in (2, 3, 4, 8):
        cfg = CompressorConfig(method="tnqsgd", bits=bits)
        for mode in ("two_phase", "faithful", "hierarchical"):
            assert fp32 / wire_bytes_per_device(cfg, N, SHARDS, mode) > 1.0, (mode, bits)


def test_wire_bytes_decomposition():
    """payload = packed uint32 groups; metadata = s+1 levels + alpha, fp32."""
    for bits in (1, 2, 3, 4, 8):
        cfg = CompressorConfig(method="tnqsgd", bits=bits)
        s = 2**bits - 1
        for n in (1, 31, 32, 33, 1000, N):
            assert wire_bytes(cfg, n) == 4 * packed_size(n, bits) + 4 * (s + 2), (bits, n)
    # dsgd is raw fp32, no metadata
    assert wire_bytes(CompressorConfig(method="dsgd"), N) == 4 * N


def test_wire_bits_per_element_matches_wire_bytes():
    for bits in (2, 3, 4, 8):
        cfg = CompressorConfig(method="tnqsgd", bits=bits)
        assert wire_bits_per_element(cfg, N) == pytest.approx(8.0 * wire_bytes(cfg, N) / N)
        # metadata amortizes away at scale: per-element cost -> bits
        assert wire_bits_per_element(cfg, N) == pytest.approx(bits, rel=2e-3)
        # and dominates for tiny tensors
        assert wire_bits_per_element(cfg, 8) > bits


def test_quantizers_has_no_shadow_accounting():
    """The inconsistent duplicate must stay deleted."""
    from repro.core import quantizers

    assert not hasattr(quantizers, "wire_bits_per_element")


def test_rejects_bad_inputs():
    cfg = CompressorConfig(method="tnqsgd", bits=4)
    with pytest.raises(ValueError):
        wire_bytes_per_device(cfg, N, SHARDS, "ring")
    with pytest.raises(ValueError):
        wire_bytes_per_device(cfg, N, 0, "faithful")


def test_wire_bytes_heterogeneous_bits():
    """Adaptive wire format: per-bucket bit widths sum per-bucket costs."""
    cfg = CompressorConfig(method="tnqsgd", bits=3)
    sizes = [1000, 2000, 31]
    bits = [2, 4, 8]
    # decomposition: total == sum of the scalar calls
    assert wire_bytes(cfg, sizes, bits) == sum(
        wire_bytes(cfg, n, b) for n, b in zip(sizes, bits))
    # scalar-bits overrides cfg.bits; None keeps it
    assert wire_bytes(cfg, 1000, 5) == wire_bytes(
        CompressorConfig(method="tnqsgd", bits=5), 1000)
    assert wire_bytes(cfg, sizes) == sum(wire_bytes(cfg, n) for n in sizes)
    # a uniform per-bucket plan equals the scalar path exactly
    assert wire_bytes(cfg, sizes, [3, 3, 3]) == wire_bytes(cfg, sizes)
    # per-element view uses the total element count
    assert wire_bits_per_element(cfg, sizes, bits) == pytest.approx(
        8.0 * wire_bytes(cfg, sizes, bits) / sum(sizes))
    with pytest.raises(ValueError):
        wire_bytes(cfg, 1000, [2, 3])          # bits list without bucket sizes
    with pytest.raises(ValueError):
        wire_bytes(cfg, sizes, [2, 3])         # length mismatch
    with pytest.raises(ValueError):
        wire_bytes(cfg, 1000, 9)               # out-of-range width


def test_decode_hbm_bytes_model():
    """Decode-side HBM accounting: the fused path reads the wire once and
    writes the mean once; unfused adds two (peers, n) HBM round-trips."""
    cfg = CompressorConfig(method="tnqsgd", bits=3)
    peers = 16
    words = 4.0 * peers * packed_size(N, 3) + 4.0 * peers * (num_levels(3) + 1)
    assert decode_hbm_bytes(cfg, N, peers, fused=True) == pytest.approx(words + 4.0 * N)
    assert decode_hbm_bytes(cfg, N, peers, fused=False) == pytest.approx(
        words + 16.0 * peers * N + 4.0 * N)
    # the fusion removes the only O(peers·n) term: big win, monotone in peers
    ratio = (decode_hbm_bytes(cfg, N, peers, fused=False)
             / decode_hbm_bytes(cfg, N, peers, fused=True))
    assert ratio > 20
    assert ratio > (decode_hbm_bytes(cfg, N, 4, fused=False)
                    / decode_hbm_bytes(cfg, N, 4, fused=True))
    # heterogeneous buckets sum per bucket
    sizes, bits = [400_000, 600_000], [2, 4]
    assert decode_hbm_bytes(cfg, sizes, peers, fused=True, bits=bits) == pytest.approx(
        sum(decode_hbm_bytes(cfg, n, peers, fused=True, bits=b)
            for n, b in zip(sizes, bits)))
    with pytest.raises(ValueError):
        decode_hbm_bytes(cfg, sizes, peers, fused=True, bits=[2])


def test_encode_hbm_bytes_model():
    """Encode-side HBM accounting: the fused EF-correct→stats +
    quantize→pack→residual path sweeps the bucket ~5× per step vs ~16×
    for the seed multi-pass pipeline at the headline config."""
    from repro.dist.collectives import encode_hbm_bytes

    cfg = CompressorConfig(method="tnqsgd", bits=3)
    nb = 1 << 20  # one 4 MB fp32 bucket
    fused = encode_hbm_bytes(cfg, nb, fused=True)
    seed = encode_hbm_bytes(cfg, nb, fused=False)
    # exact fused terms: stats read (4n) + EF read/write (8n) + encode read
    # (4n) + wire words + residual write (4n)
    words = 4.0 * packed_size(nb, 3)
    assert fused == pytest.approx(20.0 * nb + words)
    # the acceptance bar: >= 3x lower modeled encode HBM at 4 MB / 3 bits
    # with EF + adaptive on
    assert seed / fused >= 3.0, (seed, fused)
    # fused never exceeds unfused in any configuration
    for ef in (False, True):
        for adaptive in (False, True):
            f = encode_hbm_bytes(cfg, nb, fused=True, ef=ef, adaptive=adaptive)
            u = encode_hbm_bytes(cfg, nb, fused=False, ef=ef, adaptive=adaptive)
            assert f < u, (ef, adaptive, f, u)
    # the approx-gmin seed variant drops the sort term but still loses
    u_approx = encode_hbm_bytes(
        CompressorConfig(method="tnqsgd", bits=3, approx_gmin=True), nb, fused=False)
    assert fused < u_approx < seed
    # heterogeneous buckets sum per bucket
    sizes, bits = [400_000, 600_000], [2, 4]
    assert encode_hbm_bytes(cfg, sizes, fused=True, bits=bits) == pytest.approx(
        sum(encode_hbm_bytes(cfg, n, fused=True, bits=b)
            for n, b in zip(sizes, bits)))
    with pytest.raises(ValueError):
        encode_hbm_bytes(cfg, sizes, fused=True, bits=[2])


def test_live_scales_wire_pro_rata():
    """Elastic accounting: k of n live peers put k/n of the full payload on
    each link, in every mode including the fp32 baseline."""
    cfg = CompressorConfig(method="tnqsgd", bits=3)
    for mode in MODES:
        c = CompressorConfig(method="dsgd") if mode == "dsgd" else cfg
        full = wire_bytes_per_device(c, N, SHARDS, mode)
        for k in (1, SHARDS // 2, SHARDS - 1, SHARDS):
            assert wire_bytes_per_device(c, N, SHARDS, mode, live=k) == pytest.approx(
                full * k / SHARDS), (mode, k)
    # heterogeneous buckets thread live through the per-bucket sum
    sizes, bits = [400_000, 600_000], [2, 4]
    assert wire_bytes_per_device(cfg, sizes, SHARDS, "faithful", bits, live=4) == \
        pytest.approx(wire_bytes_per_device(cfg, sizes, SHARDS, "faithful", bits) * 4 / SHARDS)
    for bad in (0, SHARDS + 1):
        with pytest.raises(ValueError):
            wire_bytes_per_device(cfg, N, SHARDS, "faithful", live=bad)


def test_live_decode_encode_hbm():
    """Decode reads only the live rows; encode always runs (straggler
    contract), so live leaves its cost untouched."""
    from repro.dist.collectives import encode_hbm_bytes

    cfg = CompressorConfig(method="tnqsgd", bits=3)
    peers = 16
    for fused in (True, False):
        for k in (1, 8, 15):
            assert decode_hbm_bytes(cfg, N, peers, fused, live=k) == pytest.approx(
                decode_hbm_bytes(cfg, N, k, fused)), (fused, k)
        assert encode_hbm_bytes(cfg, N, fused=fused, live=1) == pytest.approx(
            encode_hbm_bytes(cfg, N, fused=fused))
    with pytest.raises(ValueError):
        decode_hbm_bytes(cfg, N, peers, fused=True, live=peers + 1)


def test_fp16_tier_accounting():
    """The fp16 passthrough tier: 2 bytes/element wire, chunkable two-phase
    cost, and a decode model without the unpack-codes round-trip."""
    from repro.core.codecs import get_codec
    from repro.dist.collectives import encode_hbm_bytes

    cfg = CompressorConfig(method="tnqsgd", bits=3)
    fp16 = get_codec("fp16")
    bcfg = CompressorConfig(method="fp16")
    assert fp16.wire_bytes(bcfg, N) == 2 * N
    # two_phase ships a ceil chunk, faithful the sharded full wire
    chunk = -(-N // SHARDS)
    assert wire_bytes_per_device(cfg, N, SHARDS, "two_phase",
                                 bits=("fp16", 3)) == pytest.approx(2.0 * chunk)
    assert wire_bytes_per_device(cfg, N, SHARDS, "faithful",
                                 bits=("fp16", 3)) == pytest.approx(2.0 * N / SHARDS)
    # half-precision wire always beats fp32 and loses to <=8-bit quantizers
    assert wire_bytes_per_device(cfg, N, SHARDS, "faithful", bits=("fp16", 3)) < \
        4.0 * N / SHARDS
    assert wire_bytes_per_device(cfg, N, SHARDS, "faithful") < \
        wire_bytes_per_device(cfg, N, SHARDS, "faithful", bits=("fp16", 3))
    # decode: per-peer packed half words, no int32 code tensor round-trip
    peers = 16
    words = 4.0 * peers * ((N + 1) // 2)
    assert decode_hbm_bytes(cfg, N, peers, fused=True, bits=("fp16", 3)) == \
        pytest.approx(words + 4.0 * N)
    assert decode_hbm_bytes(cfg, N, peers, fused=False, bits=("fp16", 3)) == \
        pytest.approx(words + 8.0 * peers * N + 4.0 * N)
    # encode: one cast+pack sweep, identical fused/unfused
    assert encode_hbm_bytes(cfg, N, fused=True, bits=("fp16", 3)) == pytest.approx(
        encode_hbm_bytes(cfg, N, fused=False, bits=("fp16", 3)))


def test_wire_bytes_per_device_heterogeneous():
    """Mode chunking applies per bucket for sequence inputs."""
    cfg = CompressorConfig(method="tnqsgd", bits=3)
    sizes = [400_000, 600_000]
    bits = [2, 4]
    for mode in ("two_phase", "faithful", "hierarchical"):
        got = wire_bytes_per_device(cfg, sizes, SHARDS, mode, bits)
        want = sum(wire_bytes_per_device(cfg, n, SHARDS, mode, b)
                   for n, b in zip(sizes, bits))
        assert got == pytest.approx(want), mode
    with pytest.raises(ValueError):
        wire_bytes_per_device(cfg, sizes, SHARDS, "faithful", [2])
