"""Jaxpr/VMEM regression fixtures for ``repro.analysis``.

Loaded by path from ``tests/test_analysis.py`` (never on ``sys.path``).
Each builder returns a ClosedJaxpr that must trip exactly the rule named
in the corpus README; the builders trace on whatever devices exist — a
single-device ``("data",)`` mesh still binds ``axis_index`` and the
collective primitives, which is all the linter inspects.
"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import compat  # noqa: F401  (installs shard_map/AxisType shims)


def _mesh():
    return jax.make_mesh((1,), ("data",))


def _smap(body, in_specs, out_specs):
    return jax.jit(jax.shard_map(
        body, mesh=_mesh(), in_specs=in_specs, out_specs=out_specs))


def correlated_rng():
    """REPRO102: every peer folds the same constant — identical noise."""
    def body(key, x):
        k = jax.random.fold_in(key, 7)  # no axis_index in the fold
        return x + jax.random.uniform(k, x.shape)

    key = jax.random.key(0)  # repro: allow REPRO204 (fixture trace input)
    x = jnp.zeros((8, 4), jnp.float32)
    return _smap(body, (P(), P("data")), P("data")).trace(key, x).jaxpr


def decorrelated_rng():
    """The fixed variant (the PR 2 pattern): fold the axis index in."""
    def body(key, x):
        k = jax.random.fold_in(key, jax.lax.axis_index("data"))
        return x + jax.random.uniform(k, x.shape)

    key = jax.random.key(0)  # repro: allow REPRO204 (fixture trace input)
    x = jnp.zeros((8, 4), jnp.float32)
    return _smap(body, (P(), P("data")), P("data")).trace(key, x).jaxpr


def extra_collective():
    """REPRO101 against a budget of 1: a second, redundant all-gather."""
    def body(x):
        g = jax.lax.all_gather(x, "data")
        h = jax.lax.all_gather(x * 2.0, "data")  # the extra hop
        return (g + h).reshape(-1)

    x = jnp.zeros((8,), jnp.float32)
    return _smap(body, (P("data"),), P()).trace(x).jaxpr


def f64_leak():
    """REPRO103: a float64 value escaping into the traced computation."""
    def f(x):
        return jnp.sum(x.astype(jnp.float64))

    with jax.experimental.enable_x64():
        return jax.jit(f).trace(jnp.zeros((8,), jnp.float32)).jaxpr


def scatter_add():
    """REPRO104: float scatter-add with potentially colliding indices."""
    def f(idx, v):
        return jnp.zeros((8,), jnp.float32).at[idx].add(v)

    idx = jnp.zeros((16,), jnp.int32)
    v = jnp.ones((16,), jnp.float32)
    return jax.jit(f).trace(idx, v).jaxpr


def wire_f32():
    """REPRO105: fp32 rows on a compressed-wire collective (the codec
    contract is one uint32 word vector per bucket)."""
    def body(x):
        return jax.lax.all_gather(x, "data").reshape(-1)

    x = jnp.zeros((8,), jnp.float32)
    return _smap(body, (P("data"),), P()).trace(x).jaxpr


def vmem_blowout_thunk():
    """REPRO301: a (4096, 8192) fp32 double-buffered block — 256 MiB of
    VMEM against the 4 MiB default budget."""
    from jax.experimental import pallas as pl

    def copy_kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    big = jax.ShapeDtypeStruct((8192, 8192), jnp.float32)

    def call(x):
        return pl.pallas_call(
            copy_kernel,
            grid=(2,),
            in_specs=[pl.BlockSpec((4096, 8192), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((4096, 8192), lambda i: (i, 0)),
            out_shape=big,
            interpret=True,
        )(x)

    return lambda: jax.eval_shape(call, big)
