"""REPRO204 fixture: baked-in PRNG seeds in library code."""
import jax


def make_noise(shape):
    key = jax.random.key(42)
    return jax.random.normal(key, shape)


def legacy_noise(shape):
    return jax.random.normal(jax.random.PRNGKey(0), shape)
