"""Suppression round-trip fixture: the ``literal_seed.py`` pattern carrying
the allow comment — the linter must come back clean, and stripping the
comment must re-arm the rule."""
import jax


def make_noise(shape):
    # repro: allow REPRO204 (fixture: documented constant trace seed)
    key = jax.random.key(42)
    return jax.random.normal(key, shape)
