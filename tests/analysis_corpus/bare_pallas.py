"""REPRO202 fixture: a bare ``pallas_call`` launch outside ``kernels/``."""
from jax.experimental import pallas as pl


def sneaky_launch(kernel, x, out_shape):
    # bypasses the ops wrappers: no padding, no interpret fallback
    return pl.pallas_call(kernel, out_shape=out_shape)(x)
