"""REPRO203 fixture: an ops-style kernel wrapper that accepts ``interpret=``
but never resolves it through ``_use_interpret`` (linted as
``kernels/ops.py``)."""


def fancy_encode(x, bits, *, interpret=None):
    interpret = False if interpret is None else interpret  # wrong: ignores env dispatch
    return x, bits, interpret
