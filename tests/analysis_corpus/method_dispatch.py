"""REPRO201 fixture: method-string dispatch inside a collective body.

Linted with a ``dist/sharded_codec.py`` relpath by ``tests/test_analysis.py``
so the path-scoped rule arms; never imported.
"""


def reduce_bucket(cfg, rows):
    if cfg.method == "qsgd":  # the branching the codec registry outlawed
        return rows.sum(0)
    if cfg.method in ("tqsgd", "tnqsgd"):
        return rows.mean(0)
    return rows[0]
