"""Distributed-runtime tests.  Multi-device cases run in subprocesses so the
main pytest process keeps a single CPU device (dry-run contract)."""
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def run_with_devices(code: str, n: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import NamedSharding, PartitionSpec as P, AxisType
from repro.configs import get_config, reduced
from repro.models import init_lm
from repro.data.synthetic import lm_batch
from repro.optim.optimizers import momentum_sgd
from repro.dist.train_step import make_train_step, TrainStepConfig
from repro.core.compressors import CompressorConfig
"""


def test_streamed_equals_plain_dsgd():
    out = run_with_devices(PRELUDE + """
mesh = jax.make_mesh((4,2), ("data","model"), axis_types=(AxisType.Auto,)*2)
cfg = reduced(get_config("llama3.2-1b")).replace(fsdp=True)
params0, logical = init_lm(jax.random.key(0), cfg)
opt = momentum_sgd(lr=0.05)
res = {}
for name, ts in [("plain", TrainStepConfig(sync="dsgd", streamed=False)),
                 ("stream", TrainStepConfig(sync="dsgd", streamed=True))]:
    batch = lm_batch(cfg, jnp.uint32(0), 8, 128)
    step_fn, pspecs = make_train_step(cfg, mesh, logical, opt, ts, batch)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P))
    p = jax.device_put(jax.tree.map(jnp.copy, params0), sh)
    o = jax.tree.map(jnp.zeros_like, p)
    losses = []
    for i in range(3):
        p, o, m = step_fn(p, o, lm_batch(cfg, jnp.uint32(i), 8, 128), jnp.uint32(i))
        losses.append(float(m["loss"][0]))
    res[name] = losses
assert np.allclose(res["plain"], res["stream"], atol=1e-4), res
print("OK", json.dumps(res))
""")
    assert "OK" in out


@pytest.mark.parametrize("sync,method", [("faithful", "tnqsgd"), ("two_phase", "tqsgd"), ("two_phase", "tbqsgd")])
def test_compressed_training_converges(sync, method):
    out = run_with_devices(PRELUDE + f"""
mesh = jax.make_mesh((4,2), ("data","model"), axis_types=(AxisType.Auto,)*2)
cfg = reduced(get_config("llama3.2-1b")).replace(fsdp=True)
params0, logical = init_lm(jax.random.key(0), cfg)
opt = momentum_sgd(lr=0.05)
ts = TrainStepConfig(sync="{sync}", compressor=CompressorConfig(method="{method}", bits=4))
batch = lm_batch(cfg, jnp.uint32(0), 8, 128)
step_fn, pspecs = make_train_step(cfg, mesh, logical, opt, ts, batch)
sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P))
p = jax.device_put(jax.tree.map(jnp.copy, params0), sh)
o = jax.tree.map(jnp.zeros_like, p)
losses = []
for i in range(6):
    p, o, m = step_fn(p, o, lm_batch(cfg, jnp.uint32(i), 8, 128), jnp.uint32(i))
    losses.append(float(m["loss"][0]))
assert losses[-1] < losses[0] - 0.2, losses
print("OK", losses)
""")
    assert "OK" in out


def test_multipod_mesh_compressed():
    out = run_with_devices(PRELUDE + """
mesh = jax.make_mesh((2,2,2), ("pod","data","model"), axis_types=(AxisType.Auto,)*3)
cfg = reduced(get_config("llama3.2-1b")).replace(fsdp=True)
params0, logical = init_lm(jax.random.key(0), cfg)
opt = momentum_sgd(lr=0.05)
for sync in ("dsgd", "two_phase", "hierarchical", "faithful"):
    ts = TrainStepConfig(sync=sync, compressor=CompressorConfig(method="tnqsgd", bits=4))
    batch = lm_batch(cfg, jnp.uint32(0), 8, 128)
    step_fn, pspecs = make_train_step(cfg, mesh, logical, opt, ts, batch)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P))
    p = jax.device_put(jax.tree.map(jnp.copy, params0), sh)
    o = jax.tree.map(jnp.zeros_like, p)
    losses = []
    for i in range(3):
        p, o, m = step_fn(p, o, lm_batch(cfg, jnp.uint32(i), 8, 128), jnp.uint32(i))
        losses.append(float(m["loss"][0]))
    assert losses[-1] < losses[0], (sync, losses)
    print(sync, "OK", losses)
""")
    assert out.count("OK") == 4


# The former single-mesh codec spot check (test_sharded_codec_units) is
# superseded by tests/test_mesh_invariance.py: a parameterized mesh-shape ×
# sync-method sweep pinning bitwise peer agreement and equality with the
# single-device reference codec (repro.dist.reference).


def test_bucketed_matches_per_leaf_mean():
    """The bucketed codec must agree with the per-leaf codec up to
    quantization noise while issuing a mode-bounded number of collectives
    (1 all-gather for faithful, all-to-all + all-gather for two_phase) —
    independent of the leaf count.

    Reuses the exact demo script from ``benchmarks/collectives_bench.py``
    (which asserts these properties itself), so bench and test measure the
    same thing by construction."""
    import sys

    sys.path.insert(0, str(ROOT))
    try:
        from benchmarks.collectives_bench import _bucketed_demo_rows
    finally:
        sys.path.pop(0)

    rows = {r.split(",")[1]: r.split(",")[3] for r in _bucketed_demo_rows()}
    assert rows.get("bucketed_demo") == "OK", rows
    assert int(rows["two_phase_bucket_n_collectives"]) == 2, rows
    assert int(rows["faithful_bucket_n_collectives"]) == 1, rows
    assert int(rows["two_phase_leaf_n_collectives"]) >= int(rows["n_grad_leaves"]), rows
    assert int(rows["faithful_leaf_n_collectives"]) >= int(rows["n_grad_leaves"]), rows


def test_opt_specs_with_non_mirror_leaves():
    """A scalar step counter in the optimizer state must not knock the
    mirrored momentum leaves back to full replication."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.dist.train_step import _opt_specs

    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    pspecs = {"w": P("model", None), "b": P(None)}

    # mirror + scalar counter: leaf count not divisible by param count
    opt_state = {"count": jnp.zeros(()), "mu": {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}}
    specs = _opt_specs(opt_state, params, pspecs)
    flat = dict(zip(["count", "mu.b", "mu.w"], jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))))
    assert flat["count"] == P()
    # dict flattening is key-sorted: b before w
    assert flat["mu.b"] == P(None)
    assert flat["mu.w"] == P("model", None)

    # two mirrors (AdamW-style) still cycle through the param specs
    opt2 = {"m": dict(params), "v": dict(params)}
    specs2 = _opt_specs(opt2, params, pspecs)
    leaves2 = jax.tree.leaves(specs2, is_leaf=lambda s: isinstance(s, P))
    assert leaves2 == [P(None), P("model", None), P(None), P("model", None)]


def test_pack_dim_roundtrip():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.dist.sharded_codec import pack_dim, unpack_dim

    codes = jax.random.randint(jax.random.key(0), (3, 128, 5), 0, 8).astype(jnp.uint8)
    w = pack_dim(codes, 1, 3)
    assert w.shape == (3, 12, 5)
    back = unpack_dim(w, 1, 3)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_serve_fns_multidevice():
    out = run_with_devices(PRELUDE + """
from repro.dist.serve_step import make_serve_fns
from repro.models.transformer import Batch
mesh = jax.make_mesh((4,2), ("data","model"), axis_types=(AxisType.Auto,)*2)
cfg = reduced(get_config("llama3.2-1b"))
params, logical = init_lm(jax.random.key(0), cfg)
batch = lm_batch(cfg, jnp.uint32(0), 8, 64)
prefill_fn, decode_fn, pspecs, cspecs = make_serve_fns(cfg, mesh, logical, batch, 8, 64, params_like=params)
sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P))
p = jax.device_put(params, sh)
logits, caches = prefill_fn(p, batch)
assert logits.shape == (8, cfg.vocab)
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
logits2, caches2 = decode_fn(p, tok, caches, jnp.int32(64))
assert bool(jnp.all(jnp.isfinite(logits2)))
print("OK")
""")
    assert "OK" in out
