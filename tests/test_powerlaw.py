"""Power-law tail model + optimal-parameter tests (paper §IV)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressorConfig, compress_decompress, fit_power_law_tail, sample_power_law
from repro.core import distributions as D
from repro.core import optimal as O
from repro.core import theory as T
from repro.core.compressors import plan


@pytest.fixture(scope="module")
def g():
    return sample_power_law(jax.random.key(7), (400_000,), gamma=4.0, g_min=0.01, rho=0.1)


def test_gamma_mle_recovery():
    for gamma_true in (3.5, 4.0, 4.5):
        s = sample_power_law(jax.random.key(1), (300_000,), gamma=gamma_true, g_min=0.01, rho=0.25)
        tail = fit_power_law_tail(s, gmin_quantile=0.6)
        assert abs(float(tail.gamma) - gamma_true) < 0.25, (gamma_true, float(tail.gamma))


def test_tail_mass_consistency(g):
    tail = fit_power_law_tail(g)
    alpha = 2.0 * tail.g_min
    pred = float(D.tail_mass(tail, alpha))
    emp = float(jnp.mean(jnp.abs(g) > alpha) / 2.0)
    assert abs(pred - emp) / max(emp, 1e-9) < 0.2


def test_alpha_fixed_point_matches_closed_form(g):
    """Eq. 12 fixed point ~ alpha' = g_min (2 rho s^2/(gamma-2))^(1/(gamma-1))
    since Q_U ~ 1 (paper remark after Thm 1)."""
    tail = fit_power_law_tail(g)
    alpha = O.solve_alpha_uniform(tail, bits=3)
    s = 7
    approx = float(tail.g_min) * (2 * float(tail.rho) * s * s / (float(tail.gamma) - 2)) ** (
        1.0 / (float(tail.gamma) - 1.0)
    )
    assert abs(float(alpha) - approx) / approx < 0.1


def test_alpha_grows_with_bits(g):
    tail = fit_power_law_tail(g)
    alphas = [float(O.solve_alpha_uniform(tail, bits=b)) for b in (2, 3, 4, 5)]
    assert all(a2 > a1 for a1, a2 in zip(alphas, alphas[1:]))


def test_alpha_shrinks_with_gamma():
    """Thicker tails (smaller gamma) need larger truncation thresholds."""
    alphas = []
    for gm in (3.5, 4.0, 4.5):
        s = sample_power_law(jax.random.key(2), (200_000,), gamma=gm, g_min=0.01, rho=0.2)
        tail = fit_power_law_tail(s, gmin_quantile=0.6)
        alphas.append(float(O.solve_alpha_uniform(tail, bits=3)))
    assert alphas[0] > alphas[1] > alphas[2]


def test_holder_ordering(g):
    """Q_N <= Q_U (paper's Holder argument after Thm 2)."""
    tail = fit_power_law_tail(g)
    dens = D.fit_empirical_density(g)
    alpha = O.solve_alpha_uniform(tail, bits=3)
    qn = float(O.q_n(dens, alpha))
    qu = float(D.q_u(tail, alpha))
    qb = float(O.q_b(dens, alpha, jnp.float32(0.3)))
    assert qn <= qu * 1.02
    assert qb <= qu * 1.02


def test_optimal_alpha_minimizes_error(g):
    """The Eq. 12 alpha should (approximately) minimize measured MSE over an
    alpha sweep for the uniform quantizer."""
    from repro.core.quantizers import QuantMeta, quantize, uniform_levels

    tail = fit_power_law_tail(g)
    a_star = float(O.solve_alpha_uniform(tail, bits=3))
    alphas = a_star * np.array([0.25, 0.5, 1.0, 2.0, 4.0, 8.0])
    mses = []
    for a in alphas:
        meta = QuantMeta(levels=uniform_levels(jnp.float32(a), 3), alpha=jnp.float32(a))
        qv = quantize(g[:100_000], meta, jax.random.key(3))
        mses.append(float(jnp.mean((qv - g[:100_000]) ** 2)))
    assert np.argmin(mses) == 2, (list(zip(alphas, mses)), a_star)


def test_mse_ordering_of_methods(g):
    mses = {}
    for m in ("qsgd", "nqsgd", "tqsgd", "tnqsgd", "tbqsgd"):
        out = compress_decompress(CompressorConfig(method=m, bits=3), g, jax.random.key(4))
        mses[m] = float(jnp.mean((out - g) ** 2))
    # truncation >> no truncation at 3 bits (paper Fig. 3 regime)
    assert mses["tqsgd"] < 0.1 * mses["qsgd"]
    # non-uniform beats uniform without truncation
    assert mses["nqsgd"] < mses["qsgd"]
    # optimised variants at least match the uniform truncated scheme
    assert mses["tnqsgd"] <= mses["tqsgd"] * 1.1
    assert mses["tbqsgd"] <= mses["tqsgd"] * 1.1


def test_theory_error_within_factor(g):
    """Empirical per-element MSE of TQSGD tracks Eq. 11 within ~2x."""
    tail = fit_power_law_tail(g)
    alpha = O.solve_alpha_uniform(tail, bits=3)
    pred = float(T.e_tq_uniform(tail, alpha, 3))
    out = compress_decompress(CompressorConfig(method="tqsgd", bits=3), g, jax.random.key(5))
    emp = float(jnp.mean((out - g) ** 2))
    assert 0.2 < emp / pred < 3.0, (emp, pred)


def test_bound_decreases_with_bits(g):
    tail = fit_power_law_tail(g)
    vals = [float(T.e_tq_bound(tail, jnp.float32(1.0), b)) for b in (2, 3, 4, 5)]
    assert all(v2 < v1 for v1, v2 in zip(vals, vals[1:]))


def test_approx_quantile_agrees_with_exact(g):
    """The O(n) histogram quantile (hot-loop path) pins to the full-sort
    quantile within 2% across the useful range, and the resulting tail fit
    is indistinguishable for downstream α solving."""
    gabs = jnp.abs(g)
    for q in (0.5, 0.9, 0.95, 0.99):
        exact = float(jnp.quantile(gabs, q))
        approx = float(D.approx_abs_quantile(gabs, q))
        assert abs(approx - exact) / exact < 0.02, (q, exact, approx)
    t_exact = fit_power_law_tail(g)
    t_approx = fit_power_law_tail(g, approx_quantile=True)
    assert abs(float(t_exact.gamma) - float(t_approx.gamma)) < 0.05
    assert abs(float(t_exact.g_min) - float(t_approx.g_min)) / float(t_exact.g_min) < 0.02
    a_exact = float(O.solve_alpha_uniform(t_exact, bits=3))
    a_approx = float(O.solve_alpha_uniform(t_approx, bits=3))
    assert abs(a_exact - a_approx) / a_exact < 0.05


def test_plan_sample_contiguous_chunks_agree(g):
    """The contiguous-chunk statistics subsample (the TPU-friendly
    replacement for the strided ``g32[::stride]`` gather) yields the same
    estimators as the full-tensor fit within tight tolerance on iid data,
    and actually takes contiguous runs."""
    import dataclasses

    from repro.core.compressors import _plan_sample

    cfg_full = CompressorConfig(method="tqsgd", bits=3, plan_sample=0)
    cfg_sub = CompressorConfig(method="tqsgd", bits=3, plan_sample=65536)
    m_full = plan(cfg_full, g)
    m_sub = plan(cfg_sub, g)
    assert abs(float(m_full.alpha) - float(m_sub.alpha)) / float(m_full.alpha) < 0.1
    # tail estimators agree too (the fit sees a representative sample)
    t_full = fit_power_law_tail(g)
    t_sub = fit_power_law_tail(_plan_sample(g.reshape(-1), 65536))
    assert abs(float(t_full.gamma) - float(t_sub.gamma)) < 0.2
    assert abs(float(t_full.g_min) - float(t_sub.g_min)) / float(t_full.g_min) < 0.1
    # the sample is literally made of contiguous runs of the input
    x = jnp.arange(400_000, dtype=jnp.float32)
    s = np.asarray(_plan_sample(x, 65536))
    assert s.size <= 65536
    runs = np.split(s, np.where(np.diff(s) != 1.0)[0] + 1)
    assert len(runs) <= 64 and all(r.size >= 512 for r in runs)
    # ... and the runs always spread across the WHOLE tensor, including the
    # sample < n <= 2*sample window where a naive chunking would degenerate
    # to one leading block and never see the trailing leaves of a bucket
    for n in (100_000, 131_000, 65_537):
        s = np.asarray(_plan_sample(jnp.arange(n, dtype=jnp.float32), 65536))
        assert s.max() >= 0.9 * n, (n, s.max())
        assert s.min() <= 0.1 * n, (n, s.min())
    # non-uniform methods run the same sampled statistics
    m_nu = plan(dataclasses.replace(cfg_sub, method="tnqsgd"), g)
    m_nu_full = plan(dataclasses.replace(cfg_full, method="tnqsgd"), g)
    assert abs(float(m_nu.alpha) - float(m_nu_full.alpha)) / float(m_nu_full.alpha) < 0.15


def test_plan_from_stats_agrees_with_sort_plan(g):
    """The histogram-driven ``plan_from_stats`` (what the bucketed codec
    runs off the fused one-pass statistics) solves essentially the same α
    as the sort-based ``plan`` fallback, for both uniform and non-uniform
    methods, and builds a usable strictly-increasing codebook."""
    from repro.adaptive.telemetry import bucket_statistics
    from repro.core.compressors import plan_from_stats

    counts, log_sums, g_max, _, _ = bucket_statistics(g)
    for method in ("tqsgd", "tnqsgd", "qsgd", "nqsgd", "tbqsgd"):
        cfg = CompressorConfig(method=method, bits=3, plan_sample=0)
        m_sort = plan(cfg, g)
        m_hist = plan_from_stats(cfg, counts, log_sums, g_max)
        assert abs(float(m_sort.alpha) - float(m_hist.alpha)) / float(m_sort.alpha) < 0.15, method
        lv = np.asarray(m_hist.levels)
        assert (np.diff(lv) > 0).all(), method
        assert lv[-1] == pytest.approx(float(m_hist.alpha), rel=1e-6)
    # quantizing with the histogram plan costs no material MSE vs the sort plan
    from repro.core.quantizers import quantize

    cfg = CompressorConfig(method="tnqsgd", bits=3, plan_sample=0)
    q_sort = quantize(g[:100_000], plan(cfg, g), jax.random.key(3))
    q_hist = quantize(g[:100_000], plan_from_stats(cfg, counts, log_sums, g_max),
                      jax.random.key(3))
    mse_sort = float(jnp.mean((q_sort - g[:100_000]) ** 2))
    mse_hist = float(jnp.mean((q_hist - g[:100_000]) ** 2))
    assert mse_hist < mse_sort * 1.2, (mse_hist, mse_sort)


def test_approx_gmin_compressor_path(g):
    """CompressorConfig(approx_gmin=True) routes the plan through the
    histogram quantile and changes the MSE only marginally."""
    from repro.core.compressors import plan

    for method in ("tqsgd", "tnqsgd"):
        exact_cfg = CompressorConfig(method=method, bits=3)
        approx_cfg = CompressorConfig(method=method, bits=3, approx_gmin=True)
        m_exact = plan(exact_cfg, g)
        m_approx = plan(approx_cfg, g)
        assert abs(float(m_exact.alpha) - float(m_approx.alpha)) / float(m_exact.alpha) < 0.1
        out = compress_decompress(approx_cfg, g, jax.random.key(11))
        ref = compress_decompress(exact_cfg, g, jax.random.key(11))
        mse_a = float(jnp.mean((out - g) ** 2))
        mse_e = float(jnp.mean((ref - g) ** 2))
        assert mse_a < mse_e * 1.15, (method, mse_a, mse_e)
