"""repro.adaptive: telemetry estimation, wire-budget allocation, and the
end-to-end adaptive train step (subprocess host mesh)."""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adaptive import telemetry as T
from repro.adaptive.controller import AdaptiveConfig, allocate_bits, budget_bytes
from repro.core import sample_power_law
from repro.core.compressors import CompressorConfig, wire_bytes

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _tails_for(gammas, *, n=120_000, updates=4, decay=0.9, g_mins=None, rhos=None):
    g_mins = g_mins or [0.01] * len(gammas)
    rhos = rhos or [0.15] * len(gammas)
    st = T.init_telemetry(len(gammas))
    for i in range(updates):
        bks = [sample_power_law(jax.random.key(1000 * b + i), (n,), gamma=ga,
                                g_min=gm, rho=r)
               for b, (ga, gm, r) in enumerate(zip(gammas, g_mins, rhos))]
        st = T.update_telemetry(st, bks, decay=decay)
    return st, T.estimate_tails(st)


def test_telemetry_recovers_tail_index():
    gammas = (3.3, 4.0, 4.7)
    _, tails = _tails_for(gammas)
    got = np.asarray(tails.gamma)
    for b, ga in enumerate(gammas):
        assert abs(got[b] - ga) < 0.4, (b, ga, got[b])
    # heavier tail -> smaller estimated gamma, strictly ordered
    assert got[0] < got[1] < got[2]


def test_telemetry_state_is_scale_invariant_in_ratios():
    """EMA debiasing cancels: doubling the number of updates must not move
    the estimated (gamma, rho) materially."""
    _, t_few = _tails_for((3.6,), updates=2)
    _, t_many = _tails_for((3.6,), updates=8)
    assert abs(float(t_few.gamma[0]) - float(t_many.gamma[0])) < 0.25
    assert abs(float(t_few.rho[0]) - float(t_many.rho[0])) < 0.05


def test_aggregate_peers_merges_rows():
    st, _ = _tails_for((3.5, 4.5), updates=2)
    stacked = jax.tree.map(lambda x: jnp.stack([x, x]), st)
    merged = T.aggregate_peers(stacked)
    np.testing.assert_allclose(np.asarray(merged.counts), 2 * np.asarray(st.counts))
    np.testing.assert_allclose(np.asarray(merged.g_max), np.asarray(st.g_max))
    np.testing.assert_allclose(np.asarray(merged.mean), np.asarray(st.mean), rtol=1e-6)
    # identical peers -> identical tails after merging
    ta, tb = T.estimate_tails(merged), T.estimate_tails(st)
    np.testing.assert_allclose(np.asarray(ta.gamma), np.asarray(tb.gamma), rtol=1e-5)


def test_allocate_bits_respects_budget_and_bounds():
    _, tails = _tails_for((3.2, 4.9), g_mins=[0.02, 0.002], rhos=[0.2, 0.05])
    ccfg = CompressorConfig(method="tqsgd", bits=3)
    sizes = [1 << 16, 1 << 16]
    budget = wire_bytes(ccfg, sizes)
    plan = allocate_bits(tails, sizes, budget, ccfg, min_bits=2, max_bits=8)
    assert plan.spend_bytes <= budget == plan.budget_bytes
    assert all(2 <= b <= 8 for b in plan.bits)
    assert len(plan.alphas) == len(sizes)
    # the heavy/large-scale bucket is never starved below the thin one
    assert plan.bits[0] >= plan.bits[1]


def test_allocate_bits_monotone_in_budget():
    _, tails = _tails_for((3.3, 3.9, 4.6))
    ccfg = CompressorConfig(method="tqsgd", bits=3)
    sizes = [1 << 15] * 3
    base = wire_bytes(ccfg, sizes)
    totals = []
    for f in (0.7, 1.0, 1.6):
        plan = allocate_bits(tails, sizes, int(base * f), ccfg)
        assert plan.spend_bytes <= int(base * f)
        totals.append(sum(plan.bits))
    assert totals[0] <= totals[1] <= totals[2]
    # an effectively unlimited budget saturates max_bits
    plan = allocate_bits(tails, sizes, 10 * base, ccfg, max_bits=8)
    assert plan.bits == (8, 8, 8)


def test_allocate_bits_method_dispatch():
    """The error model follows the compressor method: tnqsgd's α comes from
    the non-uniform solver over the telemetry density (what the codec's
    plan actually solves), untruncated qsgd/nqsgd pin α = max|g|."""
    from repro.core import optimal

    st, tails = _tails_for((3.4,), updates=3)
    dens = T.estimate_densities(st)
    sizes = [1 << 15]
    row = jax.tree.map(lambda x: x[0], tails)
    for bits in (2, 4):
        plan = allocate_bits(tails, sizes, 10**9, CompressorConfig(method="tnqsgd", bits=3),
                             dens=dens, min_bits=bits, max_bits=bits)
        want = float(optimal.solve_alpha_nonuniform(row, dens[0], bits))
        assert plan.alphas[0] == pytest.approx(want, rel=1e-5), (bits, plan.alphas, want)
        uni = allocate_bits(tails, sizes, 10**9, CompressorConfig(method="tqsgd", bits=3),
                            dens=dens, min_bits=bits, max_bits=bits)
        assert uni.alphas[0] == pytest.approx(
            float(optimal.solve_alpha_uniform(row, bits)), rel=1e-5)
    for method in ("qsgd", "nqsgd"):
        plan = allocate_bits(tails, sizes, 10**9, CompressorConfig(method=method, bits=3),
                             dens=dens, min_bits=3, max_bits=3)
        assert plan.alphas[0] == pytest.approx(float(row.g_max), rel=1e-6)


def test_predicted_error_monotone_in_bits():
    from repro.adaptive.controller import predicted_error

    st, tails = _tails_for((3.5,), updates=3)
    dens = T.estimate_densities(st)
    for method in ("tqsgd", "tnqsgd"):
        ccfg = CompressorConfig(method=method, bits=3)
        errs = [predicted_error(tails, [1 << 14], [b], ccfg, dens=dens)
                for b in (2, 3, 5, 8)]
        assert errs == sorted(errs, reverse=True), (method, errs)
    # BitPlan.err matches predicted_error at the solved bits
    ccfg = CompressorConfig(method="tnqsgd", bits=3)
    plan = allocate_bits(tails, [1 << 14], 10**9, ccfg, dens=dens, max_bits=6)
    assert plan.err == pytest.approx(
        predicted_error(tails, [1 << 14], plan.bits, ccfg, dens=dens), rel=1e-6)


def test_budget_bytes_default_matches_fixed_plan():
    ccfg = CompressorConfig(method="tqsgd", bits=3)
    sizes = [1000, 2000]
    assert budget_bytes(AdaptiveConfig(), ccfg, sizes) == wire_bytes(ccfg, sizes)
    mb = AdaptiveConfig(wire_budget_mb=2.5)
    assert budget_bytes(mb, ccfg, sizes) == int(2.5 * (1 << 20))


def test_adaptive_config_validation():
    with pytest.raises(ValueError):
        AdaptiveConfig(min_bits=5, max_bits=4)
    with pytest.raises(ValueError):
        AdaptiveConfig(replan_every=0)
    with pytest.raises(ValueError):
        AdaptiveConfig(ema=1.5)
    from repro.dist.train_step import TrainStepConfig

    with pytest.raises(ValueError):
        TrainStepConfig(sync="dsgd", adaptive=AdaptiveConfig())
    with pytest.raises(ValueError):
        TrainStepConfig(sync="faithful", bucket_mb=0.0, adaptive=AdaptiveConfig())
    with pytest.raises(ValueError):
        TrainStepConfig(sync="faithful", bits_plan=(0, 3))


def test_adaptive_beats_fixed_at_equal_bytes():
    """The acceptance property, in miniature (the benchmark runs it at
    scale): telemetry-driven allocation under the fixed-3-bit budget yields
    strictly lower total MSE on heterogeneous buckets."""
    import dataclasses

    specs = [(3.2, 0.02, 0.2), (5.0, 0.001, 0.05), (3.6, 0.01, 0.15)]
    keys = jax.random.split(jax.random.key(0), len(specs))
    bks = [sample_power_law(k, (1 << 15,), gamma=ga, g_min=gm, rho=r)
           for k, (ga, gm, r) in zip(keys, specs)]
    st = T.init_telemetry(len(bks))
    for _ in range(3):
        st = T.update_telemetry(st, bks, decay=0.9)
    tails = T.estimate_tails(st)
    ccfg = CompressorConfig(method="tqsgd", bits=3)
    sizes = [b.size for b in bks]
    plan = allocate_bits(tails, sizes, wire_bytes(ccfg, sizes), ccfg)

    def mse(bits):
        from repro.core.compressors import compress_decompress

        tot = sum(float(jnp.sum((compress_decompress(
            dataclasses.replace(ccfg, bits=k), g, jax.random.fold_in(jax.random.key(9), b))
            - g) ** 2)) for b, (g, k) in enumerate(zip(bks, bits)))
        return tot / sum(sizes)

    assert plan.spend_bytes <= plan.budget_bytes
    assert mse(plan.bits) < mse([3] * len(bks))


def test_stepper_cache_bound_and_hysteresis():
    """The compiled-step cache is LRU-bounded at max_cached_steps and a
    replan whose predicted gain is under switch_threshold keeps the current
    plan (no compile).  Exercised on a stepper shell with a stubbed
    builder, so no mesh/compile is involved."""
    import collections

    from repro.adaptive.controller import BitPlan
    from repro.adaptive.runtime import AdaptiveStepper
    from repro.dist.train_step import TrainStepConfig

    st, _ = _tails_for((3.4, 4.6), updates=3)
    stacked = jax.tree.map(lambda x: x[None], st)   # one peer row

    s = AdaptiveStepper.__new__(AdaptiveStepper)
    s.ts = TrainStepConfig(
        sync="faithful", compressor=CompressorConfig(method="tqsgd", bits=3),
        bucket_mb=1.0,
        adaptive=AdaptiveConfig(warmup_steps=1, max_cached_steps=2,
                                switch_threshold=0.02))
    s.sizes = (1 << 15, 1 << 15)
    s.bits = (3, 3)
    s.plan = None
    s.tails = None
    built = []
    s._build = lambda bits: (("step", bits), None)
    s._cache = collections.OrderedDict()

    # LRU bound: three distinct plans, cache keeps the last two
    for bits in [(2, 2), (3, 3), (4, 4), (3, 3)]:
        fn = s._step_for(bits)
        assert fn == ("step", bits)
        built.append(bits)
    assert len(s._cache) == 2 and (2, 2) not in s._cache
    assert list(s._cache) == [(4, 4), (3, 3)]

    # First replan away from the bootstrap always adopts (plan is None)
    p1 = s.replan(stacked)
    assert s.bits == p1.bits and s.plan is p1
    assert p1.spend_bytes <= p1.budget_bytes
    # Current plan = starved (2,2): the solved plan's predicted error is far
    # lower, so hysteresis must ADOPT the switch.
    s.bits, s.plan = (2, 2), BitPlan((2, 2), (), 0, 0, err=0.0)
    p2 = s.replan(stacked)
    assert p2.bits != (2, 2) and s.bits == p2.bits
    # With a prohibitive threshold, a perturbed current plan is KEPT even
    # though the solver disagrees with it (no compile is worth <95% gain).
    s.ts = dataclasses_replace_adaptive(s.ts, switch_threshold=0.95)
    perturbed = tuple(max(2, b - 1) for b in p2.bits)
    assert perturbed != p2.bits
    s.bits, s.plan = perturbed, BitPlan(perturbed, (), 0, 0, err=p2.err)
    kept = s.replan(stacked)
    assert kept.bits == perturbed and s.bits == perturbed


def dataclasses_replace_adaptive(ts, **kw):
    import dataclasses

    return dataclasses.replace(ts, adaptive=dataclasses.replace(ts.adaptive, **kw))


def test_adaptive_train_step_end_to_end():
    """4-device host mesh: telemetry threads through the jitted step, the
    replan switches to a cached heterogeneous-bits step, loss decreases."""
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P, AxisType
from repro.configs import get_config, reduced
from repro.models import init_lm
from repro.data.synthetic import lm_batch
from repro.optim.optimizers import momentum_sgd
from repro.dist.train_step import TrainStepConfig
from repro.core.compressors import CompressorConfig
from repro.adaptive.controller import AdaptiveConfig
from repro.adaptive.runtime import AdaptiveStepper

mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
cfg = reduced(get_config("llama3.2-1b")).replace(fsdp=False)
params, logical = init_lm(jax.random.key(0), cfg)
opt = momentum_sgd(lr=0.05)
acfg = AdaptiveConfig(replan_every=2, warmup_steps=1, ema=0.8, min_bits=2, max_bits=6)
ts = TrainStepConfig(sync="faithful", compressor=CompressorConfig(method="tqsgd", bits=3),
                     bucket_mb=1.0, adaptive=acfg)
batch0 = lm_batch(cfg, jnp.uint32(0), 8, 64)
opt_state = opt.init(params)
stepper = AdaptiveStepper(cfg, mesh, logical, opt, ts, batch0,
                          opt_state_like=jax.eval_shape(lambda: opt_state),
                          params_like=params)
assert len(stepper.sizes) > 1, stepper.sizes
sh = jax.tree.map(lambda s: NamedSharding(mesh, s), stepper.pspecs,
                  is_leaf=lambda x: isinstance(x, P))
p = jax.device_put(params, sh)
o = jax.tree.map(jnp.zeros_like, p)
tstate = stepper.init_telemetry()
assert jax.tree.leaves(tstate)[0].shape[0] == 4  # one telemetry row per peer
losses = []
for i in range(5):
    p, o, _, tstate, m = stepper.step(p, o, None, tstate,
                                      lm_batch(cfg, jnp.uint32(i), 8, 64), i)
    losses.append(float(m["loss"][0]))
assert losses[-1] < losses[0], losses
plan = stepper.plan
assert plan is not None and len(plan.bits) == len(stepper.sizes)
assert plan.spend_bytes <= plan.budget_bytes
assert all(2 <= b <= 6 for b in plan.bits)
# steps counter advanced once per step on every peer
steps = jax.tree.leaves(tstate)[-1]
assert float(jnp.min(steps)) == 5.0, steps
# a changed plan gets its own compiled step in the cache
uniform = (3,) * len(stepper.sizes)
assert len(stepper._cache) == (2 if plan.bits != uniform else 1), stepper._cache.keys()
print("OK", losses, plan.bits)
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=1200, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "OK" in r.stdout
