"""Observability-layer tests: in-graph compression metrics vs the reference
replay, collective-count neutrality, the metrics shape contract, and the
host-side sinks/spans/drift/report machinery.

Multi-device cases run in subprocesses (fake host devices) so the main
pytest process keeps a single CPU device, mirroring ``test_dist.py``.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def run_with_devices(code, n=4, timeout=1500):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# In-graph metrics: bitwise pinning against the reference replay
# ---------------------------------------------------------------------------


def test_metrics_bitwise_vs_reference():
    """The CompressionMetrics pytree a (2,2) pod×data mesh emits must be
    bit-identical to the ``dist.reference`` replay (EF + adaptive telemetry
    on, so every metric input — stats, residuals, incoming EF — is live)."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P, AxisType
from repro.adaptive.controller import AdaptiveConfig
from repro.adaptive.telemetry import init_telemetry
from repro.core.compressors import CompressorConfig, plan_buckets
from repro.dist import reference, sharded_codec as sc, sharding
from repro.dist.train_step import TrainStepConfig, _make_sync_fn

mesh = jax.make_mesh((2, 2), ("pod", "data"), axis_types=(AxisType.Auto,) * 2)
dp = sharding.manual_axes(mesh)
dp_sizes = tuple(mesh.shape[a] for a in dp)
n = 4

ts = TrainStepConfig(sync="faithful", bucket_mb=1.0 / 64.0,
                     compressor=CompressorConfig(method="tnqsgd", bits=3),
                     error_feedback=True, adaptive=AdaptiveConfig(ema=0.9),
                     metrics_gnorm=True, metrics_compression=True)

leaf_shapes = [(64, 48), (37, 61), (2048,), (999,)]
key0 = jax.random.key(5)
leaves = [
    (jax.random.normal(jax.random.fold_in(key0, i), (n,) + s) * 0.05 * (i + 1)
     ).astype(jnp.float32)
    for i, s in enumerate(leaf_shapes)
]
BP = plan_buckets([int(np.prod(s)) for s in leaf_shapes], 4096)
st_sizes = sc.bucket_state_sizes(ts.compressor, BP.sizes, ts.bits_plan)
ef = [
    (jax.random.normal(jax.random.fold_in(key0, 100 + b), (n, st)) * 0.01
     ).astype(jnp.float32)
    for b, st in enumerate(st_sizes)
]
t0 = jax.tree.map(lambda x: jnp.tile(x[None], (n,) + (1,) * x.ndim),
                  init_telemetry(BP.n_buckets))
skey = jax.random.key(123)

pspecs = [P() for _ in leaves]
sync_fn = _make_sync_fn(ts, mesh, pspecs, list(leaves))
mean, new_ef, new_t, gnorm, cm = jax.jit(sync_fn)(list(leaves), skey, tuple(ef), t0)

w_mean, w_ef, w_t, w_cm = jax.jit(
    lambda key, t, ls, e: reference.reference_sync_state(
        ts, list(ls), dp_sizes, key, ef=list(e), tstate=t)
)(skey, t0, tuple(leaves), tuple(ef))

for f, got, want in zip(cm._fields, cm, w_cm):
    got = np.asarray(got)
    assert got.shape == (n, BP.n_buckets), (f, got.shape)
    np.testing.assert_array_equal(got, np.asarray(want), err_msg=f"metric {f}")
    assert np.all(np.isfinite(got)), (f, got)
# sanity semantics: realized >= 0, clip fraction in [0,1], positive wire
assert np.all(np.asarray(cm.realized_mse) >= 0.0)
assert np.all((np.asarray(cm.clip_frac) >= 0.0) & (np.asarray(cm.clip_frac) <= 1.0))
assert np.all(np.asarray(cm.wire_bytes) > 0.0)
assert np.all(np.asarray(cm.predicted_mse) > 0.0)
# the metrics ride-along must not perturb the sync outputs themselves
for g, w in zip(mean, w_mean):
    np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
print("ALL_OK")
""")
    assert "ALL_OK" in out


def test_collective_count_unchanged():
    """Enabling ``metrics_compression`` must not change the traced collective
    count on a model-sharded mesh: the metric sums share the one gnorm psum.
    (With ``metrics_gnorm=False`` there is no psum to fuse with; the metrics
    then cost exactly one — pinned here so it cannot silently grow.)"""
    out = run_with_devices("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, AxisType
from repro.analysis.jaxpr_lint import count_collectives
from repro.core.compressors import CompressorConfig
from repro.dist.train_step import TrainStepConfig, _make_sync_fn

mesh = jax.make_mesh((2, 2), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
leaf_shapes = [(64, 48), (2048,), (999,)]
leaves = [jnp.ones((2,) + s, jnp.float32) for s in leaf_shapes]
pspecs = [P() for _ in leaves]
skey = jax.random.key(0)


def counts(sync, metrics_compression, metrics_gnorm=True):
    ts = TrainStepConfig(sync=sync, bucket_mb=1.0 / 64.0,
                         compressor=CompressorConfig(method="tnqsgd", bits=3),
                         metrics_gnorm=metrics_gnorm,
                         metrics_compression=metrics_compression)
    fn = _make_sync_fn(ts, mesh, pspecs, list(leaves))
    return count_collectives(jax.make_jaxpr(fn)(list(leaves), skey))


for sync in ("dsgd", "two_phase", "hierarchical", "faithful"):
    off, on = counts(sync, False), counts(sync, True)
    assert on == off, (sync, dict(off), dict(on))
    off_ng, on_ng = counts(sync, False, metrics_gnorm=False), counts(sync, True, metrics_gnorm=False)
    delta = on_ng - off_ng
    assert dict(delta) in ({}, {"psum": 1}), (sync, dict(delta))
print("ALL_OK")
""")
    assert "ALL_OK" in out


def test_metrics_contract_shapes():
    """Pin the ``make_train_step`` metrics contract (documented in its
    docstring): ``metrics["loss"]`` is always ``(n_dp,)`` float32 under every
    sync mode; ``gnorm`` matches it and appears iff ``metrics_gnorm``;
    compression leaves are ``(n_dp, n_buckets)``."""
    out = run_with_devices("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P, AxisType
from repro.configs import get_config, reduced
from repro.models import init_lm
from repro.data.synthetic import lm_batch
from repro.optim.optimizers import momentum_sgd
from repro.dist.train_step import make_train_step, TrainStepConfig
from repro.core.compressors import CompressorConfig

mesh = jax.make_mesh((2, 2), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
cfg = reduced(get_config("llama3.2-1b")).replace(fsdp=True)
params0, logical = init_lm(jax.random.key(0), cfg)
opt = momentum_sgd(lr=0.05)
batch = lm_batch(cfg, jnp.uint32(0), 8, 128)
n_dp = 2

cases = [("dsgd", True, False), ("two_phase", True, False),
         ("hierarchical", False, False), ("faithful", True, True)]
for sync, gnorm, comp in cases:
    ts = TrainStepConfig(sync=sync, compressor=CompressorConfig(method="tnqsgd", bits=4),
                         metrics_gnorm=gnorm, metrics_compression=comp)
    step_fn, pspecs = make_train_step(cfg, mesh, logical, opt, ts, batch)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P))
    p = jax.device_put(jax.tree.map(jnp.copy, params0), sh)
    o = jax.tree.map(jnp.zeros_like, p)
    p, o, m = step_fn(p, o, batch, jnp.uint32(0))
    assert m["loss"].shape == (n_dp,) and m["loss"].dtype == jnp.float32, (sync, m["loss"])
    assert ("gnorm" in m) == gnorm, (sync, sorted(m))
    if gnorm:
        assert m["gnorm"].shape == (n_dp,) and m["gnorm"].dtype == jnp.float32
    assert ("compression" in m) == comp, (sync, sorted(m))
    if comp:
        cm = m["compression"]
        B = cm.bits.shape[-1]
        assert B >= 1
        for f, leaf in zip(cm._fields, cm):
            assert leaf.shape == (n_dp, B), (sync, f, leaf.shape)
    print("OK", sync)
print("ALL_OK")
""", timeout=1800)
    assert "ALL_OK" in out


# ---------------------------------------------------------------------------
# Predicted-vs-realized calibration (no devices: reference replay)
# ---------------------------------------------------------------------------


def test_qsgd_realized_tracks_predicted():
    """On Gaussian gradients the realized qsgd quantization MSE must be
    non-negative and within a small constant factor of the predicted E_TQ
    (both ≈ α²/s² scalings; the band absorbs the histogram tail fit)."""
    import jax
    import jax.numpy as jnp

    from repro.core.compressors import CompressorConfig
    from repro.dist.reference import reference_sync_state
    from repro.dist.train_step import TrainStepConfig

    ts = TrainStepConfig(sync="faithful", bucket_mb=1.0 / 64.0,
                         compressor=CompressorConfig(method="qsgd", bits=4),
                         error_feedback=True, metrics_compression=True)
    n = 2
    key0 = jax.random.key(7)
    leaves = [(jax.random.normal(jax.random.fold_in(key0, i), (n, 4096)) * 0.1
               ).astype(jnp.float32) for i in range(2)]
    _, _, _, cm = jax.jit(lambda k, ls: reference_sync_state(ts, list(ls), (n,), k)
                          )(jax.random.key(3), tuple(leaves))
    realized = np.asarray(cm.realized_mse)
    predicted = np.asarray(cm.predicted_mse)
    assert np.all(realized >= 0.0)
    assert np.all(predicted > 0.0)
    ratio = realized / predicted
    assert np.all((ratio > 0.2) & (ratio < 5.0)), ratio


# ---------------------------------------------------------------------------
# Host-side machinery: sink, spans, drift, report
# ---------------------------------------------------------------------------


def _fake_metrics(bits=4, realized=1.0, predicted=1.0):
    from repro.obs import CompressionMetrics
    mk = lambda v, dt=np.float32: np.asarray([[v]], dt)
    return CompressionMetrics(
        bits=mk(bits, np.int32), rank=mk(0, np.int32), alpha=mk(0.5),
        clip_frac=mk(0.01), ef_norm=mk(0.2), wire_bytes=mk(128.0),
        realized_mse=mk(realized), predicted_mse=mk(predicted))


def test_jsonl_sink_roundtrip_and_warnings(tmp_path, capsys):
    from repro.obs import JsonlSink, metrics_event, read_events

    path = tmp_path / "events.jsonl"
    with JsonlSink(path, flush_every=2) as sink:
        for step in range(3):
            sink.write(metrics_event(step, _fake_metrics(realized=float(step))))
    assert sink.n_written == 3
    # corrupt the log: one malformed line, one schema-versioned stranger
    with path.open("a") as fh:
        fh.write("{not json\n")
        fh.write(json.dumps({"v": 99, "kind": "metrics"}) + "\n")
    events = read_events(tmp_path)
    err = capsys.readouterr().err
    assert len(events) == 3
    assert "malformed" in err and str(path) in err
    assert "unknown schema" in err
    assert events[0]["buckets"][0]["bits"] == 4
    assert events[2]["buckets"][0]["realized_mse"] == 2.0


def test_ema_and_csv_export(tmp_path):
    from repro.obs import EmaAggregator, export_csv, metrics_event

    events = [metrics_event(i, _fake_metrics(realized=float(i))) for i in range(4)]
    ema = EmaAggregator(decay=0.5)
    for ev in events:
        ema.update(ev)
    rows = ema.summary()
    assert len(rows) == 1 and rows[0]["bucket"] == 0
    # EMA of 0,1,2,3 at decay .5: 0, .5, 1.25, 2.125
    assert rows[0]["realized_mse"] == pytest.approx(2.125)
    csv_path = tmp_path / "metrics.csv"
    assert export_csv(events, csv_path) == 4
    lines = csv_path.read_text().strip().splitlines()
    assert lines[0].startswith("step,bucket,bits,")
    assert len(lines) == 5


def test_span_recorder(tmp_path):
    from repro.obs import JsonlSink, SpanRecorder, read_events

    ticks = iter([0.0, 1.0, 2.0, 2.25])
    sink = JsonlSink(tmp_path / "spans.jsonl", flush_every=1)
    rec = SpanRecorder(sink=sink, clock=lambda: next(ticks))
    with rec.span("train.step", step=0):
        pass
    with rec.span("train.step", step=1):
        pass
    s = rec.summary()["train.step"]
    assert s["count"] == 2
    assert s["total_s"] == pytest.approx(1.25)
    assert s["max_s"] == pytest.approx(1.0)
    evs = read_events(tmp_path / "spans.jsonl")
    assert [e["kind"] for e in evs] == ["span", "span"]
    assert evs[1]["dur_s"] == pytest.approx(0.25) and evs[1]["step"] == 1


def test_drift_monitor_warns(tmp_path):
    from repro.core.distributions import GAMMA_MAX, GAMMA_MIN
    from repro.obs import DriftMonitor, JsonlSink, ObsDriftWarning, read_events

    sink = JsonlSink(tmp_path / "drift.jsonl", flush_every=1)
    mon = DriftMonitor(sink=sink, ratio_threshold=4.0)
    with pytest.warns(ObsDriftWarning, match="railed outside the power-law"):
        evs = mon.check_tails([GAMMA_MIN, 4.0, GAMMA_MAX], step=10)
    assert [e.bucket for e in evs] == [0, 2]
    with pytest.warns(ObsDriftWarning, match="ratio"):
        evs = mon.check_ratio([10.0, 1.0, 5.0], [1.0, 1.0, 0.0], step=11)
    assert [e.bucket for e in evs] == [0]  # bucket 2 has no prediction: skipped
    assert len(mon.events) == 3
    assert [e["drift"] for e in read_events(tmp_path / "drift.jsonl")] == [
        "tail_regime", "tail_regime", "error_ratio"]
    quiet = DriftMonitor(warn=False)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        quiet.check_tails([GAMMA_MIN])
    assert len(quiet.events) == 1


def test_report_summarize_and_cli(tmp_path, capsys):
    from repro.obs import JsonlSink, metrics_event, span_event
    from repro.obs.report import bucket_table, main, phase_table, summarize

    with JsonlSink(tmp_path / "events.jsonl") as sink:
        for i in range(3):
            sink.write(metrics_event(i, _fake_metrics(realized=5.0, predicted=1.0)))
            sink.write(span_event("train.step", 0.0, 0.1, step=i))
    events_dir = str(tmp_path)
    rc = main(["--dir", events_dir, "--json", str(tmp_path / "OBS.json"),
               "--csv", str(tmp_path / "rows.csv")])
    out = capsys.readouterr().out
    assert rc == 0
    assert "DRIFT" in out  # ratio 5 > default threshold 2
    assert "Phase breakdown" in out
    summary = json.loads((tmp_path / "OBS.json").read_text())
    assert summary["version"] == 1 and summary["n_steps"] == 3
    assert summary["flagged"] == [0]
    assert summary["phases"][0]["name"] == "train.step"
    assert summary["phases"][0]["count"] == 3
    assert (tmp_path / "rows.csv").exists()
    # rendered tables are well-formed markdown with one row per bucket/phase
    assert bucket_table(summary).count("\n") == 2
    assert phase_table(summary).count("\n") == 2
    assert main(["--dir", str(tmp_path / "empty")]) == 1


def test_launch_report_load_warns_on_malformed(tmp_path, capsys):
    """Satellite: ``launch.report.load`` must name unreadable records instead
    of silently swallowing them."""
    from repro.launch.report import load

    good = tmp_path / "a__train_4k__16x16.json"
    good.write_text(json.dumps({"arch": "a"}))
    bad = tmp_path / "b__train_4k__16x16.json"
    bad.write_text("{broken")
    recs = load(tmp_path)
    err = capsys.readouterr().err
    assert recs == [{"arch": "a"}]
    assert "warning" in err and str(bad) in err
