"""Property test for the α fixed-point solvers (paper Eq. 12 / Eq. 19):
the solved threshold must land within tolerance of the argmin of the
closed-form ``theory.e_tq_*`` error over a dense α grid, across a sweep of
tail indices γ, tail masses ρ, and bit widths."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import distributions as D
from repro.core import optimal as O
from repro.core import theory as Th
from repro.core.distributions import PowerLawTail

UNIFORM_CASES = [
    (3.3, 0.25, 2),
    (3.6, 0.15, 3),
    (4.2, 0.10, 4),
    (4.8, 0.05, 3),
    (4.0, 0.20, 5),
]


def _grid(lo: float, hi: float, n: int = 600) -> jax.Array:
    return jnp.exp(jnp.linspace(jnp.log(lo), jnp.log(hi), n))


@pytest.mark.parametrize("gamma,rho,bits", UNIFORM_CASES)
def test_solve_alpha_uniform_matches_grid_argmin(gamma, rho, bits):
    """Eq. 12's fixed point is (near-)exact for the uniform scheme: the
    solver's error is within 2% of the dense-grid minimum of Eq. 11 and the
    threshold itself within ~25% of the grid argmin."""
    tail = PowerLawTail(gamma=jnp.float32(gamma), g_min=jnp.float32(0.01),
                        rho=jnp.float32(rho), g_max=jnp.float32(30.0))
    grid = _grid(0.01, 30.0)
    errs = jax.vmap(lambda a: Th.e_tq_uniform(tail, a, bits))(grid)
    i = int(jnp.argmin(errs))
    assert 0 < i < grid.size - 1, "grid argmin must be interior"
    a_star, e_star = float(grid[i]), float(errs[i])
    a_sol = float(O.solve_alpha_uniform(tail, bits))
    e_sol = float(Th.e_tq_uniform(tail, jnp.float32(a_sol), bits))
    assert e_sol <= 1.02 * e_star, (e_sol, e_star)
    assert 0.8 <= a_sol / a_star <= 1.25, (a_sol, a_star)


@pytest.mark.parametrize("gamma,rho,bits", [(3.4, 0.2, 3), (4.0, 0.1, 4), (4.5, 0.15, 3)])
def test_solve_alpha_nonuniform_matches_grid_argmin(gamma, rho, bits):
    """Eq. 19 optimizes the Theorem-2 bound, not the exact integral, so the
    tolerance is looser: the solver's e_tq_nonuniform stays within 30% of
    the dense-grid minimum (and far from the boundary blow-ups)."""
    g = D.sample_power_law(jax.random.key(int(gamma * 10)), (200_000,),
                           gamma=gamma, g_min=0.01, rho=rho)
    tail = D.fit_power_law_tail(g)
    dens = D.fit_empirical_density(g)
    grid = _grid(float(tail.g_min), float(tail.g_max), 400)
    errs = jax.vmap(lambda a: Th.e_tq_nonuniform(tail, dens, a, bits))(grid)
    i = int(jnp.argmin(errs))
    assert 0 < i < grid.size - 1, "grid argmin must be interior"
    e_star = float(errs[i])
    a_sol = float(O.solve_alpha_nonuniform(tail, dens, bits))
    e_sol = float(Th.e_tq_nonuniform(tail, dens, jnp.float32(a_sol), bits))
    assert e_sol <= 1.3 * e_star, (e_sol, e_star)


def test_e_tq_nonuniform_below_uniform_at_common_alpha():
    """Hölder ordering carried into the error model: at the same α and bits,
    the λ ∝ p^(1/3) codebook's variance term never exceeds the uniform one."""
    g = D.sample_power_law(jax.random.key(7), (200_000,), gamma=3.8, g_min=0.01, rho=0.15)
    tail = D.fit_power_law_tail(g)
    dens = D.fit_empirical_density(g)
    for bits in (2, 3, 4):
        a = O.solve_alpha_uniform(tail, bits)
        e_n = float(Th.e_tq_nonuniform(tail, dens, a, bits))
        e_u = float(Th.e_tq_uniform(tail, a, bits))
        assert e_n <= e_u * 1.02, (bits, e_n, e_u)
