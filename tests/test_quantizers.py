"""Quantizer-core tests: Lemma 1 (unbiasedness + variance bound), truncation,
codebooks, bit packing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CompressorConfig,
    QuantMeta,
    compress_decompress,
    sample_power_law,
    truncate,
)
from repro.core.compressors import plan
from repro.core.quantizers import (
    levels_from_density,
    num_levels,
    pack_codes,
    quantize,
    stochastic_encode,
    uniform_levels,
    unpack_codes,
)


@pytest.fixture(scope="module")
def heavy_tailed():
    return sample_power_law(jax.random.key(0), (50_000,), gamma=4.0, g_min=0.01, rho=0.1)


def test_truncation_operator(heavy_tailed):
    alpha = jnp.float32(0.05)
    t = truncate(heavy_tailed, alpha)
    assert float(jnp.max(jnp.abs(t))) <= 0.05 + 1e-7
    # identity inside the range
    inside = jnp.abs(heavy_tailed) <= 0.05
    np.testing.assert_array_equal(np.asarray(t[inside]), np.asarray(heavy_tailed[inside]))
    # idempotent
    np.testing.assert_array_equal(np.asarray(truncate(t, alpha)), np.asarray(t))


def test_unbiasedness_lemma1(heavy_tailed):
    """E[Q[g]] == T_alpha(g) (Lemma 1, Eq. 5)."""
    g = heavy_tailed[:4000]
    meta = plan(CompressorConfig(method="tnqsgd", bits=3), g)
    reps = jnp.stack([quantize(g, meta, jax.random.key(i)) for i in range(200)])
    gt = truncate(g, meta.alpha)
    err = jnp.abs(reps.mean(0) - gt)
    step = jnp.mean(jnp.diff(meta.levels))
    # mean of 200 draws: std ~ step/sqrt(200); allow 5 sigma
    assert float(jnp.max(err)) < 5 * float(step) / np.sqrt(200)


def test_variance_bound_lemma1(heavy_tailed):
    """E|Q[g]-g|^2 <= sum_k P_k |Delta_k|^2 / 4 (Lemma 1, Eq. 6)."""
    g = heavy_tailed[:20_000]
    for method in ("tqsgd", "tnqsgd", "tbqsgd"):
        meta = plan(CompressorConfig(method=method, bits=3), g)
        gt = truncate(g, meta.alpha)
        qs = jnp.stack([quantize(g, meta, jax.random.key(i)) for i in range(50)])
        emp_var = float(jnp.mean((qs - gt[None]) ** 2))
        # bound: every point's interval length <= max step -> P-weighted bound
        k = jnp.clip(jnp.searchsorted(meta.levels, gt, side="right") - 1, 0, meta.levels.shape[0] - 2)
        delta = meta.levels[k + 1] - meta.levels[k]
        bound = float(jnp.mean(delta**2 / 4.0))
        assert emp_var <= bound * 1.05, (method, emp_var, bound)


def test_uniform_levels_match_qsgd(heavy_tailed):
    """lambda = s/2alpha must reproduce QSGD's evenly spaced codebook."""
    alpha = jnp.float32(0.1)
    lv = uniform_levels(alpha, 3)
    assert lv.shape == (8,)
    np.testing.assert_allclose(np.diff(np.asarray(lv)), 2 * 0.1 / 7, rtol=1e-5)


def test_levels_from_density_uniform_case():
    """Flat density -> uniform codebook."""
    edges = jnp.linspace(0.0, 1.0, 65)
    lam = jnp.ones((64,))
    lv = levels_from_density(edges, lam, 3)
    np.testing.assert_allclose(np.diff(np.asarray(lv)), 2 / 7, atol=1e-3)
    assert float(lv[0]) == -1.0 and float(lv[-1]) == 1.0


def test_levels_monotone_under_spiky_density():
    edges = jnp.linspace(0.0, 1.0, 33)
    lam = jnp.zeros((32,)).at[3].set(100.0)
    lv = levels_from_density(edges, lam, 4)
    assert bool(jnp.all(jnp.diff(lv) > 0))


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 5, 8])
def test_pack_unpack_roundtrip(bits):
    n = 1000
    codes = jax.random.randint(jax.random.key(bits), (n,), 0, 2**bits).astype(jnp.uint8)
    words = pack_codes(codes, bits)
    assert words.dtype == jnp.uint32
    assert words.size == ((n + 31) // 32) * bits
    back = unpack_codes(words, n, bits)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_encode_codes_in_range(heavy_tailed):
    for method in ("qsgd", "tqsgd", "tnqsgd", "tbqsgd"):
        cfg = CompressorConfig(method=method, bits=3)
        meta = plan(cfg, heavy_tailed)
        codes = stochastic_encode(heavy_tailed, meta, jax.random.key(1))
        assert int(codes.max()) <= num_levels(3)
        assert int(codes.min()) >= 0


def test_compress_decompress_within_alpha(heavy_tailed):
    for method in ("tqsgd", "tnqsgd", "tbqsgd"):
        cfg = CompressorConfig(method=method, bits=3)
        meta = plan(cfg, heavy_tailed)
        out = compress_decompress(cfg, heavy_tailed, jax.random.key(2))
        assert float(jnp.max(jnp.abs(out))) <= float(meta.alpha) * (1 + 1e-5)


def test_decode_rejects_mismatched_wire(heavy_tailed):
    """A wire whose packed length disagrees with shape/bits must raise, not
    silently truncate through unpack_codes."""
    from repro.core.compressors import decode, encode

    g = heavy_tailed[:1000]
    cfg = CompressorConfig(method="tqsgd", bits=3)
    meta = plan(cfg, g)
    wire = encode(cfg, g, meta, jax.random.key(0))
    np.testing.assert_array_equal(
        np.asarray(decode(cfg, wire, meta, g.shape)),
        np.asarray(decode(cfg, wire, meta, g.shape)))  # correct wire round-trips
    with pytest.raises(ValueError, match="packed uint32 words"):
        decode(cfg, wire[:-1], meta, g.shape)          # truncated wire
    with pytest.raises(ValueError, match="packed uint32 words"):
        decode(cfg, jnp.concatenate([wire, wire[:3]]), meta, g.shape)  # oversized
    with pytest.raises(ValueError, match="packed uint32 words"):
        # right wire, wrong claimed element count
        decode(cfg, wire, meta, (900,))
    cfg_u = CompressorConfig(method="tqsgd", bits=3, pack=False)
    codes = encode(cfg_u, g, meta, jax.random.key(0))
    with pytest.raises(ValueError, match="unpacked wire"):
        decode(cfg_u, codes[:-1], meta, g.shape)


def test_dsgd_identity(heavy_tailed):
    cfg = CompressorConfig(method="dsgd")
    np.testing.assert_array_equal(
        np.asarray(compress_decompress(cfg, heavy_tailed, jax.random.key(0))),
        np.asarray(heavy_tailed),
    )
