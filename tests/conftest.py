"""Shared pytest wiring.

``--interpret`` flips the codec tests onto the Pallas-kernel dispatch branch
(``CompressorConfig.use_pallas=True``; on CPU the kernels execute in
interpret mode).  Tier-1 runs without it and exercises the shard_map-safe
jnp fallbacks; the CI ``kernels-interpret`` job runs with it so both decode
dispatch branches are covered on every PR.  The option is exported through
``REPRO_TEST_USE_PALLAS`` so the subprocess-based distributed tests inherit
it.

When hypothesis is installed (the ``dev`` extra; it is not in the pinned
runtime deps) a derandomized "ci" profile registers here and activates under
``CI=true``, so the property tests in ``tests/test_properties.py`` are
reproducible across CI runs instead of sampling fresh examples per run.
Local runs keep hypothesis's default randomized profile.
"""
import contextlib
import os

import pytest

# hypothesis ships via the dev extra only; tier-1 must run without it
with contextlib.suppress(ImportError):
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", derandomize=True, deadline=None,
                                   print_blob=True)
    if os.environ.get("CI"):
        _hyp_settings.load_profile("ci")


def pytest_addoption(parser):
    parser.addoption(
        "--interpret", action="store_true", default=False,
        help="run codec paths with use_pallas=True (interpret-mode kernels off-TPU)")


def pytest_configure(config):
    if config.getoption("--interpret"):
        os.environ["REPRO_TEST_USE_PALLAS"] = "1"


@pytest.fixture
def use_pallas() -> bool:
    return os.environ.get("REPRO_TEST_USE_PALLAS", "0") not in ("", "0")
