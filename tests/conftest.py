"""Shared pytest wiring.

``--interpret`` flips the codec tests onto the Pallas-kernel dispatch branch
(``CompressorConfig.use_pallas=True``; on CPU the kernels execute in
interpret mode).  Tier-1 runs without it and exercises the shard_map-safe
jnp fallbacks; the CI ``kernels-interpret`` job runs with it so both decode
dispatch branches are covered on every PR.  The option is exported through
``REPRO_TEST_USE_PALLAS`` so the subprocess-based distributed tests inherit
it.
"""
import os

import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--interpret", action="store_true", default=False,
        help="run codec paths with use_pallas=True (interpret-mode kernels off-TPU)")


def pytest_configure(config):
    if config.getoption("--interpret"):
        os.environ["REPRO_TEST_USE_PALLAS"] = "1"


@pytest.fixture
def use_pallas() -> bool:
    return os.environ.get("REPRO_TEST_USE_PALLAS", "0") not in ("", "0")
