"""PowerSGD codec + Gram–Schmidt orthogonalization kernel.

Pins the codec contract the registry exposes to the bucketed collectives:
the Pallas orthogonalization kernel is bit-exact against the shared-body
``kernels.ref`` oracle (interpret mode executes the identical op
sequence), the factor wire round-trips, warm-starting the power iteration
tightens the approximation, and the EF residual is exactly the
reconstruction error.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lowrank
from repro.core.codecs import bucket_cfg_entry, get_codec, known_methods
from repro.core.compressors import CompressorConfig, wire_bytes
from repro.kernels import ops, ref

SHAPES = [(8, 4), (128, 8), (1000, 3), (7, 7), (513, 16)]


def _tall(key, rows, cols):
    return jax.random.normal(key, (rows, cols), jnp.float32)


@pytest.mark.parametrize("shape", SHAPES)
def test_orthogonalize_kernel_matches_ref(shape):
    p = _tall(jax.random.key(1), *shape)
    got = ops.orthogonalize(p)
    want = ref.orthogonalize(p)
    # shared loop body + interpret mode: agreement to fusion-level rounding
    # (XLA may fuse the dot-product reductions differently inside the
    # interpreted pallas_call), pinned at float32 ULP scale
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_orthogonalize_orthonormal(shape):
    rows, cols = shape
    p = _tall(jax.random.key(2), rows, cols)
    q = np.asarray(ref.orthogonalize(p))
    r = min(rows, cols)
    gram = q.T @ q
    np.testing.assert_allclose(gram[:r, :r], np.eye(r), atol=2e-3)
    # the span is preserved: projecting p onto q reproduces p
    np.testing.assert_allclose(q @ (q.T @ np.asarray(p)), np.asarray(p),
                               rtol=2e-2, atol=2e-2)


def test_orthogonalize_zero_columns_stay_zero():
    p = jnp.zeros((64, 4), jnp.float32).at[:, 0].set(1.0)
    q = np.asarray(ref.orthogonalize(p))
    assert np.all(np.isfinite(q))
    np.testing.assert_array_equal(q[:, 1:], 0.0)


def test_matrix_shape_static_properties():
    for m in (1, 2, 3, 31, 32, 999, 3072, 2257, 1 << 20):
        rows, cols = lowrank.matrix_shape(m)
        assert rows * cols >= m
        assert cols & (cols - 1) == 0  # power of two
        assert (rows - 1) * cols < m   # no wasted full row
    assert lowrank.matrix_shape(1) == (1, 1)


def test_registry_exposes_powersgd():
    assert "powersgd" in known_methods()
    codec = get_codec("powersgd")
    assert codec.rank_based and not codec.chunkable
    cfg = bucket_cfg_entry(CompressorConfig(method="tnqsgd", bits=3),
                           ("powersgd", 4))
    assert cfg.method == "powersgd" and cfg.rank == 4
    m = 3072
    rows, cols = lowrank.matrix_shape(m)
    assert codec.wire_words(cfg, m) == (rows + cols) * 4
    assert codec.state_extra(cfg, m) == cols * 4
    assert wire_bytes(cfg, m) == 4 * (rows + cols) * 4


def test_encode_decode_roundtrip_and_residual():
    cfg = CompressorConfig(method="powersgd", rank=4)
    codec = get_codec("powersgd")
    m = 3072
    flat = jax.random.normal(jax.random.key(3), (m,), jnp.float32)
    wire, resid, aux = codec.encode_residual(cfg, flat, None, jax.random.key(0),
                                             False, aux=None)
    assert wire.dtype == jnp.uint32 and wire.size == codec.wire_words(cfg, m)
    assert aux.size == codec.state_extra(cfg, m)
    own = codec.decode_reduce(cfg, wire[None], m, False)
    # EF residual is exactly the own-reconstruction error
    np.testing.assert_allclose(np.asarray(resid), np.asarray(flat - own),
                               rtol=1e-5, atol=1e-5)
    # decode_rows stacks per-peer reconstructions consistently
    rows2 = codec.decode_rows(cfg, jnp.stack([wire, wire]), m, False)
    np.testing.assert_array_equal(np.asarray(rows2[0]), np.asarray(rows2[1]))
    np.testing.assert_allclose(np.asarray(rows2[0]), np.asarray(own), rtol=1e-6)


def test_rank_captures_low_rank_signal():
    """A genuinely rank-2 bucket is reconstructed near-exactly at rank >= 2."""
    rows, cols = 64, 32
    k1, k2 = jax.random.split(jax.random.key(4))
    mat = _tall(k1, rows, 2) @ _tall(k2, cols, 2).T
    flat = mat.reshape(-1)
    cfg = CompressorConfig(method="powersgd", rank=8)
    codec = get_codec("powersgd")
    # two warm-started iterations converge onto the 2-dim subspace
    wire, resid, aux = codec.encode_residual(cfg, flat, None, jax.random.key(0),
                                             False, aux=None)
    err_cold = float(jnp.sum(resid * resid))
    wire, resid, aux = codec.encode_residual(cfg, flat, None, jax.random.key(0),
                                             False, aux=aux)
    err_warm = float(jnp.sum(resid * resid))
    total = float(jnp.sum(flat * flat))
    assert err_warm <= err_cold + 1e-6
    assert err_warm < 1e-4 * total


def test_warm_start_tracks_subspace_better_than_cold():
    """On a slowly-rotating low-rank gradient stream, carrying Q beats
    restarting from Q0 every step (the point of the EF-state aux tail)."""
    rows, cols, r = 128, 64, 2
    base_p = _tall(jax.random.key(5), rows, r)
    base_q = _tall(jax.random.key(6), cols, r)
    noise_k = jax.random.key(7)
    cfg = CompressorConfig(method="powersgd", rank=r)
    codec = get_codec("powersgd")

    def stream(step):
        nk = jax.random.fold_in(noise_k, step)
        drift = 0.02 * step
        return ((base_p + drift * jax.random.normal(nk, base_p.shape))
                @ base_q.T).reshape(-1)

    warm_aux, warm_errs, cold_errs = None, [], []
    for i in range(6):
        flat = stream(i)
        _, res_w, warm_aux = codec.encode_residual(
            cfg, flat, None, jax.random.key(0), False, aux=warm_aux)
        _, res_c, _ = codec.encode_residual(
            cfg, flat, None, jax.random.key(0), False, aux=None)
        warm_errs.append(float(jnp.sum(res_w * res_w)))
        cold_errs.append(float(jnp.sum(res_c * res_c)))
    assert sum(warm_errs[1:]) <= sum(cold_errs[1:])


def test_zero_aux_means_cold_start():
    """A freshly-initialized (all-zero) EF aux tail must not poison Q."""
    cfg = CompressorConfig(method="powersgd", rank=4)
    codec = get_codec("powersgd")
    m = 999
    flat = jax.random.normal(jax.random.key(8), (m,), jnp.float32)
    zero_aux = jnp.zeros((codec.state_extra(cfg, m),), jnp.float32)
    w0, r0, _ = codec.encode_residual(cfg, flat, None, jax.random.key(0),
                                      False, aux=None)
    wz, rz, _ = codec.encode_residual(cfg, flat, None, jax.random.key(0),
                                      False, aux=zero_aux)
    np.testing.assert_array_equal(np.asarray(w0), np.asarray(wz))
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(rz))


def test_effective_rank_clamps_to_matrix():
    cfg = CompressorConfig(method="powersgd", rank=64)
    assert lowrank.effective_rank(cfg, 9) == 2        # (5, 2) matrix
    assert lowrank.effective_rank(cfg, 1) == 1
    assert lowrank.effective_rank(CompressorConfig(method="powersgd", rank=4),
                                  1 << 20) == 4
