"""Hypothesis property-based tests for the quantization core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not in the pinned environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import CompressorConfig
from repro.core.compressors import decode, encode, plan
from repro.core.quantizers import (
    num_levels,
    pack_codes,
    stochastic_encode,
    truncate,
    unpack_codes,
)

METHODS = ("qsgd", "nqsgd", "tqsgd", "tnqsgd", "tbqsgd")


def _gradients(draw, n):
    """Random heavy-ish tensors with varied scale (avoids all-zero)."""
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.floats(1e-3, 1e3))
    kind = draw(st.sampled_from(["normal", "cauchy", "exp"]))
    key = jax.random.key(seed)
    if kind == "normal":
        g = jax.random.normal(key, (n,))
    elif kind == "cauchy":
        g = jax.random.cauchy(key, (n,))
    else:
        g = jax.random.exponential(key, (n,)) * jnp.where(
            jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (n,)), 1.0, -1.0
        )
    return (g * scale).astype(jnp.float32)


@settings(max_examples=20, deadline=None)
@given(data=st.data(), method=st.sampled_from(METHODS), bits=st.integers(2, 5))
def test_roundtrip_bounded_and_in_codebook(data, method, bits):
    g = _gradients(data.draw, 512)
    cfg = CompressorConfig(method=method, bits=bits)
    meta = plan(cfg, g)
    wire = encode(cfg, g, meta, jax.random.key(0))
    out = decode(cfg, wire, meta, g.shape)
    # decoded values live on the codebook
    dists = jnp.min(jnp.abs(out[:, None] - meta.levels[None, :]), axis=1)
    assert float(jnp.max(dists)) < 1e-4 * max(float(meta.alpha), 1e-6) + 1e-6
    # and within [-alpha, alpha]
    assert float(jnp.max(jnp.abs(out))) <= float(meta.alpha) * (1 + 1e-5)


@settings(max_examples=20, deadline=None)
@given(data=st.data(), bits=st.integers(1, 8))
def test_pack_roundtrip_property(data, bits):
    n = data.draw(st.integers(1, 700))
    codes = np.asarray(
        jax.random.randint(jax.random.key(n), (n,), 0, 2**bits), dtype=np.uint8
    )
    back = unpack_codes(pack_codes(jnp.asarray(codes), bits), n, bits)
    np.testing.assert_array_equal(np.asarray(back), codes)


@settings(max_examples=15, deadline=None)
@given(data=st.data(), method=st.sampled_from(("tqsgd", "tnqsgd")))
def test_wire_budget_respected(data, method):
    """Payload bits per element never exceed bits + packing slack."""
    bits = data.draw(st.integers(2, 5))
    n = data.draw(st.integers(64, 2048))
    g = _gradients(data.draw, n)
    cfg = CompressorConfig(method=method, bits=bits)
    meta = plan(cfg, g)
    wire = encode(cfg, g, meta, jax.random.key(1))
    payload_bits = wire.size * 32
    # padding to 32-code groups is the only slack
    assert payload_bits <= (n + 31) // 32 * 32 * bits


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_truncation_contracts(data):
    g = _gradients(data.draw, 256)
    alpha = data.draw(st.floats(1e-4, 1e2))
    t = truncate(g, jnp.float32(alpha))
    # contraction: |T(g)| <= |g| and <= alpha
    assert bool(jnp.all(jnp.abs(t) <= jnp.abs(g) + 1e-9))
    assert float(jnp.max(jnp.abs(t))) <= alpha * (1 + 1e-6)


@settings(max_examples=8, deadline=None)
@given(data=st.data(), method=st.sampled_from(("tqsgd", "tnqsgd")), bits=st.integers(2, 4))
def test_fused_decode_reduce_unbiased(data, method, bits):
    """The fused decode-reduce is an unbiased estimator of the peer mean.

    For random per-peer codebooks/codes, the mean of the fused kernel output
    over independent RNG draws approaches the analytic expectation — the
    mean of the peers' *truncated* tensors (Lemma 1 unbiasedness survives
    the unpack→dequant→reduce fusion) — within a 5σ concentration bound:
    per element, Var ≤ Δ²/4 per peer draw, so the R-draw, n-peer mean has
    std ≤ Δmax / (2·sqrt(R·n)).  A deterministic fixed-seed twin lives in
    ``test_decode_kernels.py`` so the bias net stays live under the pinned
    CI deps (which do not include hypothesis).
    """
    from repro.kernels import ops as kops

    n_peers = data.draw(st.integers(2, 5))
    m = 192
    g = _gradients(data.draw, n_peers * m).reshape(n_peers, m)
    cfg = CompressorConfig(method=method, bits=bits)
    metas = [plan(cfg, g[p]) for p in range(n_peers)]
    levels = jnp.stack([mt.levels for mt in metas])
    target = jnp.mean(
        jnp.stack([truncate(g[p], metas[p].alpha) for p in range(n_peers)]), axis=0)
    R = 48
    outs = []
    for r in range(R):
        words = jnp.stack([
            pack_codes(stochastic_encode(g[p], metas[p], jax.random.key(r * 131 + p)), bits)
            for p in range(n_peers)])
        if method == "tqsgd":
            outs.append(kops.uniform_decode_reduce(
                words, jnp.stack([mt.alpha for mt in metas]), m, bits))
        else:
            outs.append(kops.codebook_decode_reduce(words, levels, m, bits))
    emp = jnp.mean(jnp.stack(outs), axis=0)
    step = float(jnp.max(jnp.stack([jnp.max(jnp.diff(mt.levels)) for mt in metas])))
    tol = 5.0 * step / (2.0 * np.sqrt(R * n_peers)) + 1e-6
    assert float(jnp.max(jnp.abs(emp - target))) < tol


@settings(max_examples=10, deadline=None)
@given(data=st.data(), method=st.sampled_from(METHODS))
def test_statistical_unbiasedness_coarse(data, method):
    """Mean over repeats approaches the truncated tensor (weak tolerance)."""
    g = _gradients(data.draw, 128)
    cfg = CompressorConfig(method=method, bits=4)
    meta = plan(cfg, g)
    gt = truncate(g, meta.alpha)
    reps = jnp.stack(
        [jnp.take(meta.levels, stochastic_encode(g, meta, jax.random.key(i)).astype(jnp.int32)) for i in range(64)]
    )
    step = float(jnp.max(jnp.diff(meta.levels)))
    assert float(jnp.max(jnp.abs(reps.mean(0) - gt))) < step
