"""Pallas kernel validation: shape/dtype sweep vs the pure-jnp oracle
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sample_power_law
from repro.kernels import ops, ref
from repro.kernels.ops import _to_2d

SHAPES = [(64,), (1000,), (128, 128), (3, 777), (4, 7, 33)]
BITS = [2, 3, 4, 8]


def _rand_for(g, key):
    g2, n = _to_2d(g.astype(jnp.float32))
    return jax.random.uniform(key, g2.shape, jnp.float32), g2, n


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("bits", BITS)
def test_uniform_encode_matches_ref(shape, bits):
    g = sample_power_law(jax.random.key(1), shape, gamma=4.0, g_min=0.01, rho=0.1).reshape(-1)
    alpha = jnp.float32(0.05)
    key = jax.random.key(2)
    rand, g2, n = _rand_for(g, key)
    got = ops.uniform_encode(g, alpha, bits, key)
    want = ref.uniform_encode(g2, alpha, bits, rand).reshape(-1)[:n]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", BITS)
def test_uniform_decode_matches_ref(bits):
    codes = jax.random.randint(jax.random.key(3), (999,), 0, 2**bits).astype(jnp.uint8)
    alpha = jnp.float32(0.7)
    got = ops.uniform_decode(codes, alpha, bits)
    want = ref.uniform_decode(codes, alpha, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("s", [3, 7, 15, 255])
def test_codebook_encode_matches_ref(shape, s):
    g = sample_power_law(jax.random.key(4), shape, gamma=3.6, g_min=0.02, rho=0.15).reshape(-1)
    levels = jnp.sort(jax.random.uniform(jax.random.key(5), (s + 1,), minval=-0.1, maxval=0.1))
    levels = levels.at[0].set(-0.1).at[-1].set(0.1)
    key = jax.random.key(6)
    rand, g2, n = _rand_for(g, key)
    got = ops.codebook_encode(g, levels, key)
    want = ref.codebook_encode(g2, levels, rand).reshape(-1)[:n]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    dec_got = ops.codebook_decode(got, levels)
    dec_want = ref.codebook_decode(want, levels)
    np.testing.assert_allclose(np.asarray(dec_got), np.asarray(dec_want), rtol=1e-6)


def test_kernel_matches_core_quantizer_statistically():
    """Kernel path and repro.core.quantizers agree in distribution."""
    from repro.core import CompressorConfig
    from repro.core.compressors import plan
    from repro.core.quantizers import quantize

    g = sample_power_law(jax.random.key(7), (20_000,), gamma=4.0, g_min=0.01, rho=0.1)
    meta = plan(CompressorConfig(method="tnqsgd", bits=3), g)
    core_val = quantize(g, meta, jax.random.key(8))
    kern_codes = ops.codebook_encode(g, meta.levels, jax.random.key(9))
    kern_val = ops.codebook_decode(kern_codes, meta.levels)
    # same MSE scale (different RNG draws)
    mse_core = float(jnp.mean((core_val - g) ** 2))
    mse_kern = float(jnp.mean((kern_val - g) ** 2))
    assert abs(mse_core - mse_kern) / mse_core < 0.1


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("bits", BITS)
def test_uniform_encode_packed_matches_separate_passes(shape, bits):
    """Fused encode→pack emits the exact pack_codes wire words + codes."""
    from repro.core.quantizers import pack_codes, packed_size

    g = sample_power_law(jax.random.key(20), shape, gamma=4.0, g_min=0.01, rho=0.1).reshape(-1)
    alpha = jnp.float32(0.05)
    key = jax.random.key(21)
    words, codes = ops.uniform_encode_packed(g, alpha, bits, key)
    want_codes = ops.uniform_encode(g, alpha, bits, key)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(want_codes))
    assert words.shape == (packed_size(g.size, bits),) and words.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(words), np.asarray(pack_codes(want_codes, bits)))


@pytest.mark.parametrize("bits", BITS)
def test_codebook_encode_packed_matches_separate_passes(bits):
    from repro.core.quantizers import pack_codes, unpack_codes

    s = 2**bits - 1
    g = sample_power_law(jax.random.key(22), (777,), gamma=3.6, g_min=0.02, rho=0.15)
    levels = jnp.sort(jax.random.uniform(jax.random.key(23), (s + 1,), minval=-0.1, maxval=0.1))
    levels = levels.at[0].set(-0.1).at[-1].set(0.1)
    key = jax.random.key(24)
    words, codes = ops.codebook_encode_packed(g, levels, bits, key)
    want_codes = ops.codebook_encode(g, levels, key)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(want_codes))
    np.testing.assert_array_equal(np.asarray(words), np.asarray(pack_codes(want_codes, bits)))
    # and the wire round-trips through the standard unpack
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(words, g.size, bits)), np.asarray(want_codes))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_uniform_encode_dtypes(dtype):
    g = (jax.random.normal(jax.random.key(10), (512,)) * 0.1).astype(dtype)
    codes = ops.uniform_encode(g.astype(jnp.float32), jnp.float32(0.2), 4, jax.random.key(11))
    assert codes.dtype == jnp.uint8
    assert codes.shape == (512,)
