"""Pallas kernel validation: shape/dtype sweep vs the pure-jnp oracle
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sample_power_law
from repro.kernels import ops, ref
from repro.kernels.ops import _to_2d

SHAPES = [(64,), (1000,), (128, 128), (3, 777), (4, 7, 33)]
BITS = [2, 3, 4, 8]


def _rand_for(g, key):
    g2, n = _to_2d(g.astype(jnp.float32))
    return jax.random.uniform(key, g2.shape, jnp.float32), g2, n


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("bits", BITS)
def test_uniform_encode_matches_ref(shape, bits):
    g = sample_power_law(jax.random.key(1), shape, gamma=4.0, g_min=0.01, rho=0.1).reshape(-1)
    alpha = jnp.float32(0.05)
    key = jax.random.key(2)
    rand, g2, n = _rand_for(g, key)
    got = ops.uniform_encode(g, alpha, bits, key)
    want = ref.uniform_encode(g2, alpha, bits, rand).reshape(-1)[:n]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", BITS)
def test_uniform_decode_matches_ref(bits):
    codes = jax.random.randint(jax.random.key(3), (999,), 0, 2**bits).astype(jnp.uint8)
    alpha = jnp.float32(0.7)
    got = ops.uniform_decode(codes, alpha, bits)
    want = ref.uniform_decode(codes, alpha, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("s", [3, 7, 15, 255])
def test_codebook_encode_matches_ref(shape, s):
    g = sample_power_law(jax.random.key(4), shape, gamma=3.6, g_min=0.02, rho=0.15).reshape(-1)
    levels = jnp.sort(jax.random.uniform(jax.random.key(5), (s + 1,), minval=-0.1, maxval=0.1))
    levels = levels.at[0].set(-0.1).at[-1].set(0.1)
    key = jax.random.key(6)
    rand, g2, n = _rand_for(g, key)
    got = ops.codebook_encode(g, levels, key)
    want = ref.codebook_encode(g2, levels, rand).reshape(-1)[:n]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    dec_got = ops.codebook_decode(got, levels)
    dec_want = ref.codebook_decode(want, levels)
    np.testing.assert_allclose(np.asarray(dec_got), np.asarray(dec_want), rtol=1e-6)


def test_kernel_matches_core_quantizer_statistically():
    """Kernel path and repro.core.quantizers agree in distribution."""
    from repro.core import CompressorConfig
    from repro.core.compressors import plan
    from repro.core.quantizers import quantize

    g = sample_power_law(jax.random.key(7), (20_000,), gamma=4.0, g_min=0.01, rho=0.1)
    meta = plan(CompressorConfig(method="tnqsgd", bits=3), g)
    core_val = quantize(g, meta, jax.random.key(8))
    kern_codes = ops.codebook_encode(g, meta.levels, jax.random.key(9))
    kern_val = ops.codebook_decode(kern_codes, meta.levels)
    # same MSE scale (different RNG draws)
    mse_core = float(jnp.mean((core_val - g) ** 2))
    mse_kern = float(jnp.mean((kern_val - g) ** 2))
    assert abs(mse_core - mse_kern) / mse_core < 0.1


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_uniform_encode_dtypes(dtype):
    g = (jax.random.normal(jax.random.key(10), (512,)) * 0.1).astype(dtype)
    codes = ops.uniform_encode(g.astype(jnp.float32), jnp.float32(0.2), 4, jax.random.key(11))
    assert codes.dtype == jnp.uint8
    assert codes.shape == (512,)
