"""Fused telemetry kernel vs the pure-jnp oracle: bit-exact histogram
counts, per-bin ln-sums (the Hill tail sums), and max — plus the moment
rows and semantic checks against numpy on the same bin edges."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sample_power_law
from repro.kernels import ops, ref
from repro.kernels import stats as S

SHAPES = [(64,), (1000,), (128, 128), (3, 777), (4, 7, 33), (10_000,)]


@pytest.mark.parametrize("shape", SHAPES)
def test_bucket_stats_matches_ref_bitwise(shape):
    g = sample_power_law(jax.random.key(1), shape, gamma=3.6, g_min=0.01, rho=0.15)
    got = ops.bucket_stats(g)
    want = ref.bucket_stats(g)
    np.testing.assert_array_equal(np.asarray(got.counts), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got.log_sums), np.asarray(want[1]))
    np.testing.assert_array_equal(np.asarray(got.g_max), np.asarray(want[2, 0]))
    np.testing.assert_array_equal(np.asarray(got.g_sum), np.asarray(want[3, 0]))
    np.testing.assert_array_equal(np.asarray(got.g_sumsq), np.asarray(want[4, 0]))


def test_bucket_stats_semantics_vs_numpy():
    """Counts are the |g| histogram on the module's edges; log-sums are the
    per-bin sums of ln|g|; max/moments match the direct reductions."""
    g = sample_power_law(jax.random.key(2), (50_000,), gamma=4.0, g_min=0.01, rho=0.1)
    got = ops.bucket_stats(g)
    ga = np.abs(np.asarray(g, np.float64))
    edges = np.asarray(S.bin_edges(), np.float64)
    edges_open = np.concatenate([edges[:-1], [np.inf]])   # top bin catches overflow
    counts, _ = np.histogram(ga, bins=edges_open)
    np.testing.assert_array_equal(np.asarray(got.counts), counts.astype(np.float32))
    assert float(jnp.sum(got.counts)) == g.size
    idx = np.clip(np.digitize(ga, edges_open) - 1, 0, S.NUM_BINS - 1)
    want_ls = np.zeros(S.NUM_BINS)
    np.add.at(want_ls, idx, np.log(np.maximum(ga, 1e-30)))
    np.testing.assert_allclose(np.asarray(got.log_sums), want_ls, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(float(got.g_max), ga.max(), rtol=1e-6)
    np.testing.assert_allclose(float(got.g_sum), np.asarray(g, np.float64).sum(),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(float(got.g_sumsq), (ga ** 2).sum(), rtol=1e-3)


def test_bucket_stats_zeros_and_padding():
    """All-zero buckets land entirely in bin 0; padding contributes nothing."""
    g = jnp.zeros((100,), jnp.float32)
    got = ops.bucket_stats(g)
    assert float(got.counts[0]) == 100.0
    assert float(jnp.sum(got.counts)) == 100.0
    assert float(got.g_max) == 0.0
    # a single element: everything else is padding
    one = ops.bucket_stats(jnp.asarray([0.5], jnp.float32))
    assert float(jnp.sum(one.counts)) == 1.0
    assert float(one.g_max) == 0.5


def test_jnp_fallback_agrees_with_kernel():
    """The shard_map-safe scatter-add fallback used inside the train step
    produces the same counts/max exactly and the same sums numerically."""
    from repro.adaptive.telemetry import _stats_jnp

    g = sample_power_law(jax.random.key(3), (20_000,), gamma=3.4, g_min=0.02, rho=0.2)
    got = ops.bucket_stats(g)
    c, ls, gm, gs, gq = _stats_jnp(g)
    np.testing.assert_array_equal(np.asarray(got.counts), np.asarray(c))
    np.testing.assert_array_equal(np.asarray(got.g_max), np.asarray(gm))
    np.testing.assert_allclose(np.asarray(got.log_sums), np.asarray(ls), rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(float(got.g_sum), float(gs), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(got.g_sumsq), float(gq), rtol=1e-4)
