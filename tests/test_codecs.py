"""Codec-registry error paths: unknown lookups, duplicate registration,
malformed ``bits_plan`` entries — each must raise with a message that names
the offender and says what to do instead."""
import pytest

from repro.core.codecs import (COLLECTIVE_BUDGETS, Codec, bucket_cfg_entry,
                               bucket_cfgs, get_codec, known_methods,
                               register_codec)
from repro.core.compressors import CompressorConfig
from repro.dist.train_step import TrainStepConfig


def test_get_codec_unknown_lists_known():
    with pytest.raises(KeyError) as e:
        get_codec("fp8")
    msg = str(e.value)
    assert "fp8" in msg
    for m in known_methods():
        assert m in msg  # the fix is right there in the message


def test_register_duplicate_raises_then_override_replaces():
    class Shadow(Codec):
        name = "tqsgd"

    original = get_codec("tqsgd")
    with pytest.raises(ValueError) as e:
        register_codec(Shadow())
    msg = str(e.value)
    assert "tqsgd" in msg and "override=True" in msg
    assert type(original).__name__ in msg  # names the codec being shadowed
    assert get_codec("tqsgd") is original  # failed registration is a no-op
    try:
        register_codec(Shadow(), override=True)
        assert isinstance(get_codec("tqsgd"), Shadow)
    finally:
        register_codec(original, override=True)
    assert get_codec("tqsgd") is original


def test_register_unnamed_codec_rejected():
    with pytest.raises(ValueError, match="non-empty name"):
        register_codec(Codec())


def test_collective_budget_unknown_mode_lists_modes():
    with pytest.raises(ValueError) as e:
        get_codec("tqsgd").collective_budget("ring")
    msg = str(e.value)
    assert "ring" in msg
    for mode in (*COLLECTIVE_BUDGETS, "dsgd"):
        assert mode in msg


@pytest.mark.parametrize("entry, hint", [
    (("tqsgd", 3, 1), "pair"),            # wrong arity
    ((3, "tqsgd"), "pair"),               # method not first
    (("tqsgd", "three"), "must be an int"),
    (object(), "expected an int"),
    ("tqsgd", "expected an int"),         # bare method name, no value
])
def test_malformed_bits_plan_entries(entry, hint):
    cfg = CompressorConfig(method="tqsgd", bits=3)
    with pytest.raises(ValueError, match=hint) as e:
        bucket_cfg_entry(cfg, entry)
    assert "bits_plan entry" in str(e.value)


def test_bits_plan_unknown_method_surfaces_registry_error():
    cfg = CompressorConfig(method="tqsgd", bits=3)
    with pytest.raises(KeyError, match="fp8"):
        bucket_cfg_entry(cfg, ("fp8", 3))


def test_bucket_cfgs_length_mismatch():
    cfg = CompressorConfig(method="tqsgd", bits=3)
    with pytest.raises(ValueError, match="2 entries for 3 buckets"):
        bucket_cfgs(cfg, 3, (2, 3))


def test_train_step_config_validates_plan_entries():
    with pytest.raises(ValueError, match="bits_plan entry"):
        TrainStepConfig(sync="two_phase", bits_plan=(("tqsgd", "x"),))
    with pytest.raises(ValueError, match=r"\[1, 8\]"):
        TrainStepConfig(sync="two_phase", bits_plan=(0,))
    with pytest.raises(KeyError, match="fp8"):
        TrainStepConfig(sync="two_phase", bits_plan=(("fp8", 3),))
    # well-formed mixed plans normalize to hashable (str, int) tuples
    ts = TrainStepConfig(sync="two_phase", bits_plan=(("powersgd", 2), 3))
    assert ts.bits_plan == (("powersgd", 2), 3)
