"""Unit pins for ``repro.elastic``: the deterministic peer schedule, the
chaos-trace constructors and their JSON format, the fp16 passthrough codec,
and the size-adaptive tier rewrite.

The schedule is the replayability anchor of the whole elastic subsystem:
``live_mask`` must be a pure counter-based function of ``(seed, step,
peer)`` — identical traced and untraced, on every host — because the mesh
step evaluates it in-graph while the reference replay and the adaptive
controller's ``expected_live_fraction`` recompute it host-side.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codecs import get_codec, size_adaptive_plan
from repro.core.compressors import CompressorConfig
from repro.elastic import (
    ChaosTrace,
    ElasticConfig,
    expected_live_fraction,
    flap,
    live_mask,
    load_trace,
    partition,
    save_trace,
    solo_survivor,
)


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------


def test_live_mask_deterministic_and_replayable():
    cfg = ElasticConfig(rate=0.3, seed=7)
    for step in (0, 1, 17, 100_000):
        a = np.asarray(live_mask(cfg, step, 8))
        b = np.asarray(live_mask(cfg, step, 8))
        np.testing.assert_array_equal(a, b)
        # traced == untraced (the mesh step jits it, the reference doesn't)
        c = np.asarray(jax.jit(lambda s: live_mask(cfg, s, 8))(jnp.uint32(step)))
        np.testing.assert_array_equal(a, c)
        assert a.dtype == np.float32 and set(np.unique(a)) <= {0.0, 1.0}


def test_live_mask_rate_extremes_and_floor():
    n = 8
    all_on = np.asarray(live_mask(ElasticConfig(rate=0.0), 3, n))
    np.testing.assert_array_equal(all_on, np.ones(n, np.float32))
    # rate ~1 drops everyone the hash can: the floor guarantees min_live
    floored = np.asarray(live_mask(ElasticConfig(rate=1.0, min_live=2), 3, n))
    assert floored.sum() == 2.0
    np.testing.assert_array_equal(floored, (np.arange(n) < 2).astype(np.float32))
    # min_live above n clamps to n
    np.testing.assert_array_equal(
        np.asarray(live_mask(ElasticConfig(rate=1.0, min_live=64), 3, n)),
        np.ones(n, np.float32))


def test_live_mask_rate_statistics():
    cfg = ElasticConfig(rate=0.25, seed=11)
    counts = [float(np.asarray(live_mask(cfg, s, 16)).sum()) for s in range(200)]
    frac = sum(counts) / (200 * 16)
    assert 0.70 <= frac <= 0.80, frac  # ~75% live at 25% dropout
    # different steps produce different masks (the schedule is not static)
    masks = {tuple(np.asarray(live_mask(cfg, s, 16)).tolist()) for s in range(50)}
    assert len(masks) > 10


def test_expected_live_fraction_matches_mask_replay():
    cfg = ElasticConfig(rate=0.4, seed=3)
    n, start, window = 8, 40, 20
    want = np.mean([np.asarray(live_mask(cfg, s, n)).mean()
                    for s in range(start, start + window)])
    assert expected_live_fraction(cfg, n, start, window) == pytest.approx(want)
    assert expected_live_fraction(None, n, 0, 10) == 1.0
    assert expected_live_fraction(ElasticConfig(rate=0.0), n, 0, 10) == 1.0


def test_elastic_config_validation():
    with pytest.raises(ValueError):
        ElasticConfig(rate=1.5)
    with pytest.raises(ValueError):
        ElasticConfig(rate=-0.1)
    with pytest.raises(ValueError):
        ElasticConfig(min_live=0)
    with pytest.raises(ValueError):
        ElasticConfig(trace=((1, 0), (1,)))  # ragged rows
    with pytest.raises(ValueError):
        ElasticConfig(trace=((2, 0),))       # non-binary entry


# ---------------------------------------------------------------------------
# chaos traces
# ---------------------------------------------------------------------------


def test_trace_mode_overrides_hash():
    cfg = ElasticConfig(trace=((1, 0, 1), (0, 1, 1)))
    np.testing.assert_array_equal(np.asarray(live_mask(cfg, 0, 3)), [1, 0, 1])
    np.testing.assert_array_equal(np.asarray(live_mask(cfg, 1, 3)), [0, 1, 1])
    # steps wrap modulo the trace length
    np.testing.assert_array_equal(np.asarray(live_mask(cfg, 2, 3)), [1, 0, 1])
    with pytest.raises(ValueError):
        live_mask(cfg, 0, 4)  # trace width must match n


def test_chaos_constructors():
    f = flap(4, peer=1, period=2)
    assert f.n_peers == 4 and f.n_steps == 4
    assert [r[1] for r in f.rows] == [0, 0, 1, 1]  # down-first flapping
    assert all(r[i] == 1 for r in f.rows for i in (0, 2, 3))

    p = partition(4, down=2, down_steps=3, up_steps=1)
    assert p.n_steps == 4
    assert p.rows[0] == (0, 0, 1, 1) and p.rows[3] == (1, 1, 1, 1)
    with pytest.raises(ValueError):
        partition(4, down=4, down_steps=1)  # cannot take down every peer

    s = solo_survivor(4, survivor=2, steps=2)
    assert all(r == (0, 0, 1, 0) for r in s.rows)

    # the ElasticConfig bridge carries min_live through
    cfg = f.elastic(min_live=1)
    assert cfg.trace == f.rows and cfg.min_live == 1


def test_trace_json_round_trip(tmp_path):
    t = partition(6, down=(0, 3), down_steps=2, up_steps=2)
    path = tmp_path / "trace.json"
    save_trace(t, path)
    raw = json.loads(path.read_text())
    assert raw["version"] == 1 and raw["n_peers"] == 6
    got = load_trace(path)
    assert got.rows == t.rows and got.name == t.name
    # corrupt version is rejected
    raw["version"] = 99
    path.write_text(json.dumps(raw))
    with pytest.raises(ValueError):
        load_trace(path)


# ---------------------------------------------------------------------------
# fp16 passthrough codec + size-adaptive tier
# ---------------------------------------------------------------------------


def test_fp16_codec_round_trip():
    c = get_codec("fp16")
    cfg = CompressorConfig(method="fp16")
    key = jax.random.key(0)
    for m in (1, 2, 31, 999, 4096):
        x = jax.random.normal(jax.random.fold_in(key, m), (m,), jnp.float32)
        w = c.encode(cfg, x, c.plan(cfg, x, None, False), key, False)
        assert w.dtype == jnp.uint32 and w.shape == (c.wire_words(cfg, m),)
        half = x.astype(jnp.float16).astype(jnp.float32)
        np.testing.assert_array_equal(np.asarray(c.decode_reduce(cfg, w[None], m, False)),
                                      np.asarray(half))
        # the elastic contract: an all-zero wire row decodes to exactly zero
        z = c.decode_reduce(cfg, jnp.zeros_like(w)[None], m, False)
        assert float(jnp.max(jnp.abs(z))) == 0.0
        # EF residual is the cast error
        _, r, aux = c.encode_residual(cfg, x, None, key, False)
        assert aux is None
        np.testing.assert_array_equal(np.asarray(r), np.asarray(x - half))


def test_fp16_chunks_match_full_encode():
    c = get_codec("fp16")
    cfg = CompressorConfig(method="fp16")
    key = jax.random.key(1)
    for m, n_chunks in ((999, 4), (1000, 2), (64, 8)):
        x = jax.random.normal(jax.random.fold_in(key, m), (m,), jnp.float32)
        rows, r = c.encode_chunks(cfg, x, None, key, n_chunks, False)
        mc = c.chunk_elems(cfg, m, n_chunks)
        assert mc % 2 == 0  # packed words never straddle a chunk boundary
        assert rows.shape == (n_chunks, c.chunk_wire_words(cfg, m, n_chunks))
        vals = c.decode_rows(cfg, rows, mc, False).reshape(-1)[:m]
        np.testing.assert_array_equal(
            np.asarray(vals), np.asarray(x.astype(jnp.float16).astype(jnp.float32)))
        np.testing.assert_array_equal(
            np.asarray(r), np.asarray(x - x.astype(jnp.float16).astype(jnp.float32)))


def test_size_adaptive_plan():
    cfg = CompressorConfig(method="tnqsgd", bits=3)
    sizes = (100, 5000, 2048)
    # threshold 0 or no small buckets: plan unchanged (None stays None)
    assert size_adaptive_plan(cfg, None, sizes, 0) is None
    assert size_adaptive_plan(cfg, (2, 3, 4), sizes, 0) == (2, 3, 4)
    assert size_adaptive_plan(cfg, None, sizes, 50) is None
    # small buckets flip to the fp16 tier, large keep their entries
    got = size_adaptive_plan(cfg, (2, 3, 4), sizes, 1024)
    assert got == (("fp16", 3), 3, 4)
    # with no base plan the untouched entries inherit the base config
    got = size_adaptive_plan(cfg, None, sizes, 2048)
    assert got[0] == ("fp16", 3) and got[2] == ("fp16", 3) and got[1] == cfg
    with pytest.raises(ValueError):
        size_adaptive_plan(cfg, (2, 3), sizes, 1024)  # length mismatch


def test_fp16_registered_and_configurable():
    from repro.core.codecs import known_methods

    assert "fp16" in known_methods()
    # a CompressorConfig can name it directly (the bucket_cfg_entry path)
    cfg = CompressorConfig(method="fp16")
    assert get_codec(cfg.method).fixed_wire_bits == 16
