"""Golden convergence regression: fixed-seed 30-step smallnet runs per sync
method, with pinned final-loss windows.

Gradients are synced through :func:`repro.dist.reference.reference_sync` —
the single-device replica of the mesh codec that shares every local
plan/encode/decode helper with ``dist.sharded_codec`` (and is itself pinned
bit-for-bit against the mesh in ``test_mesh_invariance``) — so a codec
refactor that silently biases the synced mean (a dropped 1/n, a truncation
bias, a decode off-by-one) moves these losses far outside their windows and
fails tier-1 instead of only drifting a benchmark curve.

The windows are generous against ulp-level platform noise (runs are fully
deterministic on the pinned CPU toolchain) but far tighter than the gap to
a broken codec: the task converges from ≈6.5 to ≲0.02 in 30 steps, and a
mean-perturbing bug stalls that decay orders of magnitude above the pinned
values.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.compressors import CompressorConfig, plan_buckets
from repro.dist import sharded_codec as sc
from repro.data.synthetic import client_batches, make_templates
from repro.dist.reference import reference_sync, reference_sync_state
from repro.dist.train_step import TrainStepConfig
from repro.models.smallnet import init_smallnet, smallnet_loss
from repro.optim.optimizers import momentum_sgd

N_CLIENTS = 8
BATCH = 32
STEPS = 30

# case -> (sync, method, ef, (pods, data) layout, pinned final loss,
# tolerance).  The synthetic shapes task converges hard in 30 steps
# (first-step loss ≈ 6.49); a codec bias that perturbs the synced mean
# stalls convergence orders of magnitude above these windows.  The
# powersgd case runs with error feedback — biased low-rank compression
# needs the residual (and its warm-started Q rides the same EF row), so
# the window also pins the bucket-resident aux-state threading.
GOLDEN = {
    "dsgd": ("dsgd", "tnqsgd", False, (8,), 0.0000, 0.02),
    "two_phase": ("two_phase", "tnqsgd", False, (8,), 0.0037, 0.05),
    "hierarchical": ("hierarchical", "tnqsgd", False, (2, 4), 0.0207, 0.05),
    "faithful": ("faithful", "tnqsgd", False, (8,), 0.0162, 0.05),
    "powersgd": ("faithful", "powersgd", True, (8,), 0.0110, 0.05),
}


def _run(sync: str, method: str, ef_on: bool, dp: tuple, elastic=None) -> list:
    ts = TrainStepConfig(sync=sync, error_feedback=ef_on, elastic=elastic,
                         compressor=CompressorConfig(method=method, bits=3, rank=4))
    templates = make_templates(jax.random.key(42))
    params = init_smallnet(jax.random.key(0))
    opt = momentum_sgd(lr=0.01, momentum=0.9, weight_decay=5e-4)
    state = opt.init(params)
    ef = None
    if ef_on:
        bp = plan_buckets([x.size for x in jax.tree.leaves(params)],
                          int(4.0 * (1 << 20) / 4))
        st_sizes = sc.bucket_state_sizes(ts.compressor, bp.sizes, ts.bits_plan)
        ef = [jnp.zeros((N_CLIENTS, s), jnp.float32) for s in st_sizes]

    @jax.jit
    def step(p, s, ef, i):
        imgs, labels = client_batches(templates, i, N_CLIENTS, BATCH)
        losses, grads = jax.vmap(
            lambda im, lb: jax.value_and_grad(smallnet_loss)(p, im, lb))(imgs, labels)
        leaves, treedef = jax.tree.flatten(grads)
        key = jax.random.fold_in(jax.random.key(0x5EED), i)
        live = None
        if elastic is not None:
            from repro.elastic import live_mask

            live = live_mask(elastic, i, N_CLIENTS)
        if ef_on:
            mean, ef2, _, _ = reference_sync_state(ts, leaves, dp, key, ef=ef,
                                                   live=live)
        else:
            mean, ef2 = reference_sync(ts, leaves, dp, key, live=live), None
        p2, s2 = opt.update(p, jax.tree.unflatten(treedef, mean), s, i)
        return p2, s2, ef2, jnp.mean(losses)

    hist = []
    p, s = params, state
    for i in range(STEPS):
        p, s, ef, loss = step(p, s, ef, jnp.uint32(i))
        hist.append(float(loss))
    return hist


@pytest.mark.parametrize("case", sorted(GOLDEN))
def test_golden_final_loss(case):
    sync, method, ef_on, dp, pinned, tol = GOLDEN[case]
    hist = _run(sync, method, ef_on, dp)
    assert hist[-1] == pytest.approx(pinned, abs=tol), (case, hist)
    # and training actually converged (quantization noise notwithstanding)
    assert hist[-1] < hist[0] - 5.0, (case, hist)


def test_golden_elastic_dropout():
    """20%% scheduled dropout (deterministic counter hash, EF on): stale-EF
    recovery keeps the run converging into a pinned window — the elastic
    analogue of the full-participation faithful case above."""
    from repro.elastic import ElasticConfig

    hist = _run("faithful", "tnqsgd", True, (8,),
                elastic=ElasticConfig(rate=0.2, seed=0x17E))
    # pinned from the deterministic run (first-step loss 6.4895); dropout
    # noise keeps the late-step losses bouncing in [0.01, 0.13], so the
    # window is wider than the full-participation cases'
    assert hist[-1] == pytest.approx(0.0938, abs=0.07), hist
    assert hist[-1] < hist[0] - 5.0, hist
