"""Fused decode kernel validation against the ``kernels/ref.py`` oracles
(interpret mode executes the kernel body on CPU) across bit widths, ragged
tails, and heterogeneous per-bucket bit tuples.

Comparison contract: the **codebook** variants are bit-exact — their dequant
is an exact one-hot table lookup and the peer accumulation is a chain of
adds, so no compilation choice can perturb a bit.  The **uniform** variants
contain a real multiply-add (``code · 2α/s − α``) whose FMA contraction is
compiler-discretionary, so two separately compiled graphs may differ in the
last couple of ulp; they are pinned at a ≤4-ulp tolerance (any real decode
bug is off by a whole quantization step, ≥3 orders of magnitude larger).
Oracles are compared under ``jax.jit`` — the codec always runs them inside a
compiled step.
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sample_power_law
from repro.core.compressors import CompressorConfig, plan
from repro.core.quantizers import pack_codes, stochastic_encode
from repro.kernels import ops, ref

# Ragged tails: multiples of the 32-code packing group, the 128-lane row, the
# (BLOCK_ROWS, 128) tile — and none of the above.
SIZES = [64, 999, 128 * 128, 64 * 128 * 2 + 17, 4096 + 31]
BITS = list(range(1, 9))
N_PEERS = 5


def _wire(key, n, bits, p=N_PEERS):
    codes = jax.random.randint(key, (p, n), 0, 2**bits).astype(jnp.uint8)
    words = jnp.stack([pack_codes(codes[j], bits) for j in range(p)])
    return codes, words


def _levels(key, bits, p=N_PEERS):
    lv = jax.random.uniform(jax.random.fold_in(key, 7), (p, 2**bits), minval=-0.2, maxval=0.2)
    return jnp.sort(lv, axis=1)


def _assert_ulp_close(got, want, scale, ulps=4):
    """Elementwise |got-want| ≤ ulps · ulp(scale), where ``scale`` bounds the
    largest intermediate (the pre-division peer accumulator for the reduce
    kernels — an element whose *mean* is near zero still carries the rounding
    of its ~Σα-sized running sum)."""
    got, want = np.asarray(got), np.asarray(want)
    tol = ulps * np.spacing(np.float32(abs(scale)))
    bad = np.abs(got - want) > tol
    assert not bad.any(), (
        f"{bad.sum()} elements beyond {ulps} ulp of scale {scale}; max diff "
        f"{np.abs(got - want).max()} at {np.argmax(np.abs(got - want))}")


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("n", SIZES)
def test_uniform_decode_reduce_matches_oracle(bits, n):
    key = jax.random.key(bits * 1000 + n)
    _, words = _wire(key, n, bits)
    alphas = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (N_PEERS,))) + 0.1
    got = ops.uniform_decode_reduce(words, alphas, n, bits)
    want = jax.jit(partial(ref.uniform_decode_reduce, n=n, bits=bits))(words, alphas)
    _assert_ulp_close(got, want, scale=float(jnp.sum(alphas)))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("n", SIZES)
def test_codebook_decode_reduce_bit_exact(bits, n):
    key = jax.random.key(bits * 2000 + n)
    _, words = _wire(key, n, bits)
    levels = _levels(key, bits)
    got = ops.codebook_decode_reduce(words, levels, n, bits)
    want = jax.jit(partial(ref.codebook_decode_reduce, n=n, bits=bits))(words, levels)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("n", SIZES)
def test_decode_rows_match_oracle(bits, n):
    key = jax.random.key(bits * 3000 + n)
    _, words = _wire(key, n, bits)
    alphas = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (N_PEERS,))) + 0.1
    levels = _levels(key, bits)
    got = ops.uniform_decode_rows(words, alphas, n, bits)
    want = jax.jit(partial(ref.uniform_decode_rows, n=n, bits=bits))(words, alphas)
    _assert_ulp_close(got, want, scale=float(jnp.max(alphas)))
    got = ops.codebook_decode_rows(words, levels, n, bits)
    want = jax.jit(partial(ref.codebook_decode_rows, n=n, bits=bits))(words, levels)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_decode_reduce_is_unfused_peer_mean():
    """The fused mean agrees with the obvious (peers, n) unpack→take→mean
    formulation up to summation-order float noise."""
    from repro.core.quantizers import unpack_codes

    bits, n = 3, 2048 + 13
    key = jax.random.key(42)
    codes, words = _wire(key, n, bits)
    levels = _levels(key, bits)
    fused = ops.codebook_decode_reduce(words, levels, n, bits)

    @jax.jit
    def unfused(words, levels):
        c = jax.vmap(lambda w: unpack_codes(w, n, bits))(words)
        return jnp.mean(jax.vmap(lambda cc, lv: jnp.take(lv, cc.astype(jnp.int32)))(c, levels),
                        axis=0)

    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused(words, levels)),
                               rtol=1e-6, atol=1e-7)


def test_end_to_end_encode_decode_roundtrip():
    """Real wire: plan + fused encode-pack per peer, fused decode-reduce back
    — the mean of the peers' dequantized tensors, on the codebook."""
    bits, n = 3, 5000
    cfg = CompressorConfig(method="tnqsgd", bits=bits)
    key = jax.random.key(3)
    words, levels, owns = [], [], []
    for p in range(4):
        g = sample_power_law(jax.random.fold_in(key, p), (n,), gamma=4.0, g_min=0.01, rho=0.1)
        meta = plan(cfg, g)
        codes = stochastic_encode(g, meta, jax.random.fold_in(key, 100 + p))
        words.append(pack_codes(codes, bits))
        levels.append(meta.levels)
        owns.append(jnp.take(meta.levels, codes.astype(jnp.int32)))
    got = ops.codebook_decode_reduce(jnp.stack(words), jnp.stack(levels), n, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(jnp.mean(jnp.stack(owns), axis=0)),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("method", ["tqsgd", "tnqsgd"])
def test_decode_reduce_unbiased_fixed_seed(method):
    """Fixed-seed statistical pin that the fused decode-reduce is unbiased:
    the mean over R independent encode draws approaches the mean of the
    peers' truncated tensors within a 5σ concentration bound
    (Var ≤ Δ²/4 per peer draw ⇒ std of the R-draw n-peer mean ≤
    Δmax/(2·sqrt(R·n))).  A deterministic twin of the hypothesis property in
    ``test_properties.py``, which only runs where hypothesis is installed —
    this one keeps the bias net live under the pinned CI deps.
    """
    from repro.core.quantizers import truncate

    bits, n_peers, m, R = 3, 4, 256, 64
    cfg = CompressorConfig(method=method, bits=bits)
    g = sample_power_law(jax.random.key(11), (n_peers, m), gamma=3.8, g_min=0.01, rho=0.12)
    metas = [plan(cfg, g[p]) for p in range(n_peers)]
    levels = jnp.stack([mt.levels for mt in metas])
    target = jnp.mean(
        jnp.stack([truncate(g[p], metas[p].alpha) for p in range(n_peers)]), axis=0)
    outs = []
    for r in range(R):
        words = jnp.stack([
            pack_codes(stochastic_encode(g[p], metas[p], jax.random.key(r * 131 + p)), bits)
            for p in range(n_peers)])
        if method == "tqsgd":
            outs.append(ops.uniform_decode_reduce(
                words, jnp.stack([mt.alpha for mt in metas]), m, bits))
        else:
            outs.append(ops.codebook_decode_reduce(words, levels, m, bits))
    emp = jnp.mean(jnp.stack(outs), axis=0)
    step = float(jnp.max(jnp.stack([jnp.max(jnp.diff(mt.levels)) for mt in metas])))
    tol = 5.0 * step / (2.0 * np.sqrt(R * n_peers)) + 1e-6
    assert float(jnp.max(jnp.abs(emp - target))) < tol


@pytest.mark.parametrize("plan_bits", [(1, 4, 3), (2, 2, 8), (5, 1, 2)])
def test_heterogeneous_bucket_bits(plan_bits):
    """An adaptive fused wire: per-bucket widths decode bucket-by-bucket
    through the fused kernels, each slice bit-exact vs its oracle."""
    from repro.core.quantizers import packed_size

    sizes = (1500, 4096, 777)
    key = jax.random.key(9)
    wire_rows, per_bucket = [], []
    for b, (n, bits) in enumerate(zip(sizes, plan_bits)):
        codes, words = _wire(jax.random.fold_in(key, b), n, bits)
        levels = _levels(jax.random.fold_in(key, 50 + b), bits)
        wire_rows.append(words)
        per_bucket.append((n, bits, levels))
    wire = jnp.concatenate(wire_rows, axis=1)           # one fused row per peer
    off = 0
    for n, bits, levels in per_bucket:
        w = packed_size(n, bits)
        words = wire[:, off:off + w]
        off += w
        got = ops.codebook_decode_reduce(words, levels, n, bits)
        want = jax.jit(partial(ref.codebook_decode_reduce, n=n, bits=bits))(words, levels)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert off == wire.shape[1]


def test_wire_size_mismatch_raises():
    """A wire row count that disagrees with (n, bits) is a hard error, not a
    silent truncation."""
    bits, n = 3, 1000
    _, words = _wire(jax.random.key(0), n, bits)
    with pytest.raises(ValueError, match="words per peer"):
        ops.codebook_decode_reduce(words[:, :-1], _levels(jax.random.key(1), bits), n, bits)
