"""Fused encode-kernel validation against the ``kernels/ref.py`` oracles
(interpret mode executes the kernel body on CPU) across bit widths, ragged
tails, and heterogeneous per-bucket bit plans.

Comparison contract (mirror of ``test_decode_kernels``): kernel and oracle
derive their uniforms from the same key over the same padded (rows, 128)
layout, so the **wire words are bit-exact for every method** (the stochastic
rounding itself is integer compares + exact one-hot lookups) and the
**codebook residual is bit-exact** (``levels[code]`` is the interval
endpoint the rounding chose, and the subtraction is a single rounding in
both paths).  The **uniform residual** contains the real multiply-add
dequant (``code · 2α/s − α``) whose FMA contraction is
compiler-discretionary — pinned at a ≤4-ulp tolerance, as on the decode
side.  ``ef_correct_stats`` shares its block statistics and merge with the
``kernels.stats`` kernel, so corrected bucket and stats tile are bit-exact
vs the blockwise oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sample_power_law
from repro.core.compressors import CompressorConfig, plan
from repro.core.quantizers import pack_codes, packed_size
from repro.kernels import ops, ref

# Ragged tails: multiples of the 32-code packing group, the 128-lane row, the
# (BLOCK_ROWS, 128) tile — and none of the above.
SIZES = [64, 999, 128 * 128, 64 * 128 * 2 + 17, 4096 + 31]
BITS = list(range(1, 9))


def _grad(key, n, scale=1.0):
    return scale * sample_power_law(key, (n,), gamma=3.8, g_min=0.01, rho=0.12)


def _levels(key, bits):
    lv = jnp.sort(jax.random.uniform(key, (2**bits,), minval=-0.2, maxval=0.2))
    return lv.at[0].set(-0.2).at[-1].set(0.2)


def _assert_ulp_close(got, want, scale, ulps=4):
    got, want = np.asarray(got), np.asarray(want)
    tol = ulps * np.spacing(np.float32(abs(scale)))
    bad = np.abs(got - want) > tol
    assert not bad.any(), (
        f"{bad.sum()} elements beyond {ulps} ulp of scale {scale}; max diff "
        f"{np.abs(got - want).max()}")


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("n", SIZES)
def test_codebook_encode_pack_residual_bit_exact(bits, n):
    g = _grad(jax.random.key(bits * 1000 + n), n)
    levels = _levels(jax.random.key(7), bits)
    key = jax.random.key(bits * 31 + n)
    w_k, r_k = ops.codebook_encode_pack_residual(g, levels, bits, key)
    w_r, r_r = jax.jit(lambda g, lv, k: ref.codebook_encode_pack_residual(
        g, lv, bits, k))(g, levels, key)
    assert w_k.shape == (packed_size(n, bits),)
    np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_r))
    np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_r))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("n", SIZES)
def test_uniform_encode_pack_residual_matches_oracle(bits, n):
    g = _grad(jax.random.key(bits * 2000 + n), n)
    alpha = jnp.float32(0.05)
    key = jax.random.key(bits * 37 + n)
    w_k, r_k = ops.uniform_encode_pack_residual(g, alpha, bits, key)
    w_r, r_r = jax.jit(lambda g, a, k: ref.uniform_encode_pack_residual(
        g, a, bits, k))(g, alpha, key)
    np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_r))
    _assert_ulp_close(r_k, r_r, scale=float(jnp.max(jnp.abs(g))) + float(alpha))


@pytest.mark.parametrize("bits", [1, 3, 5, 8])
@pytest.mark.parametrize("n", SIZES)
def test_encode_pack_words_only_matches_residual_variant(bits, n):
    """The words-only kernels emit the exact same wire as the residual
    variants (same codes, same pack) — and both equal a separate
    encode → ``pack_codes`` pipeline under the same key."""
    g = _grad(jax.random.key(bits * 3000 + n), n)
    levels = _levels(jax.random.key(9), bits)
    key = jax.random.key(bits * 41 + n)
    w_only = ops.codebook_encode_pack(g, levels, bits, key)
    w_resid, _ = ops.codebook_encode_pack_residual(g, levels, bits, key)
    np.testing.assert_array_equal(np.asarray(w_only), np.asarray(w_resid))
    codes = ops.codebook_encode(g, levels, key)
    np.testing.assert_array_equal(np.asarray(w_only),
                                  np.asarray(pack_codes(codes, bits)))
    alpha = jnp.float32(0.04)
    w_uni = ops.uniform_encode_pack(g, alpha, bits, key)
    w_uni_r, _ = ops.uniform_encode_pack_residual(g, alpha, bits, key)
    np.testing.assert_array_equal(np.asarray(w_uni), np.asarray(w_uni_r))


def test_residual_semantics():
    """resid == corrected − dequant(code): decode the wire and check."""
    bits, n = 3, 5000
    cfg = CompressorConfig(method="tnqsgd", bits=bits)
    g = _grad(jax.random.key(3), n)
    meta = plan(cfg, g)
    key = jax.random.key(4)
    words, resid = ops.codebook_encode_pack_residual(g, meta.levels, bits, key)
    own = ops.codebook_decode_reduce(words[None], meta.levels[None], n, bits)
    np.testing.assert_array_equal(np.asarray(resid), np.asarray(g - own))
    # the EF magnitude is bounded by the codebook's coarsest step plus the
    # truncated tail mass — sanity: no element exceeds max|g|
    assert float(jnp.max(jnp.abs(resid))) <= float(jnp.max(jnp.abs(g))) * 2 + 1e-6


@pytest.mark.parametrize("shape", [(64,), (1000,), (128, 128), (3, 777), (10_000,)])
def test_ef_correct_stats_bit_exact(shape):
    g = sample_power_law(jax.random.key(1), shape, gamma=3.6, g_min=0.01, rho=0.15)
    e = 0.3 * sample_power_law(jax.random.key(2), shape, gamma=4.2, g_min=0.005, rho=0.1)
    c_k, s_k = ops.ef_correct_stats(g, e)
    c_r, tile = jax.jit(ref.ef_correct_stats)(g, e)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_array_equal(np.asarray(s_k.counts), np.asarray(tile[0]))
    np.testing.assert_array_equal(np.asarray(s_k.log_sums), np.asarray(tile[1]))
    np.testing.assert_array_equal(np.asarray(s_k.g_max), np.asarray(tile[2, 0]))
    # the moment rows are plain jnp.sum reductions whose in-block
    # vectorization is fusion-context-dependent (the fused add changes the
    # emitted reduce) — everything the plan consumes (counts, log-sums, max)
    # is exact; the EMA moments get the ulp-level contract
    np.testing.assert_allclose(np.asarray(s_k.g_sum), np.asarray(tile[3, 0]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_k.g_sumsq), np.asarray(tile[4, 0]), rtol=1e-6)


def test_ef_correct_stats_is_stats_of_sum():
    """The fused pass equals the two-pass formulation: add, then
    ``bucket_stats`` — bit-for-bit (shared block statistics + merge)."""
    g = _grad(jax.random.key(11), 20_000)
    e = 0.1 * _grad(jax.random.key(12), 20_000)
    c, s = ops.ef_correct_stats(g, e)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(g + e))
    s2 = ops.bucket_stats(g + e)
    np.testing.assert_array_equal(np.asarray(s.counts), np.asarray(s2.counts))
    np.testing.assert_array_equal(np.asarray(s.log_sums), np.asarray(s2.log_sums))
    np.testing.assert_array_equal(np.asarray(s.g_max), np.asarray(s2.g_max))
    np.testing.assert_allclose(np.asarray(s.g_sum), np.asarray(s2.g_sum), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s.g_sumsq), np.asarray(s2.g_sumsq), rtol=1e-6)


@pytest.mark.parametrize("plan_bits", [(1, 4, 3), (2, 2, 8), (5, 1, 2)])
def test_heterogeneous_bucket_bits_encode(plan_bits):
    """An adaptive fused wire assembled from per-bucket fused encodes at
    heterogeneous widths: every slice is bit-exact vs its oracle and
    round-trips through the fused decode."""
    sizes = (1500, 4096, 777)
    key = jax.random.key(9)
    wire_parts, per_bucket = [], []
    for b, (n, bits) in enumerate(zip(sizes, plan_bits)):
        g = _grad(jax.random.fold_in(key, b), n)
        levels = _levels(jax.random.fold_in(key, 50 + b), bits)
        kk = jax.random.fold_in(key, 100 + b)
        w, r = ops.codebook_encode_pack_residual(g, levels, bits, kk)
        w_ref, r_ref = jax.jit(lambda g, lv, k, b=bits: ref.codebook_encode_pack_residual(
            g, lv, b, k))(g, levels, kk)
        np.testing.assert_array_equal(np.asarray(w), np.asarray(w_ref))
        np.testing.assert_array_equal(np.asarray(r), np.asarray(r_ref))
        wire_parts.append(w)
        per_bucket.append((n, bits, levels, g, r))
    wire = jnp.concatenate(wire_parts)
    off = 0
    for n, bits, levels, g, r in per_bucket:
        w = packed_size(n, bits)
        own = ops.codebook_decode_reduce(wire[off:off + w][None], levels[None], n, bits)
        np.testing.assert_array_equal(np.asarray(r), np.asarray(g - own))
        off += w
    assert off == wire.shape[0]
