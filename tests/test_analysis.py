"""Static-analysis subsystem tests (``repro.analysis``).

Three contracts:

1. every corpus pattern under ``tests/analysis_corpus/`` is flagged with
   its rule code (and the suppressed/fixed variants are not);
2. the real tree comes back clean — the AST and VMEM passes in-process,
   the jaxpr pass + CLI end-to-end in a 4-fake-device subprocess;
3. the analytic wire model (``dist.collectives``) and the jaxpr-measured
   collective operands agree byte-for-byte, with the codec registry's
   ``wire_words`` as the single source of truth.
"""
import functools
import importlib.util
import json
import os
import pathlib
import subprocess
import sys
import textwrap

from repro.analysis import RULES, Finding, ast_lint, suppressed_codes

ROOT = pathlib.Path(__file__).resolve().parents[1]
CORPUS = pathlib.Path(__file__).resolve().parent / "analysis_corpus"


def _codes(findings):
    return [f.code for f in findings]


@functools.lru_cache(maxsize=1)
def _traced():
    spec = importlib.util.spec_from_file_location(
        "analysis_corpus_traced", CORPUS / "traced.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _run_analysis(args, timeout=1200):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, timeout=timeout, env=env)


# ---------------------------------------------------------------------------
# Taxonomy + suppression plumbing
# ---------------------------------------------------------------------------


def test_rule_taxonomy_bands():
    bands = {c[:6] for c in RULES}
    assert bands == {"REPRO1", "REPRO2", "REPRO3"}
    assert all(len(c) == 8 and RULES[c] for c in RULES)


def test_suppression_comment_parsing():
    lines = ["x = 1",
             "y = f()  # repro: allow REPRO104 (documented)",
             "# repro: allow REPRO102, REPRO204 (both)",
             "z = g()"]
    assert suppressed_codes(lines, 2) == {"REPRO104"}
    assert suppressed_codes(lines, 4) == {"REPRO102", "REPRO204"}  # line above
    assert suppressed_codes(lines, 1) == frozenset()
    assert Finding("REPRO104", "a.py:2", "m").to_json() == {
        "code": "REPRO104", "where": "a.py:2", "message": "m"}


# ---------------------------------------------------------------------------
# AST corpus (REPRO2xx)
# ---------------------------------------------------------------------------


def test_corpus_method_dispatch_flagged():
    f = ast_lint.lint_file(CORPUS / "method_dispatch.py",
                           relpath="repro/dist/sharded_codec.py")
    assert _codes(f) == ["REPRO201", "REPRO201"]
    # out of the collective scope the same source is legal
    assert ast_lint.lint_file(CORPUS / "method_dispatch.py",
                              relpath="repro/core/helpers.py") == []


def test_corpus_bare_pallas_flagged():
    f = ast_lint.lint_file(CORPUS / "bare_pallas.py",
                           relpath="repro/adaptive/runtime.py")
    assert _codes(f) == ["REPRO202"]
    # the same launch inside kernels/ is the sanctioned home
    assert ast_lint.lint_file(CORPUS / "bare_pallas.py",
                              relpath="repro/kernels/encode.py") == []


def test_corpus_no_interpret_flagged():
    f = ast_lint.lint_file(CORPUS / "no_interpret.py",
                           relpath="repro/kernels/ops.py")
    assert _codes(f) == ["REPRO203"]
    assert "fancy_encode" in f[0].message


def test_corpus_literal_seed_flagged():
    f = ast_lint.lint_file(CORPUS / "literal_seed.py")
    assert _codes(f) == ["REPRO204", "REPRO204"]


def test_corpus_suppression_roundtrip():
    assert ast_lint.lint_file(CORPUS / "suppressed_seed.py") == []
    # stripping the allow comment must re-arm the rule
    src = (CORPUS / "suppressed_seed.py").read_text()
    armed = "\n".join(ln for ln in src.splitlines() if "repro: allow" not in ln)
    assert _codes(ast_lint.lint_source(armed, "x.py")) == ["REPRO204"]


def test_ast_pass_real_tree_clean():
    findings, stats = ast_lint.run_pass()
    assert findings == [], [str(f) for f in findings]
    assert stats["files"] >= 60


# ---------------------------------------------------------------------------
# Jaxpr corpus (REPRO1xx) — traced in-process on whatever devices exist
# ---------------------------------------------------------------------------


def test_corpus_correlated_rng_flagged():
    from repro.analysis import jaxpr_lint

    t = _traced()
    f = jaxpr_lint.lint_trace(t.correlated_rng(), "corpus", compressed=False)
    assert _codes(f) == ["REPRO102"]
    assert "axis_index" in f[0].message
    clean = jaxpr_lint.lint_trace(t.decorrelated_rng(), "corpus", compressed=False)
    assert clean == [], [str(x) for x in clean]


def test_corpus_extra_collective_flagged():
    from repro.analysis import jaxpr_lint

    t = _traced()
    closed = t.extra_collective()
    assert jaxpr_lint.count_collectives(closed) == {"all_gather": 2}
    f = jaxpr_lint.check_budget(closed, 1, "corpus")
    assert _codes(f) == ["REPRO101"]
    assert jaxpr_lint.check_budget(closed, 2, "corpus") == []


def test_corpus_f64_leak_flagged():
    from repro.analysis import jaxpr_lint

    t = _traced()
    f = jaxpr_lint.lint_trace(t.f64_leak(), "corpus", compressed=False)
    assert "REPRO103" in _codes(f)


def test_corpus_scatter_add_flagged():
    from repro.analysis import jaxpr_lint

    t = _traced()
    f = jaxpr_lint.lint_trace(t.scatter_add(), "corpus", compressed=False)
    assert _codes(f) == ["REPRO104"]


def test_corpus_wire_dtype_flagged():
    from repro.analysis import jaxpr_lint

    t = _traced()
    closed = t.wire_f32()
    f = jaxpr_lint.lint_trace(closed, "corpus", compressed=True)
    assert _codes(f) == ["REPRO105"]
    # the fp32 pmean of dsgd is that mode's contract, not a finding
    assert jaxpr_lint.lint_trace(closed, "corpus", compressed=False) == []


# ---------------------------------------------------------------------------
# VMEM corpus + the real kernel surface (REPRO3xx)
# ---------------------------------------------------------------------------


def test_corpus_vmem_blowout_flagged():
    from repro.analysis import vmem

    findings, table = vmem.estimate({"blowout": _traced().vmem_blowout_thunk()})
    assert _codes(findings) == ["REPRO301"]
    assert table[0].vmem_bytes > vmem.DEFAULT_BUDGET
    # a budget override admits the same kernel
    ok, _ = vmem.estimate({"blowout": _traced().vmem_blowout_thunk()},
                          budgets={"blowout": 1 << 30})
    assert ok == []


def test_vmem_stale_wiring_detected():
    from repro.analysis import vmem

    findings, table = vmem.estimate({"nothing": lambda: None})
    assert _codes(findings) == ["REPRO301"] and table == []
    assert "stale" in findings[0].message


def test_vmem_real_kernels_within_budget():
    from repro.analysis import vmem

    findings, table = vmem.estimate(vmem.default_thunks())
    assert findings == [], [str(f) for f in findings]
    assert len(table) >= 17
    assert all(e.vmem_bytes <= e.budget_bytes for e in table)


# ---------------------------------------------------------------------------
# Real tree end-to-end: the CLI over 4 fake devices (jaxpr pass included)
# ---------------------------------------------------------------------------


def test_cli_quick_real_tree_clean(tmp_path):
    out = tmp_path / "ANALYSIS.json"
    r = _run_analysis(["--quick", "--json", str(out)])
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    rep = json.loads(out.read_text())
    assert rep["version"] == 1 and rep["clean"] is True and rep["findings"] == []
    per = rep["passes"]["jaxpr"]["per_trace"]
    assert rep["passes"]["jaxpr"]["traces"] == len(per) >= 24
    # the PR 2 collective counts, pinned through the registry budgets
    assert per["sync:faithful/tqsgd"]["collectives"] == {"all_gather": 1}
    assert per["sync:two_phase/tqsgd"]["collectives"] == {
        "all_to_all": 1, "all_gather": 1}
    assert sum(per["sync:hierarchical/tqsgd"]["collectives"].values()) == 3
    assert per["sync:dsgd/tqsgd"]["collectives"] == {
        "psum": per["sync:dsgd/tqsgd"]["n_buckets"]}
    for label, row in per.items():
        if "budget" in row:
            assert sum(row["collectives"].values()) <= row["budget"], (label, row)
    assert rep["passes"]["ast"]["files"] >= 60
    assert rep["passes"]["vmem"]["kernels"] >= 17


def test_ast_pass_over_corpus_reports_findings():
    # a corpus-seeded tree must fail: point the AST pass at the corpus dir
    findings, stats = ast_lint.run_pass(CORPUS)
    assert stats["files"] >= 6
    # unscoped rules fire (bare pallas_call, literal seeds); the
    # path-scoped REPRO201/203 stay off without their relpaths
    assert sorted({f.code for f in findings}) == ["REPRO202", "REPRO204"]


# ---------------------------------------------------------------------------
# Wire cross-check: analytic bytes vs jaxpr-measured collective operands
# ---------------------------------------------------------------------------


def test_wire_model_matches_traced_collectives():
    """``encode_hbm_bytes``/``decode_hbm_bytes`` and the traced all-gather
    operand must all derive from the registry's ``wire_words``."""
    code = """
import jax
from repro.analysis import jaxpr_lint as jl
from repro.core.codecs import bucket_cfgs, get_codec
from repro.core.compressors import CompressorConfig
from repro.dist import compat  # noqa: F401
from repro.dist.collectives import decode_hbm_bytes, encode_hbm_bytes
from repro.dist.train_step import TrainStepConfig, local_bucket_sizes

st = jl.sync_trace("tqsgd", "faithful")
wires = [w for w in jl.collective_wire_sizes(st.closed)
         if w.primitive == "all_gather"]
assert len(wires) == 1, wires
assert all(d == "uint32" for d in wires[0].dtypes), wires[0]

# rebuild the exact bucket geometry the harness traced
cfg = CompressorConfig(method="tqsgd", bits=3, rank=2, approx_gmin=True)
mesh = jax.make_mesh((jl._N_DEV,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
params_like, pspecs = jl._param_trees()
ts = TrainStepConfig(sync="faithful", compressor=cfg, bucket_mb=jl._BUCKET_MB)
sizes = [int(s) for s in local_bucket_sizes(params_like, mesh, pspecs, ts)]
bits = jl._bits_plan("tqsgd", len(sizes))
assert len(sizes) == st.n_buckets >= 2, (sizes, st.n_buckets)

cfgs = bucket_cfgs(cfg, len(sizes), bits)
words = sum(get_codec(c.method).wire_words(c, n) for c, n in zip(cfgs, sizes))
# 1) the traced operand IS the registry wire: 4 bytes per uint32 word
assert wires[0].in_bytes == 4 * words, (wires[0].in_bytes, words)
assert wires[0].out_bytes == jl._N_DEV * wires[0].in_bytes

# 2) the decode model reads exactly peers x that wire (+ the (n,) output)
peers = jl._N_DEV
got = decode_hbm_bytes(cfg, sizes, peers, True, bits)
assert got == peers * wires[0].in_bytes + 4.0 * sum(sizes), got

# 3) the encode model's wire term is the measured wire minus the
#    codebook words the kernel writes straight from VMEM
got = encode_hbm_bytes(cfg, sizes, True, ef=False, adaptive=False, bits=bits)
codebook = sum(4.0 * (c.s + 1) for c in cfgs)
assert got == 8.0 * sum(sizes) + 4 * words - codebook, got
print("WIRE-CHECK-OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=1200, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "WIRE-CHECK-OK" in r.stdout
