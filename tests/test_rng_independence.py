"""Peer-RNG independence of the wire codec (paper Lemma 2 regression).

The paper's error bound assumes each worker's stochastic rounding draws
independent uniforms, so the mean of n workers' quantizations of the *same*
tensor concentrates like 1/sqrt(n).  A codec that hands every peer the same
PRNG stream produces perfectly correlated rounding errors and the mean is no
better than a single worker — these tests pin the concentration.
"""
import numpy as np

from test_dist import run_with_devices

N_PEERS = 8

_COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, AxisType
from repro.core import sample_power_law
from repro.core.compressors import CompressorConfig, compress_decompress
from repro.dist import sharded_codec as sc

M = 1 << 14
mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
cfg = CompressorConfig(method="qsgd", bits=3)  # alpha = max|g|: unbiased, no truncation
g1 = sample_power_law(jax.random.key(0), (M,), gamma=4.0, g_min=0.01, rho=0.1)
G = jnp.tile(g1[None], (8, 1))  # every peer holds the identical tensor

def rms(x):
    return float(jnp.sqrt(jnp.mean(jnp.square(x))))

# single-worker quantization error (same plan/encode pipeline, one draw)
e1 = np.mean([rms(compress_decompress(cfg, g1, jax.random.key(100 + r)) - g1)
              for r in range(4)])
"""


def test_faithful_ring_mean_error_concentrates():
    """mean-of-8-peers error must shrink ~1/sqrt(8) vs one peer on identical
    inputs — fails when all peers draw the same uniforms."""
    out = run_with_devices(_COMMON + """
def ring(x):
    return sc.faithful_ring_mean(cfg, x, "data", jax.random.key(7), False)

smap = jax.shard_map(ring, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                     axis_names={"data"}, check_vma=False)
mean8 = np.asarray(jax.jit(smap)(G))[0]
e8 = rms(mean8 - np.asarray(g1))
ratio = e8 / e1
print("RATIO", ratio)
# independent peers: ratio ~ 1/sqrt(8) = 0.354; correlated peers: ratio ~ 1
assert ratio < 0.55, f"peer quantization errors are correlated: e8/e1={ratio:.3f}"
assert ratio > 0.15, f"suspiciously small error (test broken?): {ratio:.3f}"
print("OK")
""", n=N_PEERS)
    assert "OK" in out


def test_hierarchical_pod_mesh_error_concentrates():
    """On a (2 pod x 4 data) mesh the intra-pod phase must average over ALL
    8 workers' independent draws, not just the 4 data ranks: same-data-rank
    workers in different pods sharing a stream caps the phase-1 error at
    1/sqrt(data) and shows up as a distinctly worse end-to-end ratio
    (measured: ~0.82 correlated vs ~0.71 independent; the floor is the
    cross-pod re-quantization averaging only n_pods=2 draws)."""
    out = run_with_devices(_COMMON.replace(
        'mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))',
        'mesh = jax.make_mesh((2, 4), ("pod", "data"), axis_types=(AxisType.Auto,)*2)') + """
def hier(x):
    means, _ = sc.bucketed_hierarchical_mean(cfg, [x.reshape(-1)], ("pod", "data"),
                                             jax.random.key(7), False)
    return means[0][None]

smap = jax.shard_map(hier, mesh=mesh, in_specs=P(("pod", "data")),
                     out_specs=P(("pod", "data")), axis_names={"pod", "data"}, check_vma=False)
mean8 = np.asarray(jax.jit(smap)(G))[0]
e8 = rms(mean8 - np.asarray(g1))
ratio = e8 / e1
print("RATIO", ratio)
assert ratio < 0.76, f"cross-pod quantization errors are correlated: e8/e1={ratio:.3f}"
print("OK")
""", n=N_PEERS)
    assert "OK" in out


def test_two_phase_reduce_scatter_error_concentrates():
    """Phase-1 chunks of the mean must also average independent draws.

    The baseline here is a single draw of the same per-chunk-codebook
    pipeline (per-chunk alpha is finer than the whole-tensor plan, so the
    whole-tensor ``e1`` would mask the correlation)."""
    out = run_with_devices(_COMMON + """
from repro.core.compressors import plan
from repro.core.quantizers import quantize

rows = g1.reshape(8, -1)
metas = [plan(cfg, row) for row in rows]

def chunked_draw(r):
    vals = [quantize(row, m, jax.random.key(500 + 8 * r + j))
            for j, (row, m) in enumerate(zip(rows, metas))]
    return rms(jnp.concatenate(vals) - g1)

e1c = np.mean([chunked_draw(r) for r in range(4)])

def rs(x):
    return sc.two_phase_reduce_scatter_sharded(cfg, x[0], 0, "data", jax.random.key(7), False)[None]

smap = jax.shard_map(rs, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                     axis_names={"data"}, check_vma=False)
chunks = np.asarray(jax.jit(smap)(G)).reshape(-1)  # peer j's chunk j, concatenated = full mean
e8 = rms(chunks - np.asarray(g1))
ratio = e8 / e1c
print("RATIO", ratio)
assert ratio < 0.55, f"peer quantization errors are correlated: e8/e1c={ratio:.3f}"
print("OK")
""", n=N_PEERS)
    assert "OK" in out
