"""Trip-count-aware HLO cost analysis: validated against analytic FLOPs."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze


def _compiled_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_dot_flops_counted():
    def f(a, b):
        return a @ b

    t = _compiled_text(f, jnp.ones((64, 128)), jnp.ones((128, 32)))
    cost = analyze(t, default_group=1)
    want = 2 * 64 * 128 * 32
    assert abs(cost.flops - want) / want < 0.2, (cost.flops, want)


def test_scan_trip_count_multiplies():
    trips = 13

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, None, length=trips)
        return y

    t = _compiled_text(f, jnp.ones((32, 64)), jnp.ones((64, 64)))
    cost = analyze(t, default_group=1)
    per_iter = 2 * 32 * 64 * 64
    # dot flops must be multiplied by the trip count
    assert cost.flops >= trips * per_iter, (cost.flops, trips * per_iter)
    assert cost.flops < 2 * trips * per_iter


def test_nested_scan():
    def f(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=5)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    t = _compiled_text(f, jnp.ones((16, 32)), jnp.ones((32, 32)))
    cost = analyze(t, default_group=1)
    want = 15 * 2 * 16 * 32 * 32
    assert cost.flops >= want, (cost.flops, want)
    assert cost.flops < 2 * want


def test_xla_raw_cost_undercounts_loops():
    """Demonstrates why the custom walker exists."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, None, length=50)
        return y

    compiled = jax.jit(f).lower(jnp.ones((32, 64)), jnp.ones((64, 64))).compile()
    raw = compiled.cost_analysis()
    if isinstance(raw, list):
        raw = raw[0]
    ours = analyze(compiled.as_text(), default_group=1).flops
    assert ours > 10 * float(raw.get("flops", 0.0) or 1.0)
