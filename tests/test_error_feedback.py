"""Error-feedback extension: residual re-injection cancels truncation bias.

The elastic tests at the bottom pin the stale-EF contract of partial
participation: a dropped peer's residual accumulates its whole corrected
gradient (nothing is transmitted), and on rejoin the backlog drains through
one compressed transmission — no gradient mass is lost to the dropout.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompressorConfig, sample_power_law
from repro.core.error_feedback import compress_with_feedback, init_error


def test_ef_residual_cancels_bias():
    """Averaged over rounds, EF-compressed constant gradients recover the
    true gradient (incl. the truncated tail mass), while plain compression
    keeps a persistent truncation bias."""
    g = {"w": sample_power_law(jax.random.key(0), (20_000,), gamma=3.6, g_min=0.02, rho=0.2)}
    cfg = CompressorConfig(method="tqsgd", bits=3)
    rounds, warmup = 80, 20  # EF needs a few rounds for the residual to build

    # plain: average of C(g)
    plain = jnp.zeros_like(g["w"])
    for i in range(warmup, rounds):
        from repro.core.compressors import compress_decompress

        plain = plain + compress_decompress(cfg, g["w"], jax.random.key(i))
    plain = plain / (rounds - warmup)

    # EF: average of transmitted c_t after warmup
    err = init_error(g)
    ef = jnp.zeros_like(g["w"])
    for i in range(rounds):
        c, err = compress_with_feedback(cfg, g, err, jax.random.key(1000 + i))
        if i >= warmup:
            ef = ef + c["w"]
    ef = ef / (rounds - warmup)

    # the moderate tail (95th-99th pct |g|) is clipped by plain truncation but
    # fully compensated by EF within a few rounds; the extreme tail drains
    # slowly (residual must outgrow α) — measured ratios: 0.03 mid-tail,
    # 0.43 overall.
    gw = g["w"]
    qa, qb = jnp.quantile(jnp.abs(gw), 0.95), jnp.quantile(jnp.abs(gw), 0.99)
    band = (jnp.abs(gw) >= qa) & (jnp.abs(gw) < qb)
    bias_plain = float(jnp.mean(jnp.abs(plain[band] - gw[band])))
    bias_ef = float(jnp.mean(jnp.abs(ef[band] - gw[band])))
    assert bias_ef < 0.2 * bias_plain, (bias_ef, bias_plain)
    all_plain = float(jnp.mean(jnp.abs(plain - gw)))
    all_ef = float(jnp.mean(jnp.abs(ef - gw)))
    assert all_ef < 0.7 * all_plain, (all_ef, all_plain)


def test_ef_training_low_bits():
    """EF lets even b=2 truncated quantization track the uncompressed run."""
    from repro.configs import get_config, reduced
    from repro.data.synthetic import lm_batch
    from repro.models import init_lm, loss_fn
    from repro.optim.optimizers import momentum_sgd

    cfg = reduced(get_config("llama3.2-1b"), layers=2, d_model=128, vocab=256)
    params, _ = init_lm(jax.random.key(0), cfg)
    opt = momentum_sgd(lr=0.05)
    ccfg = CompressorConfig(method="tqsgd", bits=2)

    def run(use_ef):
        p, s = params, opt.init(params)
        err = init_error(params)

        @jax.jit
        def step(p, s, err, i):
            b = lm_batch(cfg, i, 2, 64)
            loss, g = jax.value_and_grad(lambda pp: loss_fn(cfg, pp, b))(p)
            if use_ef:
                g, err2 = compress_with_feedback(ccfg, g, err, jax.random.fold_in(jax.random.key(5), i))
            else:
                from repro.core.compressors import tree_compress_decompress

                g = tree_compress_decompress(ccfg, g, jax.random.fold_in(jax.random.key(5), i))
                err2 = err
            p, s = opt.update(p, g, s, i)
            return p, s, err2, loss

        losses = []
        for i in range(10):
            p, s, err, l = step(p, s, err, jnp.uint32(i))
            losses.append(float(l))
        return losses

    l_ef = run(True)
    l_plain = run(False)
    assert l_ef[-1] <= l_plain[-1] + 0.1, (l_ef, l_plain)
    assert l_ef[-1] < l_ef[0] - 0.3


def test_ef_distributed_runtime():
    """EF threaded through ``make_train_step``: the bucket-resident residual
    rides the step signature (one stacked (clients, bucket) array per codec
    bucket), training converges at aggressive truncation (b=2 tqsgd), and
    the residual is live (non-zero) after the first step."""
    from test_dist import PRELUDE, run_with_devices

    out = run_with_devices(PRELUDE + """
from repro.dist.train_step import init_ef_state, local_bucket_sizes
mesh = jax.make_mesh((4,2), ("data","model"), axis_types=(AxisType.Auto,)*2)
cfg = reduced(get_config("llama3.2-1b")).replace(fsdp=True)
params0, logical = init_lm(jax.random.key(0), cfg)
opt = momentum_sgd(lr=0.05)

def run(ef):
    ts = TrainStepConfig(sync="faithful", compressor=CompressorConfig(method="tqsgd", bits=2),
                         error_feedback=ef)
    batch = lm_batch(cfg, jnp.uint32(0), 8, 128)
    step_fn, pspecs = make_train_step(cfg, mesh, logical, opt, ts, batch)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P))
    p = jax.device_put(jax.tree.map(jnp.copy, params0), sh)
    o = jax.tree.map(jnp.zeros_like, p)
    e = init_ef_state(params0, mesh, pspecs, ts)
    # bucket-resident layout: one (clients, model_shards * local_bucket) row
    # stack per bucket of the trace-time plan
    sizes = local_bucket_sizes(params0, mesh, pspecs, ts)
    assert isinstance(e, tuple) and len(e) == len(sizes), (len(e), sizes)
    assert all(x.shape == (4, 2 * s) for x, s in zip(e, sizes)), \\
        [(x.shape, s) for x, s in zip(e, sizes)]
    losses = []
    for i in range(8):
        b = lm_batch(cfg, jnp.uint32(i), 8, 128)
        if ef:
            p, o, e, m = step_fn(p, o, e, b, jnp.uint32(i))
        else:
            p, o, m = step_fn(p, o, b, jnp.uint32(i))
        losses.append(float(m["loss"][0]))
    enorm = float(sum(jnp.sum(jnp.abs(x)) for x in jax.tree.leaves(e)))
    return losses, enorm

l_ef, enorm = run(True)
l_plain, _ = run(False)
print("ef", ["%.3f" % l for l in l_ef])
print("plain", ["%.3f" % l for l in l_plain])
assert enorm > 0.0, "EF residual never populated"
assert l_ef[-1] < l_ef[0] - 0.3, l_ef
assert l_ef[-1] <= l_plain[-1] + 0.1, (l_ef, l_plain)
print("OK")
""")
    assert "OK" in out


# ---------------------------------------------------------------------------
# elastic: stale-EF recovery and the solo-survivor degenerate live set
# ---------------------------------------------------------------------------

_LEAF_SHAPES = [(2048,), (999,), (64, 17)]


def _elastic_setup(ts, n, seed=0):
    """Stacked per-peer leaves + zeroed bucket-resident EF for ``ts``."""
    from repro.core.compressors import plan_buckets
    from repro.dist import sharded_codec as sc

    key = jax.random.key(seed)
    leaves = [
        (jax.random.normal(jax.random.fold_in(key, i), (n,) + s) * 0.05
         ).astype(jnp.float32)
        for i, s in enumerate(_LEAF_SHAPES)
    ]
    bp = plan_buckets([int(np.prod(s)) for s in _LEAF_SHAPES], ts.bucket_elements)
    st = sc.bucket_state_sizes(ts.compressor, bp.sizes, ts.bits_plan)
    ef = [jnp.zeros((n, m), jnp.float32) for m in st]
    return leaves, bp, ef


def _bucket_rows(leaves, bp, peer):
    """Peer ``peer``'s gradient in bucket layout (the EF state's layout)."""
    from repro.core.compressors import bucket_concat

    return bucket_concat([x[peer] for x in leaves], bp)


def test_stale_ef_accumulates_and_drains_on_rejoin():
    """Partition chaos: peer 0 dark for 3 steps, then the fleet rejoins.

    While dark, peer 0's residual row must accumulate exactly k·g (its
    corrected bucket each step — nothing transmitted); within one rejoin
    step the backlog drains to ordinary quantization error.  The live
    peers' mean meanwhile tracks *their* renormalized mean, not a mean
    diluted by the dead peer's zeros.
    """
    from repro.dist.reference import reference_sync_state
    from repro.dist.train_step import TrainStepConfig
    from repro.elastic import partition

    n, dark_steps = 4, 3
    ts = TrainStepConfig(
        sync="faithful", bucket_mb=1.0 / 64.0, error_feedback=True,
        compressor=CompressorConfig(method="tnqsgd", bits=3))
    leaves, bp, ef = _elastic_setup(ts, n)
    trace = partition(n, down=(0,), down_steps=dark_steps, up_steps=1)
    cfg_el = trace.elastic()
    from repro.elastic import live_mask

    g0 = _bucket_rows(leaves, bp, 0)
    key = jax.random.key(42)
    for step in range(dark_steps):
        lv = live_mask(cfg_el, step, n)
        means, ef, _, _ = reference_sync_state(
            ts, leaves, (n,), jax.random.fold_in(key, step), ef=ef, live=lv)
        # dead peer's residual is exactly (step+1) copies of its gradient
        for b in range(bp.n_buckets):
            np.testing.assert_allclose(
                np.asarray(ef[b][0]), (step + 1) * np.asarray(g0[b]),
                rtol=1e-5, atol=1e-8, err_msg=f"dark step {step} bucket {b}")
        # the mean tracks the live peers' renormalized mean
        from repro.core.compressors import bucket_concat

        mean_b = bucket_concat(means, bp)
        live_mean = [jnp.mean(jnp.stack(
            [_bucket_rows(leaves, bp, p)[b] for p in range(1, n)]), axis=0)
            for b in range(bp.n_buckets)]
        for b in range(bp.n_buckets):
            err = float(jnp.linalg.norm(mean_b[b] - live_mean[b])
                        / jnp.linalg.norm(live_mean[b]))
            assert err < 0.35, (step, b, err)

    backlog = [float(jnp.linalg.norm(ef[b][0])) for b in range(bp.n_buckets)]
    # rejoin: everyone live; peer 0 transmits C(3·g + g) and the backlog
    # collapses to quantization error — the pinned recovery window is ONE
    # step for a 3-step outage.
    lv = live_mask(cfg_el, dark_steps, n)
    assert float(jnp.sum(lv)) == n
    means, ef, _, _ = reference_sync_state(
        ts, leaves, (n,), jax.random.fold_in(key, dark_steps), ef=ef, live=lv)
    for b in range(bp.n_buckets):
        drained = float(jnp.linalg.norm(ef[b][0]))
        assert drained < 0.5 * backlog[b], (b, drained, backlog[b])
        # and the rejoin mean carries the backlog: peer 0's contribution is
        # ~4x its per-step gradient, so the mean shifts toward g0
        assert drained < backlog[b]


def test_solo_survivor_every_sync_mode():
    """k=1 live: the mean must be the survivor's own (compressed) gradient —
    dead peers cannot move it, and their EF rows keep their full buckets."""
    from repro.dist.reference import reference_sync_state
    from repro.dist.train_step import TrainStepConfig
    from repro.elastic import solo_survivor

    n, survivor = 4, 2
    lv = jnp.asarray(solo_survivor(n, survivor=survivor).rows[0], jnp.float32)
    key = jax.random.key(7)
    for sync, dp_sizes in (("dsgd", (n,)), ("two_phase", (n,)),
                           ("faithful", (n,)), ("hierarchical", (2, 2))):
        ts = TrainStepConfig(
            sync=sync, bucket_mb=1.0 / 64.0, error_feedback=sync != "dsgd",
            compressor=CompressorConfig(method="tnqsgd", bits=3))
        leaves, bp, ef = _elastic_setup(ts, n, seed=3)
        means, resids, _, _ = reference_sync_state(
            ts, leaves, dp_sizes, key, ef=ef if sync != "dsgd" else None, live=lv)
        gs = _bucket_rows(leaves, bp, survivor)
        from repro.core.compressors import bucket_concat

        mean_b = bucket_concat(means, bp)
        for b in range(bp.n_buckets):
            err = float(jnp.linalg.norm(mean_b[b] - gs[b]) / jnp.linalg.norm(gs[b]))
            # loose sanity bar only — two_phase re-quantizes the mean in
            # phase 2, doubling the noise; the bitwise pins below are the
            # real contract
            assert err < 0.5, (sync, b, err)
        # dead peers cannot move the mean: perturb them, replay, compare
        poked = [l.at[0].mul(-5.0).at[1].mul(3.0).at[3].mul(-0.5) if survivor != 0
                 else l for l in leaves]
        means2, _, _, _ = reference_sync_state(
            ts, poked, dp_sizes, key, ef=ef if sync != "dsgd" else None, live=lv)
        for a, b in zip(means, means2):
            if sync == "dsgd":
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-7)
            else:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                              err_msg=f"{sync}: dead peer moved mean")
        # stale-EF: every dead peer's residual row is its whole bucket
        if resids is not None:
            for b in range(bp.n_buckets):
                for p in range(n):
                    if p == survivor:
                        continue
                    np.testing.assert_allclose(
                        np.asarray(resids[b][p]),
                        np.asarray(_bucket_rows(leaves, bp, p)[b]),
                        rtol=1e-6, atol=1e-8,
                        err_msg=f"{sync}: peer {p} bucket {b} residual not stale")
