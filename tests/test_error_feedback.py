"""Error-feedback extension: residual re-injection cancels truncation bias."""
import jax
import jax.numpy as jnp

from repro.core import CompressorConfig, sample_power_law
from repro.core.error_feedback import compress_with_feedback, init_error


def test_ef_residual_cancels_bias():
    """Averaged over rounds, EF-compressed constant gradients recover the
    true gradient (incl. the truncated tail mass), while plain compression
    keeps a persistent truncation bias."""
    g = {"w": sample_power_law(jax.random.key(0), (20_000,), gamma=3.6, g_min=0.02, rho=0.2)}
    cfg = CompressorConfig(method="tqsgd", bits=3)
    rounds, warmup = 80, 20  # EF needs a few rounds for the residual to build

    # plain: average of C(g)
    plain = jnp.zeros_like(g["w"])
    for i in range(warmup, rounds):
        from repro.core.compressors import compress_decompress

        plain = plain + compress_decompress(cfg, g["w"], jax.random.key(i))
    plain = plain / (rounds - warmup)

    # EF: average of transmitted c_t after warmup
    err = init_error(g)
    ef = jnp.zeros_like(g["w"])
    for i in range(rounds):
        c, err = compress_with_feedback(cfg, g, err, jax.random.key(1000 + i))
        if i >= warmup:
            ef = ef + c["w"]
    ef = ef / (rounds - warmup)

    # the moderate tail (95th-99th pct |g|) is clipped by plain truncation but
    # fully compensated by EF within a few rounds; the extreme tail drains
    # slowly (residual must outgrow α) — measured ratios: 0.03 mid-tail,
    # 0.43 overall.
    gw = g["w"]
    qa, qb = jnp.quantile(jnp.abs(gw), 0.95), jnp.quantile(jnp.abs(gw), 0.99)
    band = (jnp.abs(gw) >= qa) & (jnp.abs(gw) < qb)
    bias_plain = float(jnp.mean(jnp.abs(plain[band] - gw[band])))
    bias_ef = float(jnp.mean(jnp.abs(ef[band] - gw[band])))
    assert bias_ef < 0.2 * bias_plain, (bias_ef, bias_plain)
    all_plain = float(jnp.mean(jnp.abs(plain - gw)))
    all_ef = float(jnp.mean(jnp.abs(ef - gw)))
    assert all_ef < 0.7 * all_plain, (all_ef, all_plain)


def test_ef_training_low_bits():
    """EF lets even b=2 truncated quantization track the uncompressed run."""
    from repro.configs import get_config, reduced
    from repro.data.synthetic import lm_batch
    from repro.models import init_lm, loss_fn
    from repro.optim.optimizers import momentum_sgd

    cfg = reduced(get_config("llama3.2-1b"), layers=2, d_model=128, vocab=256)
    params, _ = init_lm(jax.random.key(0), cfg)
    opt = momentum_sgd(lr=0.05)
    ccfg = CompressorConfig(method="tqsgd", bits=2)

    def run(use_ef):
        p, s = params, opt.init(params)
        err = init_error(params)

        @jax.jit
        def step(p, s, err, i):
            b = lm_batch(cfg, i, 2, 64)
            loss, g = jax.value_and_grad(lambda pp: loss_fn(cfg, pp, b))(p)
            if use_ef:
                g, err2 = compress_with_feedback(ccfg, g, err, jax.random.fold_in(jax.random.key(5), i))
            else:
                from repro.core.compressors import tree_compress_decompress

                g = tree_compress_decompress(ccfg, g, jax.random.fold_in(jax.random.key(5), i))
                err2 = err
            p, s = opt.update(p, g, s, i)
            return p, s, err2, loss

        losses = []
        for i in range(10):
            p, s, err, l = step(p, s, err, jnp.uint32(i))
            losses.append(float(l))
        return losses

    l_ef = run(True)
    l_plain = run(False)
    assert l_ef[-1] <= l_plain[-1] + 0.1, (l_ef, l_plain)
    assert l_ef[-1] < l_ef[0] - 0.3


def test_ef_distributed_runtime():
    """EF threaded through ``make_train_step``: the bucket-resident residual
    rides the step signature (one stacked (clients, bucket) array per codec
    bucket), training converges at aggressive truncation (b=2 tqsgd), and
    the residual is live (non-zero) after the first step."""
    from test_dist import PRELUDE, run_with_devices

    out = run_with_devices(PRELUDE + """
from repro.dist.train_step import init_ef_state, local_bucket_sizes
mesh = jax.make_mesh((4,2), ("data","model"), axis_types=(AxisType.Auto,)*2)
cfg = reduced(get_config("llama3.2-1b")).replace(fsdp=True)
params0, logical = init_lm(jax.random.key(0), cfg)
opt = momentum_sgd(lr=0.05)

def run(ef):
    ts = TrainStepConfig(sync="faithful", compressor=CompressorConfig(method="tqsgd", bits=2),
                         error_feedback=ef)
    batch = lm_batch(cfg, jnp.uint32(0), 8, 128)
    step_fn, pspecs = make_train_step(cfg, mesh, logical, opt, ts, batch)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P))
    p = jax.device_put(jax.tree.map(jnp.copy, params0), sh)
    o = jax.tree.map(jnp.zeros_like, p)
    e = init_ef_state(params0, mesh, pspecs, ts)
    # bucket-resident layout: one (clients, model_shards * local_bucket) row
    # stack per bucket of the trace-time plan
    sizes = local_bucket_sizes(params0, mesh, pspecs, ts)
    assert isinstance(e, tuple) and len(e) == len(sizes), (len(e), sizes)
    assert all(x.shape == (4, 2 * s) for x, s in zip(e, sizes)), \\
        [(x.shape, s) for x, s in zip(e, sizes)]
    losses = []
    for i in range(8):
        b = lm_batch(cfg, jnp.uint32(i), 8, 128)
        if ef:
            p, o, e, m = step_fn(p, o, e, b, jnp.uint32(i))
        else:
            p, o, m = step_fn(p, o, b, jnp.uint32(i))
        losses.append(float(m["loss"][0]))
    enorm = float(sum(jnp.sum(jnp.abs(x)) for x in jax.tree.leaves(e)))
    return losses, enorm

l_ef, enorm = run(True)
l_plain, _ = run(False)
print("ef", ["%.3f" % l for l in l_ef])
print("plain", ["%.3f" % l for l in l_plain])
assert enorm > 0.0, "EF residual never populated"
assert l_ef[-1] < l_ef[0] - 0.3, l_ef
assert l_ef[-1] <= l_plain[-1] + 0.1, (l_ef, l_plain)
print("OK")
""")
    assert "OK" in out
