"""Install the repro JAX compatibility shims as soon as ``jax`` is imported.

The distributed tests (and user code following them) use the modern JAX
surface — ``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``,
``jax.shard_map`` — *before* importing any ``repro`` module, so the shims in
:mod:`repro.dist.compat` must be live by the time ``import jax`` returns.
Because this file sits next to the ``repro`` package on ``PYTHONPATH``,
Python's ``site`` machinery imports it at interpreter startup; it registers a
one-shot meta-path hook that runs the shim installer immediately after the
real ``jax`` module executes.  On JAX versions that already provide the
modern names the installer is a no-op.
"""
import importlib.abc
import importlib.util
import pathlib
import sys


def _install_shims():
    # Load compat.py directly by path: importing ``repro.dist.compat`` through
    # the package would run ``repro.dist.__init__`` (and transitively
    # ``repro.core``), which may be the very import that triggered jax — the
    # direct load keeps the hook cycle-free.  The installers are idempotent,
    # so the later regular import of repro.dist.compat is harmless.
    path = pathlib.Path(__file__).resolve().parent / "repro" / "dist" / "compat.py"
    spec = importlib.util.spec_from_file_location("_repro_jax_compat_bootstrap", path)
    module = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(module)


class _ShimLoader(importlib.abc.Loader):
    def __init__(self, inner):
        self._inner = inner

    def create_module(self, spec):
        return self._inner.create_module(spec)

    def exec_module(self, module):
        self._inner.exec_module(module)
        _install_shims()


class _JaxShimFinder(importlib.abc.MetaPathFinder):
    def find_spec(self, name, path=None, target=None):
        if name != "jax":
            return None
        sys.meta_path.remove(self)  # one-shot; avoid recursing into find_spec
        spec = importlib.util.find_spec("jax")
        if spec is not None and spec.loader is not None:
            spec.loader = _ShimLoader(spec.loader)
        return spec


sys.meta_path.insert(0, _JaxShimFinder())
