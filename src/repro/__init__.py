"""Reproduction of "Improved Quantization Strategies for Managing
Heavy-tailed Gradients in Distributed Learning" as a jax/pallas runtime.

Subpackages: ``core`` (quantizers/compressors), ``kernels`` (Pallas),
``dist`` (sharding + compressed collectives), ``models`` (LM zoo),
``optim``, ``data``, ``configs``, ``launch``, ``checkpoint``.
"""
