"""Static-analysis passes over the sync surface (``python -m repro.analysis``).

Three passes, one rule-code band each:

- ``REPRO1xx`` — :mod:`repro.analysis.jaxpr_lint`: traces every registered
  sync mode × codec through the ``dist.train_step`` / ``dist.reference``
  closures and walks the ClosedJaxpr — collective counts against the
  budgets declared on the codec registry, per-peer RNG decorrelation
  (every ``random_*`` key inside a shard_map region must data-depend on
  ``axis_index``), f64 leaks, nondeterministic reductions, and non-uint32
  wire tensors crossing collective boundaries.
- ``REPRO2xx`` — :mod:`repro.analysis.ast_lint`: architectural rules over
  the source tree — no method-string dispatch in the collective bodies,
  no bare ``pl.pallas_call`` outside ``kernels/``, interpret-fallback
  dispatch on every kernel wrapper, no literal PRNG seeds in library code.
- ``REPRO3xx`` — :mod:`repro.analysis.vmem`: static VMEM footprint of each
  Pallas kernel from its BlockSpecs/grid against a per-kernel budget.

A finding is suppressed by a source comment on (or one line above) the
offending line::

    x = jnp.zeros(...).at[b].add(v)  # repro: allow REPRO104 (CPU-only path)

This module is deliberately import-light (no jax): the CLI must set
``XLA_FLAGS`` for the fake-device mesh before jax loads, and the AST pass
has no reason to pay for a jax import.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["Finding", "RULES", "suppressed_codes", "filter_suppressed"]

#: rule code -> one-line description (the taxonomy REPRO1xx/2xx/3xx)
RULES = {
    "REPRO101": "collective count exceeds the mode's declared budget",
    "REPRO102": "random_* key inside shard_map lacks an axis_index dependency "
                "(correlated per-peer quantization RNG)",
    "REPRO103": "float64 value in a traced sync computation",
    "REPRO104": "nondeterministic float reduction (scatter-add without "
                "unique indices)",
    "REPRO105": "non-uint32 tensor crossing a compressed-wire collective",
    "REPRO201": "method-string dispatch inside a collective body",
    "REPRO202": "bare pl.pallas_call outside kernels/",
    "REPRO203": "kernel wrapper without interpret-fallback dispatch",
    "REPRO204": "argless/literal jax.random.PRNGKey/key seed in library code",
    "REPRO301": "Pallas kernel VMEM footprint exceeds its budget",
}

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\s+(REPRO\d{3}(?:\s*,\s*REPRO\d{3})*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a source location when known."""

    code: str            # REPROxxx
    where: str           # "path:line" or a trace label like "faithful/tqsgd"
    message: str

    def __str__(self) -> str:
        return f"{self.where}: {self.code} {self.message}"

    def to_json(self) -> dict:
        return {"code": self.code, "where": self.where, "message": self.message}


def suppressed_codes(source_lines: list[str], lineno: int) -> frozenset[str]:
    """Codes allowed at 1-based ``lineno``: a ``# repro: allow REPROxxx``
    comment on the line itself or on the line directly above."""
    codes: set[str] = set()
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(source_lines):
            m = _ALLOW_RE.search(source_lines[ln - 1])
            if m:
                codes.update(c.strip() for c in m.group(1).split(","))
    return frozenset(codes)


def filter_suppressed(findings: list[Finding], sources: dict[str, list[str]]) -> list[Finding]:
    """Drop findings whose ``path:line`` location carries an allow comment.

    ``sources`` maps path -> source lines; findings anchored to unlisted
    paths (or to trace labels) pass through unfiltered.
    """
    out = []
    for f in findings:
        path, _, line = f.where.rpartition(":")
        if (path in sources and line.isdigit()
                and f.code in suppressed_codes(sources[path], int(line))):
            continue
        out.append(f)
    return out
