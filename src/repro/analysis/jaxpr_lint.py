"""Pass 1 (``REPRO1xx``): jaxpr linter over the traced sync surface.

Traces every registered sync mode × codec method through the
``dist.train_step._make_sync_fn`` shard_map closure (the collective
surface ``make_train_step`` compiles) and the single-device
``dist.reference`` replay, then walks each ClosedJaxpr:

- **REPRO101** — collective eqns (``all_gather`` / ``all_to_all`` / ``psum``
  / ``ppermute`` / …) counted against the budget the codec registry
  declares per sync mode (``core.codecs.Codec.collective_budget``): 1 for
  ``faithful``, 2 for ``two_phase``, 3 for ``hierarchical``, one ``pmean``
  per bucket for uncompressed ``dsgd``.  This is the reusable checker that
  replaced the ad-hoc trace-count assertions in
  ``benchmarks/adaptive_bench.py``.
- **REPRO102** — every ``random_bits`` / ``threefry2x32`` draw inside a
  shard_map region must have a data dependency on ``axis_index``: peers
  folding the same step key without the axis index draw *identical*
  quantization noise, the exact correlated-RNG bug PR 2 fixed.  Key
  derivation (``random_split`` / ``random_fold_in``) is exempt — only
  payload draws are checked.
- **REPRO103** — float64 values anywhere in the trace.
- **REPRO104** — float scatter-add without ``unique_indices`` (reduction
  order, and therefore the synced bytes, become schedule-dependent).
- **REPRO105** — non-uint32 operands crossing an ``all_gather`` /
  ``all_to_all`` boundary in a compressed trace (the wire contract: one
  uint32 word vector per bucket).

Findings anchored to a source line honor the ``# repro: allow REPROxxx``
comment suppression (see :mod:`repro.analysis`).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import pathlib

import jax
import jax.numpy as jnp

from . import Finding, suppressed_codes

#: primitives that move bytes between peers (the budgeted set)
COLLECTIVES = frozenset(
    {"all_to_all", "all_gather", "psum", "ppermute", "all_gather_invariant",
     "reduce_scatter"})

#: collectives whose operands are wire tensors under the compressed codec
_WIRE_COLLECTIVES = frozenset(
    {"all_to_all", "all_gather", "all_gather_invariant", "ppermute",
     "reduce_scatter"})

#: payload RNG draws (key derivation — random_split/random_fold_in — exempt)
_RNG_CONSUMERS = frozenset({"random_bits", "threefry2x32"})


def _inner_jaxpr(v):
    """The Jaxpr inside a ClosedJaxpr/Jaxpr param value, else None."""
    j = getattr(v, "jaxpr", None)
    if j is not None and hasattr(j, "eqns"):
        return j
    return v if hasattr(v, "eqns") else None


def _sub_jaxprs(eqn):
    """Yield ``(jaxpr, outer_invars | None)`` for each sub-jaxpr of ``eqn``.

    ``outer_invars`` maps positionally onto the sub-jaxpr's invars when the
    correspondence is 1:1 (pjit / shard_map / scan / custom_* calls);
    ``cond`` branches bind ``eqn.invars[1:]``; anything else yields None
    and the caller must treat the mapping as unknown.
    """
    if eqn.primitive.name == "cond":
        for br in eqn.params["branches"]:
            yield _inner_jaxpr(br), list(eqn.invars[1:])
        return
    for v in eqn.params.values():
        j = _inner_jaxpr(v)
        if j is None:
            continue
        yield j, (list(eqn.invars) if len(j.invars) == len(eqn.invars) else None)


def walk_eqns(jaxpr):
    """Depth-first iterator over every eqn of ``jaxpr`` and its sub-jaxprs."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)  # ClosedJaxpr -> Jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub, _ in _sub_jaxprs(eqn):
            yield from walk_eqns(sub)


def count_collectives(jaxpr) -> collections.Counter:
    """Collective-primitive counts over ``jaxpr`` (Closed or plain), at any
    nesting depth — the reusable checker behind REPRO101 and the
    ``adaptive_bench`` collective-count rows."""
    acc: collections.Counter = collections.Counter()
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name in COLLECTIVES:
            acc[eqn.primitive.name] += 1
    return acc


@dataclasses.dataclass(frozen=True)
class WireOp:
    """One collective eqn's operand/result geometry."""

    primitive: str
    in_bytes: int        # bytes this device feeds into the collective
    out_bytes: int       # bytes it holds afterwards
    dtypes: tuple[str, ...]


def collective_wire_sizes(jaxpr) -> list[WireOp]:
    """Measured wire-tensor sizes of every collective in the trace — the
    jaxpr side of the ``encode_hbm_bytes`` / ``decode_hbm_bytes``
    cross-check (``tests/test_analysis.py``)."""
    out = []
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name not in COLLECTIVES:
            continue
        ins = [v.aval for v in eqn.invars if hasattr(v, "aval")]
        outs = [v.aval for v in eqn.outvars if hasattr(v, "aval")]
        out.append(WireOp(
            primitive=eqn.primitive.name,
            in_bytes=sum(a.size * a.dtype.itemsize for a in ins),
            out_bytes=sum(a.size * a.dtype.itemsize for a in outs),
            dtypes=tuple(str(a.dtype) for a in ins),
        ))
    return out


# ---------------------------------------------------------------------------
# Source anchoring + suppression
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=256)
def _source_lines(path: str) -> tuple[str, ...]:
    try:
        return tuple(pathlib.Path(path).read_text().splitlines())
    except OSError:
        return ()


def _eqn_site(eqn):
    """``(file, line)`` of the user frame that bound ``eqn``, else None."""
    try:
        from jax._src import source_info_util

        frame = source_info_util.user_frame(eqn.source_info)
    except Exception:
        return None
    if frame is None:
        return None
    return frame.file_name, frame.start_line


def _emit(findings: list[Finding], code: str, eqn, label: str, message: str) -> None:
    site = _eqn_site(eqn)
    if site is not None:
        path, line = site
        if code in suppressed_codes(list(_source_lines(path)), line):
            return
        where = f"{path}:{line}"
    else:
        where = label
    findings.append(Finding(code, where, f"[{label}] {message}"))


# ---------------------------------------------------------------------------
# REPRO102: axis_index -> RNG-key taint analysis
# ---------------------------------------------------------------------------


def _check_rng(jaxpr, tainted: set, in_shard_map: bool, label: str,
               findings: list[Finding]) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "axis_index":
            tainted.update(eqn.outvars)
            continue
        any_taint = any(not isinstance(v, jax.core.Literal) and v in tainted
                        for v in eqn.invars)
        for sub, outer in _sub_jaxprs(eqn):
            inner = (set(sub.invars) if any_taint else set()) if outer is None \
                else {iv for iv, ov in zip(sub.invars, outer)
                      if not isinstance(ov, jax.core.Literal) and ov in tainted}
            _check_rng(sub, inner, in_shard_map or name == "shard_map",
                       label, findings)
        if in_shard_map and name in _RNG_CONSUMERS and not any_taint:
            _emit(findings, "REPRO102", eqn, label,
                  f"{name} key has no data dependency on axis_index — all "
                  "peers draw identical quantization noise")
        if any_taint:
            tainted.update(eqn.outvars)


# ---------------------------------------------------------------------------
# The per-trace lint (REPRO102-105) and budget check (REPRO101)
# ---------------------------------------------------------------------------


def lint_trace(jaxpr, label: str, *, compressed: bool = True) -> list[Finding]:
    """REPRO102/103/104/105 over one traced computation.

    ``compressed=False`` (the dsgd fp32 paths) skips the uint32 wire-dtype
    rule — an fp32 ``pmean`` is that mode's contract.
    """
    findings: list[Finding] = []
    core = getattr(jaxpr, "jaxpr", jaxpr)
    _check_rng(core, set(), False, label, findings)
    for eqn in walk_eqns(core):
        name = eqn.primitive.name
        for v in list(eqn.outvars) + list(eqn.invars):
            aval = getattr(v, "aval", None)
            if aval is not None and getattr(aval, "dtype", None) == jnp.float64:
                _emit(findings, "REPRO103", eqn, label,
                      f"{name} touches float64 (dtype {aval.dtype})")
                break
        if name == "scatter-add" and not eqn.params.get("unique_indices", False):
            aval = eqn.outvars[0].aval
            if jnp.issubdtype(aval.dtype, jnp.floating):
                _emit(findings, "REPRO104", eqn, label,
                      "float scatter-add without unique_indices: reduction "
                      "order (and synced bytes) become schedule-dependent")
        if compressed and name in _WIRE_COLLECTIVES:
            for v in eqn.invars:
                aval = getattr(v, "aval", None)
                if aval is not None and aval.dtype != jnp.uint32:
                    _emit(findings, "REPRO105", eqn, label,
                          f"{name} moves {aval.dtype} across the compressed "
                          "wire; the codec contract is uint32 words")
    # one eqn site can be traced many times (per bucket, per phase)
    return list(dict.fromkeys(findings))


def check_budget(jaxpr, budget: int, label: str) -> list[Finding]:
    """REPRO101: total collective count vs the registry-declared budget."""
    counts = count_collectives(jaxpr)
    total = sum(counts.values())
    if total > budget:
        detail = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
        return [Finding("REPRO101", label,
                        f"[{label}] {total} collectives traced ({detail}) "
                        f"vs a budget of {budget}")]
    return []


# ---------------------------------------------------------------------------
# Trace harness: tiny sync closures over fake host devices
# ---------------------------------------------------------------------------

_N_DEV = 4
_LEAF_SIZES = (2048, 1024)
_BUCKET_MB = 0.008  # ~2048-element buckets -> 2 buckets over _LEAF_SIZES


@dataclasses.dataclass(frozen=True)
class SyncTrace:
    """One traced mode × method sync closure plus its budget inputs."""

    label: str
    closed: object       # ClosedJaxpr
    n_buckets: int
    budget: int
    compressed: bool


def _require_devices() -> None:
    if len(jax.devices()) < _N_DEV:
        raise RuntimeError(
            f"the jaxpr pass traces over {_N_DEV} devices; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={_N_DEV} "
            "(the CLI and CI job set this before importing jax)")


def _param_trees():
    params_like = {f"p{i}": jax.ShapeDtypeStruct((s,), jnp.float32)
                   for i, s in enumerate(_LEAF_SIZES)}
    from jax.sharding import PartitionSpec as P

    pspecs = {k: P() for k in params_like}
    return params_like, pspecs


def _bits_plan(method: str, n_buckets: int):
    """Heterogeneous per-bucket plan (the adaptive wire) for quantizer
    methods; rank-based codecs keep the base config."""
    from repro.core.codecs import get_codec

    if get_codec(method).rank_based or n_buckets < 2:
        return None
    return tuple(2 + (i % 3) for i in range(n_buckets))


def sync_trace(method: str, mode: str) -> SyncTrace:
    """Trace ``_make_sync_fn`` for one mode × method with EF + telemetry
    threaded (where the mode supports them) and a heterogeneous bit plan."""
    from repro.adaptive.controller import AdaptiveConfig
    from repro.core.codecs import get_codec
    from repro.core.compressors import CompressorConfig
    from repro.dist.train_step import (TrainStepConfig, _make_sync_fn,
                                       init_ef_state, init_telemetry_state,
                                       local_bucket_sizes)

    from repro.dist import compat  # noqa: F401  (installs AxisType/make_mesh shims)

    _require_devices()
    AxisType = jax.sharding.AxisType
    shape, axes = ((2, 2), ("pod", "data")) if mode == "hierarchical" \
        else ((_N_DEV,), ("data",))
    mesh = jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    params_like, pspecs = _param_trees()
    compressed = not (mode == "dsgd" or method == "dsgd")
    cfg = CompressorConfig(method=method, bits=3, rank=2, approx_gmin=True)
    ts0 = TrainStepConfig(sync=mode, compressor=cfg, bucket_mb=_BUCKET_MB)
    n_buckets = len(local_bucket_sizes(params_like, mesh, pspecs, ts0))
    ts = TrainStepConfig(
        sync=mode, compressor=cfg, bucket_mb=_BUCKET_MB,
        error_feedback=compressed,
        adaptive=AdaptiveConfig() if compressed else None,
        bits_plan=_bits_plan(method, n_buckets) if compressed else None)
    stacked = {k: jnp.zeros((_N_DEV,) + tuple(v.shape), v.dtype)
               for k, v in params_like.items()}
    extras = []
    if ts.error_feedback:
        extras.append(init_ef_state(params_like, mesh, pspecs, ts))
    if ts.adaptive is not None:
        extras.append(init_telemetry_state(params_like, mesh, pspecs, ts))
    # geometry-only trace key; never executed
    key = jax.random.key(0)  # repro: allow REPRO204 (trace-time aval only)
    jfn = jax.jit(_make_sync_fn(ts, mesh, pspecs, stacked))
    closed = jfn.trace(stacked, key, *extras).jaxpr
    budget = get_codec(method).collective_budget(mode, n_buckets)
    return SyncTrace(label=f"sync:{mode}/{method}", closed=closed,
                     n_buckets=n_buckets, budget=budget, compressed=compressed)


def reference_trace(method: str, mode: str) -> SyncTrace:
    """Trace the single-device ``dist.reference`` replay (no collectives —
    budget 0 — but the dtype/determinism rules still apply)."""
    from repro.core.compressors import CompressorConfig
    from repro.dist import reference
    from repro.dist.train_step import TrainStepConfig

    cfg = CompressorConfig(method=method, bits=3, rank=2, approx_gmin=True)
    ts = TrainStepConfig(sync=mode, compressor=cfg, bucket_mb=_BUCKET_MB)
    leaves = [jnp.zeros((_N_DEV, s), jnp.float32) for s in _LEAF_SIZES]
    key = jax.random.key(0)  # repro: allow REPRO204 (trace-time aval only)
    closed = jax.jit(
        lambda lv, k: reference.reference_sync(ts, lv, (_N_DEV,), k)
    ).trace(leaves, key).jaxpr
    compressed = not (mode == "dsgd" or method == "dsgd")
    return SyncTrace(label=f"reference:{mode}/{method}", closed=closed,
                     n_buckets=0, budget=0, compressed=compressed)


#: the mode sweep (dsgd = the uncompressed pmean baseline)
MODES = ("faithful", "two_phase", "hierarchical", "dsgd")

#: reference replays are method-redundant; spot-check one per decode family
_REFERENCE_METHODS = ("tqsgd", "tnqsgd", "powersgd", "dsgd")


def run_pass(methods=None, modes=None, *, quick: bool = False):
    """Trace + lint the full mode × method matrix.

    Returns ``(findings, stats)``; ``stats`` carries per-trace collective
    counts for the JSON report.  ``quick`` restricts to one method per
    codec family (the tier-1 test configuration).
    """
    from repro.core.codecs import known_methods

    if methods is None:
        methods = ("tqsgd", "powersgd", "dsgd") if quick else known_methods()
    if modes is None:
        modes = MODES
    findings: list[Finding] = []
    traces: dict[str, dict] = {}
    for mode in modes:
        for method in methods:
            st = sync_trace(method, mode)
            findings += check_budget(st.closed, st.budget, st.label)
            findings += lint_trace(st.closed, st.label, compressed=st.compressed)
            traces[st.label] = {
                "collectives": dict(count_collectives(st.closed)),
                "budget": st.budget, "n_buckets": st.n_buckets}
        ref_methods = methods if quick else \
            tuple(m for m in _REFERENCE_METHODS if m in methods)
        for method in ref_methods:
            rt = reference_trace(method, mode)
            findings += lint_trace(rt.closed, rt.label, compressed=rt.compressed)
            traces[rt.label] = {"collectives": dict(count_collectives(rt.closed))}
    return findings, {"traces": len(traces), "per_trace": traces}
