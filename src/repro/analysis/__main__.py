"""``python -m repro.analysis`` — run the three static-analysis passes.

Usage::

    PYTHONPATH=src python -m repro.analysis [--json ANALYSIS.json]
        [--pass jaxpr|ast|vmem ...] [--quick]

Exit status is non-zero iff any finding survives suppression.  The JSON
report schema (validated in CI by ``benchmarks/check_analysis.py``)::

    {"version": 1,
     "passes": {"jaxpr": {"traces": N, "per_trace": {...}},
                "ast":   {"files": N},
                "vmem":  {"kernels": N, "table": [...]}},
     "findings": [{"code": ..., "where": ..., "message": ...}],
     "clean": true}
"""
# The jaxpr pass traces shard_map over a 4-device mesh; the fake-device
# flag must land in the environment before jax is first imported, so the
# pass modules are imported lazily inside main().
from __future__ import annotations

import argparse
import json
import os
import sys

PASSES = ("jaxpr", "ast", "vmem")


def _ensure_fake_devices(n: int = 4) -> None:
    flag = f"--xla_force_host_platform_device_count={n}"
    if "jax" in sys.modules:
        return  # too late to change device count; _require_devices() reports
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="three-pass static analysis of the sync surface")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the ANALYSIS.json report here")
    ap.add_argument("--pass", dest="passes", action="append", choices=PASSES,
                    metavar="|".join(PASSES), default=None,
                    help="run only the named pass (repeatable; default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="jaxpr pass: one method per codec family")
    args = ap.parse_args(argv)
    selected = tuple(args.passes) if args.passes else PASSES

    _ensure_fake_devices()

    from repro.analysis import Finding  # noqa: F401  (import-light root)

    findings = []
    passes: dict[str, dict] = {}

    if "jaxpr" in selected:
        from repro.analysis import jaxpr_lint

        f, stats = jaxpr_lint.run_pass(quick=args.quick)
        findings += f
        passes["jaxpr"] = stats
        print(f"[jaxpr] {stats['traces']} traces, {len(f)} finding(s)")

    if "ast" in selected:
        from repro.analysis import ast_lint

        f, stats = ast_lint.run_pass()
        findings += f
        passes["ast"] = stats
        print(f"[ast]   {stats['files']} files, {len(f)} finding(s)")

    if "vmem" in selected:
        from repro.analysis import vmem

        f, table = vmem.run_pass()
        findings += f
        passes["vmem"] = {"kernels": len(table),
                          "table": [e.to_json() for e in table]}
        peak = max((e.vmem_bytes for e in table), default=0)
        print(f"[vmem]  {len(table)} kernels, peak {peak} B, "
              f"{len(f)} finding(s)")

    for f in findings:
        print(f"  {f}", file=sys.stderr)

    report = {"version": 1, "passes": passes,
              "findings": [f.to_json() for f in findings],
              "clean": not findings}
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
        print(f"report -> {args.json}")

    if findings:
        print(f"FAIL: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
