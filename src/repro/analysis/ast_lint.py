"""Pass 2 (``REPRO2xx``): AST linter for the repo's architectural rules.

Rules (all honor the ``# repro: allow REPROxxx`` comment suppression):

- **REPRO201** — no method-string dispatch inside the collective bodies
  (``dist/sharded_codec.py``): comparing ``*.method`` against string
  literals (``==``, ``!=``, ``in``) reintroduces exactly the branching the
  codec registry removed; collective code must branch on the ``Codec``
  interface (``chunkable``, ``state_extra``, …) only.
- **REPRO202** — no bare ``pl.pallas_call`` outside ``kernels/``: every
  kernel launch must live behind the ``kernels.ops`` wrappers that own
  padding, dtype narrowing, and the interpret fallback.
- **REPRO203** — every public wrapper in ``kernels/ops.py`` that takes an
  ``interpret`` keyword must resolve it through ``_use_interpret`` (the
  CPU/TPU dispatch every call site relies on).
- **REPRO204** — no argless or literal-seeded ``jax.random.PRNGKey`` /
  ``jax.random.key`` in library code (``src/``): a baked-in seed silently
  correlates anything derived from it across callers; keys must flow in
  from the caller (trace-geometry and dataset seeds carry an allow
  comment stating why the constant is sound).

The linter is plain ``ast`` — no jax import — so it runs anywhere, fast.
"""
from __future__ import annotations

import ast
import pathlib

from . import Finding, suppressed_codes

#: files whose bodies are collective wiring (REPRO201 scope)
COLLECTIVE_MODULES = ("dist/sharded_codec.py",)

#: directory whose modules may call pl.pallas_call directly (REPRO202)
KERNELS_DIR = "kernels/"

#: the kernel-wrapper module (REPRO203 scope)
OPS_MODULE = "kernels/ops.py"


def _is_method_attr(node: ast.AST) -> bool:
    return isinstance(node, ast.Attribute) and node.attr == "method"


def _is_str_const(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return True
    return (isinstance(node, ast.Tuple | ast.List | ast.Set)
            and all(_is_str_const(e) for e in node.elts))


def _lint_tree(tree: ast.Module, relpath: str) -> list[tuple[str, int, str]]:
    """Raw (code, lineno, message) hits for one parsed module."""
    hits: list[tuple[str, int, str]] = []
    in_collective = any(relpath.endswith(m) for m in COLLECTIVE_MODULES)
    in_kernels = KERNELS_DIR in relpath
    in_ops = relpath.endswith(OPS_MODULE)

    for node in ast.walk(tree):
        # REPRO201: cfg.method == "..." / cfg.method in ("...",) dispatch
        if in_collective and isinstance(node, ast.Compare):
            sides = [node.left] + list(node.comparators)
            if any(_is_method_attr(s) for s in sides) and \
                    any(_is_str_const(s) for s in sides):
                hits.append((
                    "REPRO201", node.lineno,
                    "method-string comparison in a collective body; branch "
                    "on the Codec interface (get_codec(...).<attr>) instead"))

        # REPRO202: pl.pallas_call outside kernels/
        if not in_kernels and isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr == "pallas_call":
                hits.append((
                    "REPRO202", node.lineno,
                    "bare pl.pallas_call outside kernels/; launch through "
                    "the kernels.ops wrappers (padding + interpret fallback)"))

        # REPRO203: ops.py wrappers must dispatch through _use_interpret
        if in_ops and isinstance(node, ast.FunctionDef):
            takes_interpret = any(a.arg == "interpret"
                                  for a in node.args.kwonlyargs + node.args.args)
            if takes_interpret:
                uses = any(isinstance(n, ast.Name) and n.id == "_use_interpret"
                           for n in ast.walk(node))
                if not uses:
                    hits.append((
                        "REPRO203", node.lineno,
                        f"kernel wrapper {node.name}() takes interpret= but "
                        "never resolves it via _use_interpret(); the CPU "
                        "fallback dispatch is the wrapper contract"))

        # REPRO204: argless/literal jax.random.PRNGKey / jax.random.key
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr in ("PRNGKey", "key"):
                base = fn.value
                if isinstance(base, ast.Attribute) and base.attr == "random":
                    literal = (not node.args and not node.keywords) or (
                        len(node.args) == 1
                        and isinstance(node.args[0], ast.Constant))
                    if literal:
                        hits.append((
                            "REPRO204", node.lineno,
                            f"jax.random.{fn.attr} with a baked-in seed in "
                            "library code; thread the key in from the caller"))
    return hits


def lint_source(source: str, relpath: str) -> list[Finding]:
    """Lint one module's source; ``relpath`` selects the scoped rules."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("REPRO202", f"{relpath}:{e.lineno or 0}",
                        f"unparseable module: {e.msg}")]
    lines = source.splitlines()
    out = []
    for code, lineno, message in _lint_tree(tree, relpath):
        if code in suppressed_codes(lines, lineno):
            continue
        out.append(Finding(code, f"{relpath}:{lineno}", message))
    return out


def lint_file(path: pathlib.Path, relpath: str | None = None) -> list[Finding]:
    """Lint one file.  ``relpath`` overrides the scope key — the corpus
    tests use it to make a fixture masquerade as e.g. a ``dist/`` module."""
    rel = relpath if relpath is not None else str(path)
    return lint_source(path.read_text(), rel)


def run_pass(root: pathlib.Path | None = None):
    """Lint every module under ``src/`` (library code only — tests and
    benchmarks may seed keys and poke kernels at will)."""
    if root is None:
        root = pathlib.Path(__file__).resolve().parents[2]  # src/
    findings: list[Finding] = []
    files = sorted(root.rglob("*.py"))
    for path in files:
        findings += lint_file(path, str(path.relative_to(root.parent)))
    return findings, {"files": len(files)}
