"""Pass 3 (``REPRO3xx``): static VMEM footprint of every Pallas kernel.

Each ``kernels.ops`` wrapper is traced abstractly (``jax.eval_shape`` — no
compilation, no execution) at representative shapes while ``pl.pallas_call``
is shimmed to record its grid/BlockSpec/out_shape geometry and the operand
avals it is applied to.  The footprint model is the standard double-buffered
tiling estimate:

- a *blocked* operand/result (a BlockSpec with a block shape) keeps two
  tiles resident (the compute tile + the in-flight DMA tile): ``2 × block
  bytes``;
- an *unblocked* one (``memory_space=None`` — whole-operand residency, e.g.
  codebooks, α rows, the orthogonalize factor) charges its full size;
- per-kernel scratch shapes, when requested, are charged in full.

The sum must stay under the per-kernel budget (default 4 MiB — a quarter of
the ~16 MiB/core TPU VMEM, leaving room for semaphores, spills, and the
next kernel's prologue).  Violations are ``REPRO301`` findings; the whole
table lands in ``ANALYSIS.json`` so CI archives the footprint history.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from collections.abc import Callable

import jax
import jax.numpy as jnp

from . import Finding

#: default per-kernel budget: 4 MiB of ~16 MiB/core VMEM
DEFAULT_BUDGET = 4 << 20

#: per-wrapper overrides (bytes), for kernels allowed to run hotter
BUDGETS: dict[str, int] = {}


@dataclasses.dataclass(frozen=True)
class KernelEstimate:
    """Static VMEM geometry of one recorded ``pallas_call``."""

    wrapper: str         # the ops.py entry point traced
    kernel: str          # the kernel function handed to pallas_call
    grid: tuple[int, ...]
    operands: tuple[tuple[str, int], ...]  # (describe, resident bytes)
    vmem_bytes: int
    budget_bytes: int

    def to_json(self) -> dict:
        return {"wrapper": self.wrapper, "kernel": self.kernel,
                "grid": list(self.grid),
                "operands": [list(o) for o in self.operands],
                "vmem_bytes": self.vmem_bytes,
                "budget_bytes": self.budget_bytes}


def _block_bytes(block_shape, aval) -> tuple[str, int]:
    """(description, resident bytes) of one operand/result under its spec."""
    itemsize = jnp.dtype(aval.dtype).itemsize
    if block_shape is None:
        n = math.prod(aval.shape) if aval.shape else 1
        return f"full {tuple(aval.shape)} {aval.dtype}", n * itemsize
    dims = tuple(1 if b is None else int(b) for b in block_shape)
    return (f"block {dims} {aval.dtype} x2",
            2 * math.prod(dims) * itemsize)


def _as_list(x) -> list:
    if x is None:
        return []
    return list(x) if isinstance(x, list | tuple) else [x]


@contextlib.contextmanager
def _record_pallas_calls(records: list):
    """Swap ``pallas.pallas_call`` for a recording shim within the block.

    The shim still builds the real traced call (abstract eval only under
    ``jax.eval_shape``) but first captures the geometry + operand avals.
    """
    from jax.experimental import pallas as pl

    real = pl.pallas_call

    def spy(kernel, **kwargs):
        inner = real(kernel, **kwargs)

        def call(*args):
            grid = kwargs.get("grid", ())
            grid = (grid,) if isinstance(grid, int) else tuple(grid)
            in_specs = _as_list(kwargs.get("in_specs"))
            out_specs = _as_list(kwargs.get("out_specs"))
            out_shape = _as_list(kwargs.get("out_shape"))
            # a missing spec means whole-operand residency (no tiling)
            in_specs += [None] * (len(args) - len(in_specs))
            out_specs += [None] * (len(out_shape) - len(out_specs))
            ops = []
            for spec, a in zip(in_specs, args):
                ops.append(_block_bytes(getattr(spec, "block_shape", None),
                                        jax.api_util.shaped_abstractify(a)))
            for spec, sds in zip(out_specs, out_shape):
                ops.append(_block_bytes(getattr(spec, "block_shape", None), sds))
            for scratch in _as_list(kwargs.get("scratch_shapes")):
                n = math.prod(getattr(scratch, "shape", ()) or ())
                item = jnp.dtype(getattr(scratch, "dtype", jnp.float32)).itemsize
                ops.append((f"scratch {getattr(scratch, 'shape', ())}", n * item))
            body = getattr(kernel, "func", kernel)  # unwrap functools.partial
            records.append({
                "kernel": getattr(body, "__name__", str(body)),
                "grid": grid, "operands": tuple(ops),
                "vmem_bytes": sum(b for _, b in ops)})
            return inner(*args)

        return call

    pl.pallas_call = spy
    try:
        yield
    finally:
        pl.pallas_call = real


def estimate(thunks: dict[str, Callable[[], object]],
             budgets: dict[str, int] | None = None,
             default_budget: int = DEFAULT_BUDGET,
             ) -> tuple[list[Finding], list[KernelEstimate]]:
    """Trace each named thunk, estimate every Pallas kernel it launches.

    A thunk is a zero-argument callable that traces its wrapper abstractly
    (``jax.eval_shape``); whatever ``pallas_call``\\ s fire during the trace
    are attributed to that wrapper name.
    """
    budgets = {**BUDGETS, **(budgets or {})}
    findings: list[Finding] = []
    table: list[KernelEstimate] = []
    for name, thunk in thunks.items():
        records: list = []
        with _record_pallas_calls(records):
            thunk()
        budget = budgets.get(name, default_budget)
        for rec in records:
            est = KernelEstimate(wrapper=name, kernel=rec["kernel"],
                                 grid=rec["grid"], operands=rec["operands"],
                                 vmem_bytes=rec["vmem_bytes"],
                                 budget_bytes=budget)
            table.append(est)
            if est.vmem_bytes > budget:
                detail = "; ".join(f"{d}={b}" for d, b in est.operands)
                findings.append(Finding(
                    "REPRO301", f"vmem:{name}/{est.kernel}",
                    f"static VMEM footprint {est.vmem_bytes} B exceeds the "
                    f"{budget} B budget (grid {est.grid}; {detail})"))
        if not records:
            findings.append(Finding(
                "REPRO301", f"vmem:{name}",
                "wrapper traced no pallas_call — estimator wiring is stale"))
    return findings, table


# ---------------------------------------------------------------------------
# The repo's kernel surface at representative shapes
# ---------------------------------------------------------------------------

_N = 1 << 20          # one 4 MiB fp32 bucket
_PEERS = 4
_BITS = 3


def default_thunks() -> dict[str, Callable[[], object]]:
    """One abstract-trace thunk per public ``kernels.ops`` wrapper."""
    from repro.core.quantizers import num_levels, packed_size
    from repro.kernels import ops

    f32 = jnp.float32
    g = jax.ShapeDtypeStruct((_N,), f32)
    codes = jax.ShapeDtypeStruct((_N,), jnp.uint8)
    alpha = jax.ShapeDtypeStruct((1,), f32)
    levels = jax.ShapeDtypeStruct((num_levels(_BITS) + 1,), f32)
    words = jax.ShapeDtypeStruct((_PEERS, packed_size(_N, _BITS)), jnp.uint32)
    alphas = jax.ShapeDtypeStruct((_PEERS,), f32)
    plevels = jax.ShapeDtypeStruct((_PEERS, num_levels(_BITS) + 1), f32)
    factor = jax.ShapeDtypeStruct((2048, 32), f32)
    key = jax.random.key(0)  # repro: allow REPRO204 (abstract trace only)

    class _S:
        """Static-argument marker: baked into the closure, not traced
        (``bits``/``n`` are ``static_argnames`` on the jitted wrappers)."""

        def __init__(self, v):
            self.v = v

    def t(fn, *tmpl):
        arrays = [a for a in tmpl if not isinstance(a, _S)]

        def call(*traced):
            it = iter(traced)
            args = [a.v if isinstance(a, _S) else next(it) for a in tmpl]
            return fn(*args, interpret=True)

        return lambda: jax.eval_shape(call, *arrays)

    bits, n = _S(_BITS), _S(_N)
    return {
        "uniform_encode": t(ops.uniform_encode, g, alpha, bits, key),
        "uniform_decode": t(ops.uniform_decode, codes, alpha, bits),
        "codebook_encode": t(ops.codebook_encode, g, levels, key),
        "codebook_decode": t(ops.codebook_decode, codes, levels),
        "uniform_encode_packed": t(ops.uniform_encode_packed, g, alpha, bits, key),
        "codebook_encode_packed": t(ops.codebook_encode_packed, g, levels, bits, key),
        "uniform_decode_reduce": t(ops.uniform_decode_reduce, words, alphas, n, bits),
        "codebook_decode_reduce": t(ops.codebook_decode_reduce, words, plevels, n, bits),
        "uniform_decode_rows": t(ops.uniform_decode_rows, words, alphas, n, bits),
        "codebook_decode_rows": t(ops.codebook_decode_rows, words, plevels, n, bits),
        "bucket_stats": t(ops.bucket_stats, g),
        "ef_correct_stats": t(ops.ef_correct_stats, g, g),
        "uniform_encode_pack": t(ops.uniform_encode_pack, g, alpha, bits, key),
        "codebook_encode_pack": t(ops.codebook_encode_pack, g, levels, bits, key),
        "uniform_encode_pack_residual": t(
            ops.uniform_encode_pack_residual, g, alpha, bits, key),
        "codebook_encode_pack_residual": t(
            ops.codebook_encode_pack_residual, g, levels, bits, key),
        "orthogonalize": t(ops.orthogonalize, factor),
    }


def run_pass() -> tuple[list[Finding], list[KernelEstimate]]:
    """Estimate the whole registered kernel surface against its budgets."""
    return estimate(default_thunks())
