"""Decoder-only LM (dense / MoE / SSM / hybrid / VLM) and Whisper-style
encoder-decoder, with scan-over-layers parameter stacking.

Design:
- A *unit* is the scan step: one layer for uniform stacks; a superblock of
  ``attn_period`` layers for hybrids (Jamba: 1 attention + 7 Mamba per unit,
  MoE on every other layer).
- Params are dict pytrees stacked on the unit axis; a parallel pytree of
  logical-axis tuples drives PartitionSpec derivation.
- Modes: ``train`` (full seq, loss), ``prefill`` (full seq -> caches + last
  logits), ``decode`` (1 token + caches).
- Cross-entropy is computed in sequence chunks (scan) against the (possibly
  vocab-sharded) LM head — (B, S, V) logits are never materialized.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from .attention import (
    KVCache,
    attention_decode,
    attention_forward,
    attention_prefill,
    attn_init,
    cross_attention_forward,
    cross_kv,
)
from .layers import apply_norm, dense_init, mlp_apply, mlp_init, norm_init
from .moe import dense_moe_apply, moe_apply, moe_init
from .rope import sincos_embedding
from .ssm import init_ssm_state, ssm_decode, ssm_forward, ssm_init

AUX_LOSS_WEIGHT = 0.01


@dataclasses.dataclass(frozen=True)
class SubDesc:
    mixer: str  # 'attn' | 'ssm'
    ffn: str    # 'mlp' | 'moe'
    cross: bool = False


def unit_pattern(cfg) -> tuple[list[SubDesc], int]:
    """Sublayer descriptors for one scan unit + number of units."""
    if cfg.family == "hybrid":
        period = cfg.attn_period
        units = cfg.num_layers // period
        descs = []
        for j in range(period):
            mixer = "attn" if j == cfg.attn_offset else "ssm"
            ffn = "moe" if (cfg.moe is not None and j % cfg.moe.every == cfg.moe.every - 1) else "mlp"
            descs.append(SubDesc(mixer, ffn))
        return descs, units
    mixer = "ssm" if cfg.family == "ssm" else "attn"
    if cfg.moe is not None and cfg.moe.every == 1:
        ffn = "moe"
    elif cfg.d_ff <= 0:
        ffn = "none"  # pure-Mamba stacks are mixer-only
    else:
        ffn = "mlp"
    return [SubDesc(mixer, ffn)], cfg.num_layers


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _sublayer_init(key, cfg, desc: SubDesc):
    ks = jax.random.split(key, 6)
    p, la = {}, {}
    p["ln1"], la["ln1"] = norm_init(cfg, cfg.d_model)
    mixer_init = attn_init if desc.mixer == "attn" else ssm_init
    p["mixer"], la["mixer"] = mixer_init(ks[0], cfg, cfg.d_model)
    if desc.cross:
        p["lnx"], la["lnx"] = norm_init(cfg, cfg.d_model)
        p["cross"], la["cross"] = attn_init(ks[2], cfg, cfg.d_model, cross=True)
    if desc.ffn != "none":
        p["ln2"], la["ln2"] = norm_init(cfg, cfg.d_model)
    if desc.ffn == "moe":
        p["ffn"], la["ffn"] = moe_init(ks[1], cfg, cfg.d_model)
    elif desc.ffn == "mlp":
        p["ffn"], la["ffn"] = mlp_init(ks[1], cfg, cfg.d_model, cfg.d_ff, jnp.dtype(cfg.param_dtype))
    return p, la


def _unit_init(key, cfg, descs):
    if len(descs) == 1:
        return _sublayer_init(key, cfg, descs[0])
    p, la = {}, {}
    for j, d in enumerate(descs):
        p[f"sub{j}"], la[f"sub{j}"] = _sublayer_init(jax.random.fold_in(key, j), cfg, d)
    return p, la


def init_lm(key, cfg) -> tuple[dict, dict]:
    """Returns (params, logical-axis pytree)."""
    descs, units = _decoder_descs(cfg)
    ks = jax.random.split(key, 8)
    dtype = jnp.dtype(cfg.param_dtype)

    p: dict[str, Any] = {}
    la: dict[str, Any] = {}
    p["embed"] = dense_init(ks[0], (cfg.vocab, cfg.d_model), cfg.d_model, dtype)
    la["embed"] = ("vocab", "embed_fsdp")

    unit_keys = jax.random.split(ks[1], units)
    stacked = jax.vmap(lambda k: _unit_init(k, cfg, descs)[0])(unit_keys)
    _, stacked_la = _unit_init(unit_keys[0], cfg, descs)
    p["blocks"] = stacked
    la["blocks"] = jax.tree.map(lambda ax: ("layers",) + ax, stacked_la, is_leaf=lambda x: isinstance(x, tuple))
    p["final_norm"], la["final_norm"] = norm_init(cfg, cfg.d_model)

    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab), cfg.d_model, dtype)
        la["lm_head"] = ("embed_fsdp", "vocab")

    if cfg.vlm_patches:
        p["projector"] = dense_init(ks[3], (cfg.vlm_vision_dim, cfg.d_model), cfg.vlm_vision_dim, dtype)
        la["projector"] = (None, "embed_fsdp")

    if cfg.enc_dec:
        enc_cfg = cfg  # same dims for encoder
        enc_keys = jax.random.split(ks[4], cfg.num_enc_layers)
        enc_desc = SubDesc("attn", "mlp")
        enc_stack = jax.vmap(lambda k: _sublayer_init(k, enc_cfg, enc_desc)[0])(enc_keys)
        _, enc_la = _sublayer_init(enc_keys[0], enc_cfg, enc_desc)
        p["encoder"] = {"blocks": enc_stack}
        p["encoder"]["final_norm"], fn_la = norm_init(cfg, cfg.d_model)
        la["encoder"] = {
            "blocks": jax.tree.map(lambda ax: ("layers",) + ax, enc_la, is_leaf=lambda x: isinstance(x, tuple)),
            "final_norm": fn_la,
        }
    return p, la


# ---------------------------------------------------------------------------
# Sublayer / unit forward
# ---------------------------------------------------------------------------


def _ffn_apply(cfg, desc, p, x):
    if desc.ffn == "none":
        return jnp.zeros_like(x), jnp.float32(0.0)
    if desc.ffn == "moe":
        if cfg.moe.num_experts <= 4 and x.shape[0] * x.shape[1] < 4096:
            return dense_moe_apply(cfg, p["ffn"], x)
        return moe_apply(cfg, p["ffn"], x)
    return mlp_apply(cfg, p["ffn"], x), jnp.float32(0.0)


def _sublayer_fwd(cfg, desc, p, x, positions, mode, cache, position, capacity=None):
    """Returns (x, new_cache, aux)."""
    h = apply_norm(cfg, p["ln1"], x)
    new_cache = cache
    if desc.mixer == "attn":
        win = cfg.sliding_window
        if mode == "train":
            mx = attention_forward(cfg, p["mixer"], h, positions, causal=True, window=win)
        elif mode == "prefill":
            mx, new_cache = attention_prefill(cfg, p["mixer"], h, positions, window=win, capacity=capacity)
        elif mode == "encode":
            mx = attention_forward(cfg, p["mixer"], h, positions, causal=False)
        else:
            mx, new_cache = attention_decode(cfg, p["mixer"], h, cache, position, window=win)
    else:
        if mode == "train":
            mx = ssm_forward(cfg, p["mixer"], h)
        elif mode == "prefill":
            mx, new_cache = ssm_forward(cfg, p["mixer"], h, return_state=True)
        else:
            mx, new_cache = ssm_decode(cfg, p["mixer"], h, cache)
    x = x + mx

    if desc.cross and "cross" in p:
        hx = apply_norm(cfg, p["lnx"], x)
        enc_kv = cache["cross_kv"] if isinstance(cache, dict) and "cross_kv" in cache else new_cache["cross_kv"]
        x = x + cross_attention_forward(cfg, p["cross"], hx, enc_kv)

    if desc.ffn == "none":
        return x, new_cache, jnp.float32(0.0)
    h2 = apply_norm(cfg, p["ln2"], x)
    y, aux = _ffn_apply(cfg, desc, p, h2)
    return x + y, new_cache, aux


def _unit_fwd(cfg, descs, p, x, positions, mode, cache, position, capacity=None):
    if len(descs) == 1:
        return _sublayer_fwd(cfg, descs[0], p, x, positions, mode, cache, position, capacity)
    aux_total = jnp.float32(0.0)
    new_cache = {}
    for j, d in enumerate(descs):
        sub_cache = cache[f"sub{j}"] if cache is not None else None
        x, nc, aux = _sublayer_fwd(cfg, d, p[f"sub{j}"], x, positions, mode, sub_cache, position, capacity)
        new_cache[f"sub{j}"] = nc
        aux_total = aux_total + aux
    return x, new_cache, aux_total


# ---------------------------------------------------------------------------
# Whisper-style decoder sublayers need cross-attention; wrap descriptors.
# ---------------------------------------------------------------------------


def _decoder_descs(cfg) -> tuple[list[SubDesc], int]:
    descs, units = unit_pattern(cfg)
    if cfg.enc_dec:
        descs = [dataclasses.replace(d, cross=True) for d in descs]
    return descs, units


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def _embed_tokens(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.rope == "sincos":
        pos = sincos_embedding(tokens.shape[1], cfg.d_model)
        x = x + pos[None].astype(x.dtype)
    return x.astype(jnp.dtype(cfg.compute_dtype))


def _embed_decode_token(cfg, params, token, position):
    x = jnp.take(params["embed"], token, axis=0)
    if cfg.rope == "sincos":
        # one sincos row at a dynamic position
        d = cfg.d_model
        half = d // 2
        freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
        ang = position.astype(jnp.float32) * freqs
        row = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
        x = x + row[None, None].astype(x.dtype)
    return x.astype(jnp.dtype(cfg.compute_dtype))


def lm_head_weights(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def _inject_vision(cfg, params, x, patches):
    """Replace the first ``vlm_patches`` positions with projected patch embeds."""
    proj = (patches.astype(jnp.dtype(cfg.compute_dtype)) @ params["projector"].astype(jnp.dtype(cfg.compute_dtype)))
    return jnp.concatenate([proj, x[:, cfg.vlm_patches :]], axis=1)


# ---------------------------------------------------------------------------
# Encoder (audio): consumes precomputed frame embeddings (conv frontend stub).
# ---------------------------------------------------------------------------


def encode_frames(cfg, params, frames):
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + sincos_embedding(x.shape[1], cfg.d_model)[None].astype(x.dtype)
    enc_desc = SubDesc("attn", "mlp")
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

    def step(carry, p_l):
        y, _, _ = _sublayer_fwd(cfg, enc_desc, p_l, carry, positions, "encode", None, None)
        return y, None

    x, _ = jax.lax.scan(step, x, params["encoder"]["blocks"])
    return apply_norm(cfg, params["encoder"]["final_norm"], x)


# ---------------------------------------------------------------------------
# Main entry points
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# Decomposed train-path entry points (used by the layer-streamed ZeRO-3 train
# step, which gathers params and syncs grads per scan unit).
# ---------------------------------------------------------------------------


def outer_params(params: dict) -> dict:
    return {k: v for k, v in params.items() if k != "blocks"}


def embed_fn(cfg, outer: dict, batch: "Batch") -> jax.Array:
    """Embedding + modality injection; returns h0 (B, S, D)."""
    x = _embed_tokens(cfg, outer, batch.tokens)
    if cfg.vlm_patches:
        x = _inject_vision(cfg, outer, x, batch.patches)
    return shard(x, "batch", "seq", None)


def unit_fn(cfg, unit_params: dict, h: jax.Array, positions: jax.Array):
    """One scan unit in train mode.  Returns (h_out, aux)."""
    descs, _ = _decoder_descs(cfg)
    h2, _, aux = _unit_fwd(cfg, descs, unit_params, h, positions, "train", None, None)
    return h2, aux


def num_sublayers(cfg) -> int:
    descs, _ = _decoder_descs(cfg)
    return len(descs)


def sublayer_fn(cfg, idx: int, sub_params: dict, h: jax.Array, positions: jax.Array):
    """One sublayer of a (hybrid) scan unit, train mode.  Returns (h_out, aux).

    Used by the streamed train step to gather params / sync grads one
    sublayer at a time inside Jamba-style superblocks."""
    descs, _ = _decoder_descs(cfg)
    h2, _, aux = _sublayer_fwd(cfg, descs[idx], sub_params, h, positions, "train", None, None)
    return h2, aux


def head_fn(cfg, outer: dict, hidden: jax.Array, batch: "Batch") -> jax.Array:
    """Final norm + chunked cross-entropy."""
    hidden = apply_norm(cfg, outer["final_norm"], hidden)
    return chunked_xent_with(cfg, outer, hidden, batch.labels)


def chunked_xent_with(cfg, params_for_head, hidden, labels, chunk: int = 512):
    return _chunked_xent_impl(cfg, params_for_head, hidden, labels, chunk)


class Batch(NamedTuple):
    tokens: jax.Array                      # (B, S) int32
    labels: jax.Array                      # (B, S) int32, -1 = masked
    positions: jax.Array | None = None  # (B,S) or (3,B,S) for mrope
    patches: jax.Array | None = None    # (B, P, vdim) VLM patch embeddings
    frames: jax.Array | None = None     # (B, enc_seq, D) audio frames


def _positions_for(cfg, batch: Batch):
    if batch.positions is not None:
        return batch.positions
    b, s = batch.tokens.shape
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


def backbone(cfg, params, batch: Batch, mode: str, capacity=None):
    """Embed -> scan units -> final norm.  Returns (hidden, caches, aux)."""
    descs, units = _decoder_descs(cfg)
    x = _embed_tokens(cfg, params, batch.tokens)
    if cfg.vlm_patches:
        x = _inject_vision(cfg, params, x, batch.patches)
    x = shard(x, "batch", "seq", None)
    positions = _positions_for(cfg, batch)

    enc_out = None
    if cfg.enc_dec:
        enc_out = encode_frames(cfg, params, batch.frames)

    def unit(carry, p_u):
        h, aux = carry
        h2, new_cache, aux_u = _unit_fwd_with_cross(cfg, descs, p_u, h, positions, mode, enc_out, capacity)
        return (h2, aux + aux_u), new_cache

    fn = unit
    if cfg.remat and mode == "train":
        fn = jax.checkpoint(unit, prevent_cse=False)
    (x, aux), caches = jax.lax.scan(fn, (x, jnp.float32(0.0)), params["blocks"])
    x = apply_norm(cfg, params["final_norm"], x)
    return x, caches, aux


def _unit_fwd_with_cross(cfg, descs, p_u, x, positions, mode, enc_out, capacity=None):
    """Unit forward for train/prefill, computing cross-KV from enc_out as needed."""
    if not cfg.enc_dec:
        return _unit_fwd(cfg, descs, p_u, x, positions, mode, None, None, capacity)
    # encoder-decoder: single-sublayer units with cross attention
    d = descs[0]
    h = apply_norm(cfg, p_u["ln1"], x)
    if mode == "prefill":
        mx, kv_cache = attention_prefill(cfg, p_u["mixer"], h, positions, window=cfg.sliding_window, capacity=capacity)
    else:
        mx = attention_forward(cfg, p_u["mixer"], h, positions, causal=True, window=cfg.sliding_window)
        kv_cache = None
    x = x + mx
    hx = apply_norm(cfg, p_u["lnx"], x)
    ekv = cross_kv(cfg, p_u["cross"], enc_out)
    x = x + cross_attention_forward(cfg, p_u["cross"], hx, ekv)
    h2 = apply_norm(cfg, p_u["ln2"], x)
    y, aux = _ffn_apply(cfg, d, p_u, h2)
    x = x + y
    cache = {"self": kv_cache, "cross_kv": ekv} if mode == "prefill" else None
    return x, cache, aux


def chunked_xent(cfg, params, hidden, labels, chunk: int = 512):
    """Scan over sequence chunks; never materializes (B, S, V)."""
    return _chunked_xent_impl(cfg, params, hidden, labels, chunk)


def _chunked_xent_impl(cfg, params, hidden, labels, chunk: int = 512):
    b, s, d = hidden.shape
    c = min(chunk, s)
    while s % c:
        c -= 1
    n = s // c
    w = lm_head_weights(cfg, params).astype(jnp.dtype(cfg.compute_dtype))
    hr = hidden.reshape(b, n, c, d).transpose(1, 0, 2, 3)
    lr = labels.reshape(b, n, c).transpose(1, 0, 2)

    def step(carry, inp):
        hc, lc = inp
        logits = (hc @ w).astype(jnp.float32)
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        loss = jnp.sum((lse - ll) * mask)
        return (carry[0] + loss, carry[1] + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)), (hr, lr))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg, params, batch: Batch):
    hidden, _, aux = backbone(cfg, params, batch, "train")
    loss = chunked_xent(cfg, params, hidden, batch.labels)
    return loss + AUX_LOSS_WEIGHT * aux


def prefill(cfg, params, batch: Batch, capacity=None):
    """Returns (last-token logits (B, V), caches).  ``capacity`` reserves
    cache room for subsequent decode steps."""
    hidden, caches, _ = backbone(cfg, params, batch, "prefill", capacity=capacity)
    last = hidden[:, -1]
    logits = (last @ lm_head_weights(cfg, params).astype(last.dtype)).astype(jnp.float32)
    return logits, caches


def decode_step(cfg, params, token, caches, position):
    """One decode step.  token: (B, 1) int32; position: scalar int32.

    Returns (logits (B, V), new caches)."""
    descs, _ = _decoder_descs(cfg)
    x = _embed_decode_token(cfg, params, token, position)
    x = shard(x, "batch", None, None)
    positions = jnp.broadcast_to(position.reshape(1, 1), token.shape).astype(jnp.int32)

    def unit(carry, xs):
        p_u, cache_u = xs
        # barrier: stops XLA hoisting fp32 converts of the *entire* stacked
        # KV cache out of the decode loop (2x 7.5 GiB on gemma decode_32k)
        cache_u = jax.lax.optimization_barrier(cache_u)
        if cfg.enc_dec:
            h = apply_norm(cfg, p_u["ln1"], carry)
            mx, new_self = attention_decode(cfg, p_u["mixer"], h, cache_u["self"], position, window=cfg.sliding_window)
            y = carry + mx
            hx = apply_norm(cfg, p_u["lnx"], y)
            y = y + cross_attention_forward(cfg, p_u["cross"], hx, cache_u["cross_kv"])
            h2 = apply_norm(cfg, p_u["ln2"], y)
            out, _ = _ffn_apply(cfg, descs[0], p_u, h2)
            return y + out, {"self": new_self, "cross_kv": cache_u["cross_kv"]}
        h, new_cache, _ = _unit_fwd(cfg, descs, p_u, carry, positions, "decode", cache_u, position)
        return h, new_cache

    x, new_caches = jax.lax.scan(unit, x, (params["blocks"], caches))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = (x[:, 0] @ lm_head_weights(cfg, params).astype(x.dtype)).astype(jnp.float32)
    return logits, new_caches


# ---------------------------------------------------------------------------
# Cache construction (zeros; serving starts from prefill in practice, but the
# dry-run and tests need shape-correct caches without running prefill).
# ---------------------------------------------------------------------------


def init_caches(cfg, batch: int, cache_len: int):
    descs, units = _decoder_descs(cfg)
    dh = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.compute_dtype)
    eff_len = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    # full (non-rolling) caches leave the last slot free: decode appends at
    # cache.length
    fill = eff_len if cfg.sliding_window else eff_len - 1

    def kv():
        return KVCache(
            k=jnp.zeros((batch, eff_len, cfg.num_kv_heads, dh), dt),
            v=jnp.zeros((batch, eff_len, cfg.num_kv_heads, dh), dt),
            length=jnp.asarray(fill, jnp.int32),
        )

    def one(desc):
        if cfg.enc_dec:
            ekv = (
                jnp.zeros((batch, cfg.enc_seq, cfg.num_kv_heads, dh), dt),
                jnp.zeros((batch, cfg.enc_seq, cfg.num_kv_heads, dh), dt),
            )
            return {"self": kv(), "cross_kv": ekv}
        if desc.mixer == "attn":
            return kv()
        return init_ssm_state(cfg, batch, jnp.float32)

    unit_cache = one(descs[0]) if len(descs) == 1 \
        else {f"sub{j}": one(d) for j, d in enumerate(descs)}
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (units,) + x.shape) if hasattr(x, "shape") else x, unit_cache)
