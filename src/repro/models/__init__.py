"""Model zoo: decoder LMs (dense/MoE/SSM/hybrid/VLM) + enc-dec (audio)."""
from . import attention, layers, moe, rope, ssm, transformer
from .transformer import (
    Batch,
    backbone,
    decode_step,
    init_caches,
    init_lm,
    loss_fn,
    prefill,
)

__all__ = [
    "Batch",
    "attention",
    "backbone",
    "decode_step",
    "init_caches",
    "init_lm",
    "layers",
    "loss_fn",
    "moe",
    "prefill",
    "rope",
    "ssm",
    "transformer",
]
