"""GQA attention: chunked-flash for train/prefill, cached decode, cross-attn.

Memory-efficient attention is a pure-JAX online-softmax over (q-chunk,
kv-chunk) tiles via nested lax.scan — the HLO stays small for 32k prefill and
the working set per step is one (B, QC, H, KC) score tile.  (A Pallas flash
kernel is not part of the paper's contribution; XLA's fused attention on TPU
is adequate, and the quantizer kernels are where the paper's hot spot is.)

Sliding-window decode uses a rolling KV cache of window size: position enters
keys via RoPE *before* caching, so attention is permutation-invariant over
cache slots and no unrotation is needed.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from .layers import dense_init
from .rope import apply_positional

NEG_INF = -1e30


def attn_init(key, cfg, d: int, *, cross: bool = False) -> tuple[dict, dict]:
    dh = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": dense_init(ks[0], (d, hq * dh), d, dtype),
        "wk": dense_init(ks[1], (d, hkv * dh), d, dtype),
        "wv": dense_init(ks[2], (d, hkv * dh), d, dtype),
        "wo": dense_init(ks[3], (hq * dh, d), hq * dh, dtype),
    }
    la = {
        "wq": ("embed_fsdp", "heads"),
        "wk": ("embed_fsdp", "heads"),
        "wv": ("embed_fsdp", "heads"),
        "wo": ("heads", "embed_fsdp"),
    }
    return p, la


def _split_heads(x, n, dh):
    return x.reshape(x.shape[:-1] + (n, dh))


def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of ``s`` that is <= target (keeps tiles even)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def _repeat_kv(k, hq):
    hkv = k.shape[2]
    if hkv == hq:
        return k
    return jnp.repeat(k, hq // hkv, axis=2)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention.  q: (B,Sq,H,Dh), k/v: (B,Skv,H,Dh)."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    qc = _pick_chunk(sq, q_chunk)
    kc = _pick_chunk(skv, kv_chunk)
    nq, nk = sq // qc, skv // kc
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    qr = q.reshape(b, nq, qc, h, dh)
    kr = k.reshape(b, nk, kc, h, dh)
    vr = v.reshape(b, nk, kc, h, dh)

    def q_step(_, qi):
        q_blk, q_idx = qi  # (B, qc, H, Dh), scalar block index
        qpos = q_idx * qc + jnp.arange(qc)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_blk, v_blk, k_idx = ki
            kpos = k_idx * kc + jnp.arange(kc)
            s = jnp.einsum("bqhd,bkhd->bqhk", q_blk.astype(jnp.float32), k_blk.astype(jnp.float32)) * scale
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bqhk,bkhd->bqhd", p, v_blk.astype(jnp.float32))
            return (m_new, l, acc), None

        init = (
            jnp.full((b, qc, h), NEG_INF, jnp.float32),
            jnp.zeros((b, qc, h), jnp.float32),
            jnp.zeros((b, qc, h, dh), jnp.float32),
        )
        # checkpoint each tile: the backward otherwise saves every tile's
        # (B, qc, H, kc) probability matrix — nq*nk tiles of fp32.
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step, prevent_cse=False),
            init,
            (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4), jnp.arange(nk)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qr.transpose(1, 0, 2, 3, 4), jnp.arange(nq)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


class KVCache(NamedTuple):
    k: jax.Array        # (B, S_cache, Hkv, Dh) — RoPE already applied
    v: jax.Array        # (B, S_cache, Hkv, Dh)
    length: jax.Array   # scalar int32: number of valid positions (== S_cache when full)


def attention_forward(
    cfg,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Full-sequence self-attention (training / prefill)."""
    dh = cfg.resolved_head_dim
    q = _split_heads(x @ p["wq"], cfg.num_heads, dh)
    k = _split_heads(x @ p["wk"], cfg.num_kv_heads, dh)
    v = _split_heads(x @ p["wv"], cfg.num_kv_heads, dh)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None) if cfg.num_kv_heads > 1 else k
    q = apply_positional(cfg, q, positions)
    k = apply_positional(cfg, k, positions)
    out = flash_attention(q, _repeat_kv(k, cfg.num_heads), _repeat_kv(v, cfg.num_heads), causal=causal, window=window)
    out = shard(out, "batch", "seq", "heads", None)
    return out.reshape(x.shape[:-1] + (cfg.num_heads * dh,)) @ p["wo"]


def attention_prefill(cfg, p, x, positions, *, window=None, capacity: int | None = None):
    """Prefill: returns (y, KVCache with rotated keys).

    ``capacity`` > seq_len leaves room for subsequent decode steps (decode
    appends at ``cache.length``)."""
    dh = cfg.resolved_head_dim
    q = _split_heads(x @ p["wq"], cfg.num_heads, dh)
    k = _split_heads(x @ p["wk"], cfg.num_kv_heads, dh)
    v = _split_heads(x @ p["wv"], cfg.num_kv_heads, dh)
    q = apply_positional(cfg, q, positions)
    k = apply_positional(cfg, k, positions)
    y = flash_attention(q, _repeat_kv(k, cfg.num_heads), _repeat_kv(v, cfg.num_heads), causal=True, window=window)
    y = y.reshape(x.shape[:-1] + (cfg.num_heads * dh,)) @ p["wo"]
    length = k.shape[1]
    if window is not None:
        k, v = k[:, -window:], v[:, -window:]
        length = k.shape[1]
    if capacity is not None and capacity > k.shape[1]:
        pad = capacity - k.shape[1]
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cache = KVCache(k=k, v=v, length=jnp.asarray(length, jnp.int32))
    return y, cache


def attention_decode(
    cfg,
    p: dict,
    x: jax.Array,            # (B, 1, D)
    cache: KVCache,
    position: jax.Array,     # scalar int32: absolute position of the new token
    *,
    window: int | None = None,
) -> tuple[jax.Array, KVCache]:
    dh = cfg.resolved_head_dim
    b = x.shape[0]
    pos2 = jnp.broadcast_to(position.reshape(1, 1), (b, 1))
    q = apply_positional(cfg, _split_heads(x @ p["wq"], cfg.num_heads, dh), pos2)
    k_new = apply_positional(cfg, _split_heads(x @ p["wk"], cfg.num_kv_heads, dh), pos2)
    v_new = _split_heads(x @ p["wv"], cfg.num_kv_heads, dh)
    s_cache = cache.k.shape[1]
    # Rolling caches (sliding window) index by absolute position; full caches
    # append at the current length (prefill must have left capacity).
    slot = (position % s_cache) if window is not None else cache.length
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, slot, 0, 0))
    new_len = jnp.minimum(cache.length + 1, s_cache)

    # Grouped-query attention against the cache without materializing the
    # head-repeated (or fp32-cast) cache: q is viewed as (B, 1, Hkv, rep, Dh)
    # and contracted against the raw bf16 cache with fp32 accumulation.
    rep = cfg.num_heads // max(cfg.num_kv_heads, 1)
    qg = q.reshape(b, 1, cfg.num_kv_heads, rep, dh)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    s = jnp.einsum("bqhrd,bkhd->bqhrk", qg, k, preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(s_cache)[None, None, None, None, :] < new_len
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhrk,bkhd->bqhrd", w.astype(k.dtype), v, preferred_element_type=jnp.float32)
    out = out.astype(x.dtype).reshape(b, 1, cfg.num_heads * dh)
    y = out @ p["wo"]
    return y, KVCache(k=k, v=v, length=new_len)


def cross_attention_forward(cfg, p, x, enc_kv: tuple[jax.Array, jax.Array]) -> jax.Array:
    """Decoder cross-attention; enc_kv = (k, v) precomputed from encoder output."""
    dh = cfg.resolved_head_dim
    q = _split_heads(x @ p["wq"], cfg.num_heads, dh)
    k, v = enc_kv
    out = flash_attention(
        q, _repeat_kv(k, cfg.num_heads), _repeat_kv(v, cfg.num_heads),
        causal=False, q_chunk=1024, kv_chunk=max(64, min(1024, k.shape[1])),
    )
    return out.reshape(x.shape[:-1] + (cfg.num_heads * dh,)) @ p["wo"]


def cross_kv(cfg, p, enc_out: jax.Array) -> tuple[jax.Array, jax.Array]:
    dh = cfg.resolved_head_dim
    k = _split_heads(enc_out @ p["wk"], cfg.num_kv_heads, dh)
    v = _split_heads(enc_out @ p["wv"], cfg.num_kv_heads, dh)
    return k, v
