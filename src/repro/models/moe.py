"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Dispatch avoids the GShard (S, E, C) one-hot einsum (whose dispatch matmul
FLOPs would swamp the expert FLOPs at 128 experts): tokens are routed by
sorting (token, expert) assignments by expert id, ranking within each expert
by cumulative position, dropping beyond capacity, and scattering into a
dense (E, C, D) buffer that feeds a batched expert matmul.  The buffer's
expert axis is sharded over 'model' (expert parallelism); XLA inserts the
all-to-all at the scatter/gather boundaries.

A dense reference path (`dense_moe_apply`, all experts on all tokens) exists
for equivalence tests at tiny sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from .layers import dense_init


def moe_init(key, cfg, d: int) -> tuple[dict, dict]:
    m = cfg.moe
    f = m.d_ff or cfg.d_ff
    e = m.num_experts
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    p = {
        "router": dense_init(ks[0], (d, e), d, jnp.float32),  # router kept fp32
        "wi": dense_init(ks[1], (e, d, f), d, dtype),
        "wg": dense_init(ks[2], (e, d, f), d, dtype),
        "wo": dense_init(ks[3], (e, f, d), f, dtype),
    }
    la = {
        "router": ("embed", "expert"),
        "wi": ("expert", "embed_fsdp", "ff"),
        "wg": ("expert", "embed_fsdp", "ff"),
        "wo": ("expert", "ff", "embed_fsdp"),
    }
    return p, la


def _route(cfg, p, xf: jax.Array):
    """xf: (T, D) fp32 -> (gates (T,k), expert ids (T,k), router probs (T,E))."""
    m = cfg.moe
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx, probs


def capacity(cfg, tokens: int) -> int:
    m = cfg.moe
    c = int(m.top_k * tokens * m.capacity_factor / m.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


GROUP_TOKENS = 16384  # dispatch group size: bounds the (T*k, D) gather temps


def moe_apply(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (..., D). Returns (y, aux_loss).

    Tokens are processed in groups of GROUP_TOKENS via lax.scan: the sort-
    based dispatch materializes (G_t * k, D) gathered tokens and the
    (E, C_g, D) expert buffer per *group*, keeping transients bounded at
    production sizes (65k tokens x top-8 would otherwise gather 4 GB+ per
    layer).  Capacity applies per group."""
    m = cfg.moe
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    t = xf.shape[0]
    if t <= GROUP_TOKENS:
        y, aux = _moe_group(cfg, p, xf)
        return y.reshape(*lead, d).astype(x.dtype), aux
    g = GROUP_TOKENS
    while t % g:
        g -= 1
    xg = xf.reshape(t // g, g, d)

    # checkpoint each group: the backward otherwise saves every group's
    # gathered-token and expert-buffer residuals (full-size again).
    # (Perf log: unrolling this loop was tried to keep expert-grad
    # accumulators sharded — it *increased* CPU-XLA peak memory 37->62 GiB
    # because all groups' temps get co-scheduled; scan is the better form.)
    grp = jax.checkpoint(lambda xrow: _moe_group(cfg, p, xrow), prevent_cse=False)

    def step(_, xrow):
        y, aux = grp(xrow)
        return None, (y, aux)

    _, (ys, auxs) = jax.lax.scan(step, None, xg)
    return ys.reshape(*lead, d).astype(x.dtype), jnp.mean(auxs)


def _moe_group(cfg, p: dict, xf: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sort-based capacity dispatch for one token group.  xf: (T, D)."""
    m = cfg.moe
    d = xf.shape[-1]
    t = xf.shape[0]
    e, k = m.num_experts, m.top_k
    c = capacity(cfg, t)

    gates, idx, probs = _route(cfg, p, xf)

    # Load-balance auxiliary loss (Switch-style): E * <fraction routed> . <router prob>
    me = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(me * ce)

    # Flatten assignments and sort by expert.
    e_flat = idx.reshape(-1)                         # (T*k,)
    g_flat = gates.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    g_sorted = g_flat[order]
    # Rank within expert = index - start offset of that expert's run.
    starts = jnp.searchsorted(e_sorted, jnp.arange(e), side="left")
    rank = jnp.arange(t * k) - starts[e_sorted]
    keep = rank < c
    slot = jnp.where(keep, e_sorted * c + rank, e * c)  # dropped -> dump row

    # Dispatch by GATHER, not scatter: scatter only the int32 slot->token
    # table (tiny), then each expert shard gathers its own rows of xf.
    # A direct (E*C, D) scatter against the 'model'-sharded expert axis made
    # GSPMD emit full-buffer all-reduces (§Perf: 6.7 TB/step on qwen3).
    slot_tok = jnp.zeros((e * c + 1,), jnp.int32).at[slot].set(tok_sorted)
    slot_valid = jnp.zeros((e * c + 1,), jnp.bool_).at[slot].set(keep)
    slot_gate = jnp.zeros((e * c + 1,), jnp.float32).at[slot].set(g_sorted * keep)
    buf = jnp.take(xf, slot_tok[: e * c], axis=0) * slot_valid[: e * c, None].astype(xf.dtype)
    buf = buf.reshape(e, c, d)
    buf = shard(buf, "expert", None, None)

    # Batched expert FFN.
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    h = shard(h, "expert", None, "ff")
    if cfg.act in ("silu", "gelu"):
        act = jax.nn.silu if cfg.act == "silu" else (lambda v: jax.nn.gelu(v, approximate=True))
        h = act(h) * jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    else:
        h = jax.nn.gelu(h, approximate=True)
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    out = shard(out, "expert", None, None)

    # Combine: gate each slot's output and scatter-add back by token id
    # (invalid slots have gate 0, so their writes are no-ops on token 0).
    out_flat = out.reshape(e * c, d) * slot_gate[: e * c, None].astype(out.dtype)
    y = jnp.zeros((t, d), out.dtype).at[slot_tok[: e * c]].add(out_flat)
    return y, aux


def dense_moe_apply(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Reference: every expert on every token, masked by top-k gates."""
    m = cfg.moe
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    gates, idx, probs = _route(cfg, p, xf)
    e = m.num_experts
    h = jnp.einsum("td,edf->tef", xf, p["wi"])
    if cfg.act in ("silu", "gelu"):
        act = jax.nn.silu if cfg.act == "silu" else (lambda v: jax.nn.gelu(v, approximate=True))
        h = act(h) * jnp.einsum("td,edf->tef", xf, p["wg"])
    else:
        h = jax.nn.gelu(h, approximate=True)
    out = jnp.einsum("tef,efd->ted", h, p["wo"])  # (T, E, D)
    w = jnp.zeros((xf.shape[0], e), out.dtype)
    w = jax.vmap(lambda wrow, ids, gs: wrow.at[ids].add(gs.astype(out.dtype)))(w, idx, gates)
    y = jnp.einsum("ted,te->td", out, w)
    me = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(me * ce)
    return y.reshape(*lead, d).astype(x.dtype), aux
