"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE (arXiv:2409.12191): the rotary dim is split into three sections
(temporal, height, width) with independent position streams; text tokens use
identical positions in all three sections, image patches use (t, h, w).
Position ids are supplied as (3, B, S) int32; standard RoPE takes (B, S).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# M-RoPE section split as fractions of head_dim/2 (Qwen2-VL uses 16/24/24
# of 64 frequency pairs for head_dim 128).
MROPE_SECTIONS = (0.25, 0.375, 0.375)


def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., head_dim); cos/sin: broadcastable (..., head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    freqs = _rope_freqs(x.shape[-1], theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _apply_rotary(x, cos, sin)


def mrope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: (B, S, H, Dh); positions: (3, B, S) int32 (t, h, w streams)."""
    half = x.shape[-1] // 2
    freqs = _rope_freqs(x.shape[-1], theta)
    sizes = [int(round(f * half)) for f in MROPE_SECTIONS]
    sizes[-1] = half - sizes[0] - sizes[1]
    # Build the per-frequency position stream by section.
    sec_ids = jnp.concatenate(
        [jnp.full((n,), i, jnp.int32) for i, n in enumerate(sizes)]
    )  # (half,)
    pos_per_freq = jnp.take_along_axis(
        positions.astype(jnp.float32).transpose(1, 2, 0),  # (B, S, 3)
        jnp.broadcast_to(sec_ids[None, None, :], positions.shape[1:] + (half,)),
        axis=-1,
    )  # (B, S, half)
    ang = pos_per_freq * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _apply_rotary(x, cos, sin)


def sincos_embedding(seq: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (seq, d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def apply_positional(cfg, x: jax.Array, positions: jax.Array) -> jax.Array:
    """Dispatch on cfg.rope for q/k tensors."""
    if cfg.rope == "rope":
        return rope(x, positions if positions.ndim == 2 else positions[0], cfg.rope_theta)
    if cfg.rope == "mrope":
        if positions.ndim == 2:  # plain text stream: all three sections equal
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return mrope(x, positions, cfg.rope_theta)
    return x  # sincos/learned handled at the embedding level
