"""Basic layers: norms, MLPs, initializers.

Params are plain dict pytrees; every init function also returns a parallel
pytree of *logical axis tuples* (see repro.dist.sharding) so the launcher can
derive PartitionSpecs without re-walking model code.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard

Params = Any
Logical = Any


def dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / jnp.sqrt(jnp.asarray(in_axis_size, jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + 0.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_init(cfg, d: int) -> tuple[Params, Logical]:
    if cfg.norm == "layernorm":
        return (
            {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)},
            {"scale": ("embed",), "bias": ("embed",)},
        )
    return {"scale": jnp.zeros((d,), jnp.float32)}, {"scale": ("embed",)}


def apply_norm(cfg, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def mlp_init(key, cfg, d: int, f: int, dtype) -> tuple[Params, Logical]:
    """Gated (SwiGLU/GeGLU) or plain MLP depending on cfg.act."""
    ks = jax.random.split(key, 3)
    if cfg.act in ("silu", "gelu"):
        p = {
            "wi": dense_init(ks[0], (d, f), d, dtype),
            "wg": dense_init(ks[1], (d, f), d, dtype),
            "wo": dense_init(ks[2], (f, d), f, dtype),
        }
        la = {"wi": ("embed_fsdp", "ff"), "wg": ("embed_fsdp", "ff"), "wo": ("ff", "embed_fsdp")}
    else:  # gelu_mlp (whisper-style)
        p = {
            "wi": dense_init(ks[0], (d, f), d, dtype),
            "wo": dense_init(ks[2], (f, d), f, dtype),
        }
        la = {"wi": ("embed_fsdp", "ff"), "wo": ("ff", "embed_fsdp")}
    return p, la


def mlp_apply(cfg, p: Params, x: jax.Array) -> jax.Array:
    h = x @ p["wi"]
    h = shard(h, "batch", "seq", "ff")
    if cfg.act == "silu":
        h = jax.nn.silu(h) * (x @ p["wg"])
    elif cfg.act == "gelu":
        h = jax.nn.gelu(h, approximate=True) * (x @ p["wg"])
    else:
        h = jax.nn.gelu(h, approximate=True)
    return h @ p["wo"]
