"""Small conv classifier for the paper's MNIST-style experiments (§V).

The paper trains AlexNet on MNIST with 8 clients; offline we use a compact
CNN on the synthetic 28x28 'shapes' dataset (see data.synthetic).  Conv and
FC layers are quantized as separate groups, as in the paper ("gradients from
convolutional and fully-connected layers have different distributions").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_smallnet(key, num_classes: int = 10):
    k = jax.random.split(key, 4)
    he = lambda kk, shape, fan: jax.random.normal(kk, shape) * jnp.sqrt(2.0 / fan)
    return {
        "conv1": {"w": he(k[0], (3, 3, 1, 16), 9), "b": jnp.zeros((16,))},
        "conv2": {"w": he(k[1], (3, 3, 16, 32), 144), "b": jnp.zeros((32,))},
        "fc1": {"w": he(k[2], (7 * 7 * 32, 128), 7 * 7 * 32), "b": jnp.zeros((128,))},
        "fc2": {"w": he(k[3], (128, num_classes), 128), "b": jnp.zeros((num_classes,))},
    }


def _conv(x, w, b, stride=1):
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return y + b


def smallnet_logits(params, imgs):
    x = jax.nn.relu(_conv(imgs, params["conv1"]["w"], params["conv1"]["b"]))
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = jax.nn.relu(_conv(x, params["conv2"]["w"], params["conv2"]["b"]))
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def smallnet_loss(params, imgs, labels):
    logits = smallnet_logits(params, imgs)
    ll = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(ll, labels[:, None], axis=1))


def accuracy(params, imgs, labels):
    return jnp.mean((jnp.argmax(smallnet_logits(params, imgs), -1) == labels).astype(jnp.float32))


def grad_groups(grads) -> dict:
    """conv vs fc quantization groups (paper §V)."""
    return {
        "conv": [grads["conv1"]["w"], grads["conv2"]["w"]],
        "fc": [grads["fc1"]["w"], grads["fc2"]["w"]],
    }
