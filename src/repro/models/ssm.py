"""Mamba2 block via the SSD (state-space duality) chunked algorithm
[arXiv:2405.21060].

Training/prefill uses the chunkwise form: quadratic attention-like compute
inside chunks of length Q, a linear recurrence over chunk summaries, and a
state->output correction — O(S·Q) work, scan-friendly HLO, TPU-native (all
contractions are einsums on the MXU).  Decode is the O(1) recurrent step.

Projections are kept separate (wz/wx/wB/wC/wdt) instead of Mamba's fused
in_proj so each output dim gets a clean sharding axis (d_inner over 'model').
The short causal conv is depthwise and applied per-stream, which is
equivalent to the fused conv over the concatenated streams.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from .layers import dense_init, rmsnorm

NEG_INF = -1e30


class SSMState(NamedTuple):
    conv_x: jax.Array   # (B, W-1, d_inner) raw pre-conv inputs
    conv_B: jax.Array   # (B, W-1, G*N)
    conv_C: jax.Array   # (B, W-1, G*N)
    ssm: jax.Array      # (B, H, P, N) recurrent state


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def ssm_init(key, cfg, d: int) -> tuple[dict, dict]:
    s = cfg.ssm
    di, h = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    ks = jax.random.split(key, 10)
    dtype = jnp.dtype(cfg.param_dtype)
    # dt bias: inverse-softplus of dt ~ U[1e-3, 1e-1] (mamba2 init)
    dt = jnp.exp(
        jax.random.uniform(ks[7], (h,), jnp.float32) * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    p = {
        "wz": dense_init(ks[0], (d, di), d, dtype),
        "wx": dense_init(ks[1], (d, di), d, dtype),
        "wB": dense_init(ks[2], (d, gn), d, dtype),
        "wC": dense_init(ks[3], (d, gn), d, dtype),
        "wdt": dense_init(ks[4], (d, h), d, jnp.float32),
        "conv_x": dense_init(ks[5], (s.conv_width, di), s.conv_width, jnp.float32),
        "conv_B": dense_init(ks[6], (s.conv_width, gn), s.conv_width, jnp.float32),
        "conv_C": dense_init(ks[8], (s.conv_width, gn), s.conv_width, jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias,
        "norm": jnp.ones((di,), jnp.float32),
        "wo": dense_init(ks[9], (di, d), di, dtype),
    }
    la = {
        "wz": ("embed_fsdp", "ff"), "wx": ("embed_fsdp", "ff"),
        "wB": ("embed_fsdp", None), "wC": ("embed_fsdp", None),
        "wdt": ("embed", None),
        "conv_x": (None, "ff"), "conv_B": (None, None), "conv_C": (None, None),
        "A_log": (None,), "D": (None,), "dt_bias": (None,),
        "norm": ("ff",),
        "wo": ("ff", "embed_fsdp"),
    }
    return p, la


def _causal_conv(x: jax.Array, w: jax.Array, tail: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv.  x: (B, S, C), w: (W, C).  ``tail``: (B, W-1, C)
    carried context from a previous segment (decode/prefill-continuation)."""
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype)
    return out


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q) log-decays -> (..., Q, Q) lower-triangular segment sums."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, ss, NEG_INF)


def ssd_chunked(
    x: jax.Array,        # (B, S, H, P) — already multiplied by dt
    log_decay: jax.Array,  # (B, S, H) = dt * A  (negative)
    b_s: jax.Array,      # (B, S, G, N)
    c_s: jax.Array,      # (B, S, G, N)
    chunk: int,
    init_state: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N)).

    Sequential lax.scan over chunks: each step does the intra-chunk quadratic
    (one (B,H,Q,Q) tile), the state->output correction against the carried
    state, and the state update.  Working set per step is one chunk — the
    all-chunks-at-once form of the minimal SSD listing would materialize
    (B,H,C,Q,Q), which is TB-scale at production sizes."""
    bsz, s, h, p = x.shape
    g, n = b_s.shape[2], b_s.shape[3]
    q = min(chunk, s)
    while s % q:
        q -= 1
    nc = s // q
    hpg = h // g

    xr = x.reshape(bsz, nc, q, h, p).transpose(1, 0, 2, 3, 4)        # (C,B,Q,H,P)
    ar = log_decay.reshape(bsz, nc, q, h).transpose(1, 0, 3, 2)      # (C,B,H,Q)
    br = b_s.reshape(bsz, nc, q, g, n).transpose(1, 0, 2, 3, 4)      # (C,B,Q,G,N)
    cr = c_s.reshape(bsz, nc, q, g, n).transpose(1, 0, 2, 3, 4)

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None else init_state.astype(jnp.float32)

    def chunk_step(carry, inp):
        x_c, a_c, b_c, c_c = inp
        bh = jnp.repeat(b_c, hpg, axis=2).astype(jnp.float32)        # (B,Q,H,N)
        ch = jnp.repeat(c_c, hpg, axis=2).astype(jnp.float32)
        xf = x_c.astype(jnp.float32)
        a_cum = jnp.cumsum(a_c, axis=-1)                              # (B,H,Q)
        # intra-chunk quadratic
        el = jnp.exp(_segsum(a_c))                                    # (B,H,Q,Q)
        scores = jnp.einsum("bqhn,bkhn->bhqk", ch, bh)
        y_diag = jnp.einsum("bhqk,bhqk,bkhp->bqhp", scores, el, xf)
        # carried-state contribution
        state_decay = jnp.exp(a_cum)                                  # (B,H,Q)
        y_off = jnp.einsum("bqhn,bhpn,bhq->bqhp", ch, carry, state_decay)
        # state update
        decay_states = jnp.exp(a_cum[..., -1:] - a_cum)               # (B,H,Q)
        summary = jnp.einsum("bkhn,bhk,bkhp->bhpn", bh, decay_states, xf)
        new = carry * jnp.exp(a_cum[..., -1])[..., None, None] + summary
        return new, (y_diag + y_off).astype(x.dtype)

    final_state, ys = jax.lax.scan(chunk_step, h0, (xr, ar, br, cr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    return y, final_state


def ssm_forward(
    cfg,
    p: dict,
    x: jax.Array,
    state: SSMState | None = None,
    *,
    return_state: bool = False,
):
    """Full-sequence SSD pass (train / prefill).  x: (B, S, D)."""
    s_cfg = cfg.ssm
    di, h = ssm_dims(cfg)
    pdim = s_cfg.head_dim
    g, n = s_cfg.n_groups, s_cfg.d_state

    z = x @ p["wz"]
    xs_raw = x @ p["wx"]
    bs_raw = x @ p["wB"]
    cs_raw = x @ p["wC"]
    xs = jax.nn.silu(_causal_conv(xs_raw, p["conv_x"], state.conv_x if state else None))
    bs = jax.nn.silu(_causal_conv(bs_raw, p["conv_B"], state.conv_B if state else None))
    cs = jax.nn.silu(_causal_conv(cs_raw, p["conv_C"], state.conv_C if state else None))
    xs = shard(xs, "batch", "seq", "ff")

    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"])                                                 # (H,)
    xh = xs.reshape(*xs.shape[:2], h, pdim)
    bh = bs.reshape(*bs.shape[:2], g, n)
    chh = cs.reshape(*cs.shape[:2], g, n)
    y, fin = ssd_chunked(
        xh.astype(jnp.float32) * dt[..., None],
        dt * a,
        bh, chh,
        s_cfg.chunk,
        init_state=state.ssm if state else None,
    )
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(*x.shape[:2], di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["wo"]
    if not return_state:
        return out
    w = s_cfg.conv_width
    new_state = SSMState(
        conv_x=xs_raw[:, -(w - 1):].astype(jnp.float32),
        conv_B=bs_raw[:, -(w - 1):].astype(jnp.float32),
        conv_C=cs_raw[:, -(w - 1):].astype(jnp.float32),
        ssm=fin,
    )
    return out, new_state


def ssm_decode(cfg, p: dict, x: jax.Array, state: SSMState) -> tuple[jax.Array, SSMState]:
    """Single-token recurrent step.  x: (B, 1, D)."""
    s_cfg = cfg.ssm
    di, h = ssm_dims(cfg)
    pdim = s_cfg.head_dim
    g, n = s_cfg.n_groups, s_cfg.d_state
    w = s_cfg.conv_width
    bsz = x.shape[0]

    z = x @ p["wz"]
    xs_raw = x @ p["wx"]
    bs_raw = x @ p["wB"]
    cs_raw = x @ p["wC"]

    def step_conv(tail, new, wgt):
        ctx = jnp.concatenate([tail.astype(new.dtype), new], axis=1)  # (B, W, C)
        out = jnp.einsum("bwc,wc->bc", ctx, wgt.astype(new.dtype))[:, None]
        return jax.nn.silu(out), ctx[:, 1:]

    xs, conv_x = step_conv(state.conv_x, xs_raw, p["conv_x"])
    bs, conv_b = step_conv(state.conv_B, bs_raw, p["conv_B"])
    cs, conv_c = step_conv(state.conv_C, cs_raw, p["conv_C"])

    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a)                                                        # (B,H)
    xh = xs.reshape(bsz, h, pdim).astype(jnp.float32)
    bh = jnp.repeat(bs.reshape(bsz, g, n), h // g, axis=1).astype(jnp.float32)     # (B,H,N)
    chh = jnp.repeat(cs.reshape(bsz, g, n), h // g, axis=1).astype(jnp.float32)
    hs = state.ssm.astype(jnp.float32) * decay[..., None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xh, bh, dt
    )
    y = jnp.einsum("bhpn,bhn->bhp", hs, chh) + xh * p["D"][:, None]
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    return y @ p["wo"], SSMState(conv_x=conv_x, conv_B=conv_b, conv_C=conv_c, ssm=hs)


def init_ssm_state(cfg, batch: int, dtype=jnp.float32) -> SSMState:
    s = cfg.ssm
    di, h = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    w = s.conv_width
    return SSMState(
        conv_x=jnp.zeros((batch, w - 1, di), dtype),
        conv_B=jnp.zeros((batch, w - 1, gn), dtype),
        conv_C=jnp.zeros((batch, w - 1, gn), dtype),
        ssm=jnp.zeros((batch, h, s.head_dim, s.d_state), dtype),
    )


def naive_recurrence(x, log_decay, b_s, c_s):
    """O(S) reference recurrence for testing ssd_chunked.  Shapes as ssd_chunked."""
    bsz, s, h, p = x.shape
    g, n = b_s.shape[2], b_s.shape[3]
    hpg = h // g
    bh = jnp.repeat(b_s, hpg, axis=2)
    ch = jnp.repeat(c_s, hpg, axis=2)

    def step(hstate, t):
        xt, at, bt, ct = t
        hstate = hstate * jnp.exp(at)[..., None, None] + jnp.einsum("bhp,bhn->bhpn", xt, bt)
        yt = jnp.einsum("bhpn,bhn->bhp", hstate, ct)
        return hstate, yt

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(
        step,
        h0,
        (
            x.transpose(1, 0, 2, 3).astype(jnp.float32),
            log_decay.transpose(1, 0, 2).astype(jnp.float32),
            bh.transpose(1, 0, 2, 3).astype(jnp.float32),
            ch.transpose(1, 0, 2, 3).astype(jnp.float32),
        ),
    )
    return ys.transpose(1, 0, 2, 3)
