"""Logical-axis sharding annotations and logical→mesh partitioning rules.

Model code never mentions mesh axes.  Every init function returns a parallel
pytree of *logical axis tuples* (``("embed_fsdp", "heads")`` …) and forward
code annotates activations with :func:`shard`, e.g. ``shard(x, "batch",
"seq", None)``.  This module owns the translation onto whatever mesh the
launcher built:

- ``data`` / ``pod``   — batch-parallel axes; gradients are synchronized
  across them by the compressed collectives in ``train_step``;
- ``model``            — tensor-parallel axis for heads / ff / experts /
  vocab;
- ``embed_fsdp``       — parameter dimension additionally sharded over
  ``data`` when ``cfg.fsdp`` (ZeRO-3 style), gathered on the fly inside the
  train step.

:func:`shard` is context-dependent: outside any context it is the identity
(pure single-device use); inside :func:`axis_rules` it applies a
``with_sharding_constraint`` built from the active rule table.  The train
step installs a *manual-data* rule table (batch/fsdp axes are manual inside
its shard_map, so constraints may only mention the auto ``model`` axis);
the serve path installs the full table.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import compat  # noqa: F401  (installs the jax.shard_map / AxisType shims)

# Logical axes that live on the tensor-parallel mesh axis.
MODEL_AXES = ("vocab", "heads", "kv_heads", "ff", "expert")
# Logical axes that are never sharded (scan/layer stacking, plain embed dim).
REPLICATED_AXES = ("layers", "embed", "seq")

_ACTIVE: list[tuple[Mesh, dict]] = []


def data_axes(mesh: Mesh) -> tuple | None:
    """Batch-parallel mesh axes, outermost first (``("pod", "data")`` …)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes or None


def manual_axes(mesh: Mesh) -> tuple:
    """Mesh axes the train step runs manually (its shard_map axis_names)."""
    return data_axes(mesh) or ()


def activation_rules(mesh: Mesh, *, manual_data: bool = False, fsdp: bool = False) -> dict:
    """Logical→mesh rule table for activation constraints.

    With ``manual_data=True`` (inside the train step's shard_map) the
    batch/fsdp axes are dropped: they are manual there and constraints may
    only reference auto axes.
    """
    rules: dict = {}
    if "model" in mesh.axis_names:
        for name in MODEL_AXES:
            rules[name] = "model"
    if not manual_data:
        dp = data_axes(mesh)
        if dp:
            rules["batch"] = dp
        if fsdp and "data" in mesh.axis_names:
            rules["embed_fsdp"] = "data"
    return rules


@contextmanager
def axis_rules(mesh: Mesh, rules: dict):
    """Activate a rule table for :func:`shard` during tracing."""
    _ACTIVE.append((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.pop()


def _axis_group_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (None = replicated).

    Identity outside an :func:`axis_rules` context, and per-dimension axes
    are dropped whenever the dimension is not evenly divisible by the mapped
    mesh-axis group (tiny reduced configs on wide meshes).
    """
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    spec = []
    nontrivial = False
    for dim, name in enumerate(logical):
        axes = rules.get(name) if name is not None else None
        if axes is not None and x.shape[dim] % _axis_group_size(mesh, axes) != 0:
            axes = None
        spec.append(axes)
        nontrivial = nontrivial or axes is not None
    if not nontrivial:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs from logical-axis pytrees
# ---------------------------------------------------------------------------


def _leaf_pspec(axes: tuple, shape: tuple | None, mesh: Mesh, fsdp: bool) -> P:
    entries = []
    for dim, name in enumerate(axes):
        mapped = None
        if name in MODEL_AXES and "model" in mesh.axis_names:
            mapped = "model"
        elif name == "embed_fsdp" and fsdp and "data" in mesh.axis_names:
            mapped = "data"
        if mapped is not None and shape is not None and shape[dim] % mesh.shape[mapped] != 0:
            mapped = None
        entries.append(mapped)
    return P(*entries)


def param_pspecs(logical: Any, mesh: Mesh, fsdp: bool, params_like: Any = None) -> Any:
    """PartitionSpec pytree for a parameter tree described by ``logical``.

    ``params_like`` (arrays or ShapeDtypeStructs) enables divisibility
    pruning: any mapped axis that does not evenly divide its dimension is
    dropped rather than left to fail at ``device_put``.
    """
    is_axes = lambda t: isinstance(t, tuple)
    axes_leaves, treedef = jax.tree.flatten(logical, is_leaf=is_axes)
    shape_leaves = [x.shape for x in jax.tree.leaves(params_like)] \
        if params_like is not None else [None] * len(axes_leaves)
    specs = [_leaf_pspec(a, s, mesh, fsdp) for a, s in zip(axes_leaves, shape_leaves)]
    return jax.tree.unflatten(treedef, specs)


def strip_to_manual(spec_tree: Any, mesh: Mesh) -> Any:
    """Project a PartitionSpec tree onto the manual (data/pod) axes only.

    shard_map ``in_specs``/``out_specs`` may not mention auto axes; the auto
    sharding of those dimensions is carried by the arrays themselves.
    """
    keep = set(manual_axes(mesh))

    def one(spec: P) -> P:
        entries = []
        for e in spec:
            if isinstance(e, tuple):
                kept = tuple(a for a in e if a in keep)
                entries.append(kept if kept else None)
            else:
                entries.append(e if e in keep else None)
        return P(*entries)

    return jax.tree.map(one, spec_tree, is_leaf=lambda s: isinstance(s, P))
