"""Single-device reference of the sharded gradient-sync collectives.

Replays exactly what ``train_step._make_sync_fn``'s shard_map body computes
on an (pods…, data) mesh — same bucket plan, same per-peer RNG folding, same
encode/decode helpers — but with every collective replaced by explicit
indexing over a stacked peer axis on one device:

- ``all_gather_stacked``  →  the stacked array itself;
- ``all_to_all_rows``     →  a transpose of the stacked chunk rows;
- ``flat_axis_index``     →  the row index (row-major over the dp axes).

The *local* codec ops are not re-implemented: planning, encoding and the
fused decode go through the very same ``sharded_codec`` helpers the mesh
path calls (``_plan_bucket``, ``_plan_encode_rows``, ``encode_pack``,
``encode_pack_residual``, ``decode_reduce``, ``decode_rows``, and
``adaptive.telemetry.correct_stats`` for the EF+stats pass), so under a
common jit the reference is
**bit-identical** to the mesh result for every compressed mode — only the
collective wiring and key folding are spelled out here, which is precisely
what ``tests/test_mesh_invariance.py`` pins.  (``dsgd`` uses ``jnp.mean``
where the mesh runs ``lax.pmean``; the all-reduce's summation order is the
partitioner's, so that one mode is compared within float tolerance.)

``tests/test_golden_convergence.py`` reuses :func:`reference_sync` to run
fixed-seed multi-client training per sync mode without devices, so codec
refactors that silently bias the mean fail tier-1.
"""
from __future__ import annotations

from collections.abc import Sequence

import jax
import jax.numpy as jnp

from repro.adaptive import telemetry as adaptive_telemetry
from repro.core import compressors
from repro.core.codecs import size_adaptive_plan
from repro.core.compressors import CompressorConfig, plan
from repro.obs import metrics as obs_metrics

from . import sharded_codec as sc

# Elastic replay note: every ``live=`` parameter below reuses the mesh
# path's own masking helpers (``sc._mask_wire`` / ``sc._mask_resid`` /
# ``sc._live_scale``) on the identical operands, so a k-of-n live subset
# replays bit-for-bit — the chaos-harness contract
# ``tests/test_mesh_invariance.py`` pins.


def _fold(key: jax.Array, i: int) -> jax.Array:
    """``sc._peer_key`` for the peer whose flat axis index is ``i``."""
    return jax.random.fold_in(key, i)


def _in_keys(key, n: int) -> list:
    """Per-peer *incoming* key list: collectives normally receive one
    replicated key, but the hierarchical intra-pod phase hands each peer an
    already-folded key — accept both."""
    return list(key) if isinstance(key, list | tuple) else [key] * n


# ---------------------------------------------------------------------------
# Single-tensor modes (the per-leaf codec, ``bucket_mb=0``)
# ---------------------------------------------------------------------------


def faithful_ring_mean(cfg: CompressorConfig, stacked: jax.Array, key,
                       use_pallas: bool = False) -> jax.Array:
    """``sc.faithful_ring_mean`` over ``stacked`` (n, m) per-peer tensors."""
    n = stacked.shape[0]
    keys = _in_keys(key, n)
    if n == 1:
        flat = stacked[0].reshape(-1).astype(jnp.float32)
        meta = plan(cfg, flat)
        words = sc.encode_pack(cfg, flat, meta, keys[0], use_pallas)
        return sc.decode_reduce(cfg, words[None], meta.levels[None], flat.size, use_pallas)
    words, levels = [], []
    for i in range(n):
        flat = stacked[i].reshape(-1).astype(jnp.float32)
        meta = plan(cfg, flat)
        words.append(sc.encode_pack(cfg, flat, meta, _fold(keys[i], i), use_pallas))
        levels.append(meta.levels)
    m = stacked.shape[1]
    return sc.decode_reduce(cfg, jnp.stack(words), jnp.stack(levels), m, use_pallas)


def two_phase_mean(cfg: CompressorConfig, stacked: jax.Array, key,
                   use_pallas: bool = False) -> jax.Array:
    """``sc.two_phase_mean`` over ``stacked`` (n, m): compressed
    reduce-scatter then compressed all-gather, identical on every peer."""
    n, size = stacked.shape
    if n == 1:
        return stacked[0]
    keys = [jax.random.split(_fold(k, j)) for j, k in enumerate(_in_keys(key, n))]
    pad = (-size) % n
    m = (size + pad) // n
    # Phase 1 (reduce-scatter): peer i packs its n chunk rows; peer j decodes
    # row j of every peer (the all-to-all transpose) into its mean chunk.
    words, levels = [], []
    for i in range(n):
        flats = jnp.pad(stacked[i].astype(jnp.float32), (0, pad)).reshape(n, m)
        w, metas = sc._plan_encode_rows(cfg, flats, _fold(keys[i][0], i), use_pallas)
        words.append(w)
        levels.append(metas.levels)
    chunks = [
        sc.decode_reduce(cfg, jnp.stack([words[i][j] for i in range(n)]),
                         jnp.stack([levels[i][j] for i in range(n)]), m, use_pallas)
        for j in range(n)
    ]
    # Phase 2 (all-gather): each peer re-quantizes its mean chunk.
    words2, levels2 = [], []
    for j in range(n):
        meta2 = plan(cfg, chunks[j])
        words2.append(sc.encode_pack(cfg, chunks[j], meta2, keys[j][1], use_pallas))
        levels2.append(meta2.levels)
    full = sc.decode_rows(cfg, jnp.stack(words2), jnp.stack(levels2), m, use_pallas)
    return full.reshape(n * m)[:size]


def hierarchical_mean(cfg: CompressorConfig, stacked: jax.Array, n_pod: int, key,
                      use_pallas: bool = False) -> jax.Array:
    """``train_step._sync_leaf``'s hierarchical composition: two-phase inside
    each pod's data axis, faithful exchange of the pod means across pods."""
    n = stacked.shape[0]
    nd = n // n_pod
    k1, k2 = jax.random.split(key)
    pod_means = []
    for p in range(n_pod):
        in_keys = [_fold(k1, p * nd + d) for d in range(nd)]
        pod_means.append(two_phase_mean(cfg, stacked[p * nd:(p + 1) * nd], in_keys,
                                        use_pallas))
    return faithful_ring_mean(cfg, jnp.stack(pod_means), k2, use_pallas)


# ---------------------------------------------------------------------------
# Bucketed modes (the default codec)
# ---------------------------------------------------------------------------


def _peer_stats(cfg: CompressorConfig, buckets: list, use_pallas: bool,
                stats: list | None) -> list:
    """Per-peer × per-bucket one-pass statistics tuples (computed from each
    peer's bucket row when not handed in): ``stats[i][b]``."""
    if stats is not None:
        return stats
    n = buckets[0].shape[0]
    return [[adaptive_telemetry.bucket_statistics(sb[i].astype(jnp.float32),
                                                  use_pallas=use_pallas)
             for sb in buckets] for i in range(n)]


def bucketed_faithful_ring_mean(
    cfg: CompressorConfig, buckets: list, key, use_pallas: bool = False,
    bits: Sequence | None = None, stats: list | None = None,
    aux: list | None = None, live: jax.Array | None = None,
) -> tuple[list, list]:
    """``sc.bucketed_faithful_ring_mean`` over stacked (n, m_b) buckets.
    ``aux[b]`` (optional) stacks the per-peer codec aux tails (n, extra_b).
    Returns ``(mean_buckets, state_stacked)`` with ``state_stacked[b]`` the
    (n, m_b + extra_b) per-peer EF residual (+ aux) rows."""
    n = buckets[0].shape[0]
    keys = _in_keys(key, n)
    keys = [_fold(k, i) for i, k in enumerate(keys)] if n > 1 else keys
    cfgs = sc._bucket_cfgs(cfg, len(buckets), bits)
    codecs = [sc.get_codec(c.method) for c in cfgs]
    stats = _peer_stats(cfg, buckets, use_pallas, stats)
    scale = None if live is None else sc._live_scale(live, n)
    means, states = [], []
    for b, sb in enumerate(buckets):
        wires, rows = [], []
        for i in range(n):
            flat = sb[i].astype(jnp.float32)
            pln = codecs[b].plan(cfgs[b], flat, stats[i][b], use_pallas)
            w, r, a = codecs[b].encode_residual(
                cfgs[b], flat, pln, jax.random.fold_in(keys[i], b), use_pallas,
                aux=aux[b][i] if aux is not None and aux[b] is not None else None)
            if live is not None:
                w = sc._mask_wire(w, live[i])
                r = sc._mask_resid(r, flat, live[i])
            wires.append(w)
            rows.append(sc._state_row(r, a))
        states.append(jnp.stack(rows))
        mean_b = codecs[b].decode_reduce(cfgs[b], jnp.stack(wires), sb.shape[1],
                                         use_pallas)
        means.append(mean_b if scale is None else mean_b * scale)
    return means, states


def bucketed_two_phase_mean(
    cfg: CompressorConfig, buckets: list, key, use_pallas: bool = False,
    bits: Sequence | None = None, stats: list | None = None,
    aux: list | None = None, live: jax.Array | None = None,
) -> tuple[list, list]:
    """``sc.bucketed_two_phase_mean`` over stacked (n, m_b) buckets.
    Returns ``(mean_buckets, state_stacked)``.  With ``live``, phase 1
    masks dead peers' contributions and renormalizes; phase 2 (the relay
    of already-averaged chunks) runs unmasked — chunk ownership is
    structural, mirroring the mesh body."""
    n = buckets[0].shape[0]
    cfgs = sc._bucket_cfgs(cfg, len(buckets), bits)
    codecs = [sc.get_codec(c.method) for c in cfgs]
    if n == 1:
        flats = [sb[0].astype(jnp.float32) for sb in buckets]
        return flats, [
            sc._state_row(
                jnp.zeros_like(f) if live is None
                else sc._mask_resid(jnp.zeros_like(f), f, live[0]),
                aux[b][0] if aux is not None and aux[b] is not None else None)[None]
            for b, f in enumerate(flats)]
    keys = [jax.random.split(_fold(k, j)) for j, k in enumerate(_in_keys(key, n))]
    stats = _peer_stats(cfg, buckets, use_pallas, stats)
    scale = None if live is None else sc._live_scale(live, n)
    means, states = [], []
    for b, sb in enumerate(buckets):
        size = sb.shape[1]
        chunk_rows, wires, rows = [], [], []
        for i in range(n):
            flat = sb[i].astype(jnp.float32)
            pln = codecs[b].plan(cfgs[b], flat, stats[i][b], use_pallas)
            ki = jax.random.fold_in(keys[i][0], b)
            if codecs[b].chunkable:
                w, r = codecs[b].encode_chunks(cfgs[b], flat, pln, ki, n, use_pallas)
                if live is not None:
                    w = sc._mask_wire(w, live[i])
                    r = sc._mask_resid(r, flat, live[i])
                chunk_rows.append(w)
                a = None
            else:
                w, r, a = codecs[b].encode_residual(
                    cfgs[b], flat, pln, ki, use_pallas,
                    aux=aux[b][i] if aux is not None and aux[b] is not None else None)
                if live is not None:
                    w = sc._mask_wire(w, live[i])
                    r = sc._mask_resid(r, flat, live[i])
                wires.append(w)
            rows.append(sc._state_row(r, a))
        states.append(jnp.stack(rows))
        if not codecs[b].chunkable:
            # tiled all-to-all == all-gather: every peer decodes the same
            # stacked wires into the same full mean in phase 1
            fm = codecs[b].decode_reduce(cfgs[b], jnp.stack(wires), size,
                                         use_pallas)
            means.append(fm if scale is None else fm * scale)
            continue
        mc = codecs[b].chunk_elems(cfgs[b], size, n)
        chunks = [
            codecs[b].decode_reduce(
                cfgs[b], jnp.stack([chunk_rows[i][j] for i in range(n)]), mc,
                use_pallas)
            for j in range(n)
        ]
        if scale is not None:
            chunks = [ch * scale for ch in chunks]
        wires2 = [
            codecs[b].encode(cfgs[b], chunks[j],
                             codecs[b].plan(cfgs[b], chunks[j], None, use_pallas),
                             jax.random.fold_in(keys[j][1], b), use_pallas)
            for j in range(n)
        ]
        vals = codecs[b].decode_rows(cfgs[b], jnp.stack(wires2), mc, use_pallas)
        means.append(vals.reshape(n * mc)[:size])
    return means, states


def bucketed_hierarchical_mean(
    cfg: CompressorConfig, buckets: list, n_pod: int, key, use_pallas: bool = False,
    bits: Sequence | None = None, stats: list | None = None,
    aux: list | None = None, live: jax.Array | None = None,
) -> tuple[list, list]:
    """``sc.bucketed_hierarchical_mean``: intra-pod two-phase (keys folded by
    the *full* dp index), faithful pod-mean exchange across pods.  The EF
    state (residual + codec aux) is the intra-pod stage's; the cross-pod
    stage runs aux-cold (mirroring the mesh path).  With ``live``, each
    stage renormalizes over its own live members: a pod is live iff any
    member is, so pods weigh equally (as in the full-participation
    mean-of-pod-means)."""
    n = buckets[0].shape[0]
    nd = n // n_pod
    k1, k2 = jax.random.split(key)
    stats = _peer_stats(cfg, buckets, use_pallas, stats)
    mat = None if live is None else live.reshape(n_pod, nd)
    pod_means, pod_resids = [], []
    for p in range(n_pod):
        in_keys = [_fold(k1, p * nd + d) for d in range(nd)]
        aux_p = None
        if aux is not None:
            aux_p = [a[p * nd:(p + 1) * nd] if a is not None else None for a in aux]
        m, r = bucketed_two_phase_mean(
            cfg, [sb[p * nd:(p + 1) * nd] for sb in buckets], in_keys, use_pallas,
            bits, stats[p * nd:(p + 1) * nd], aux_p,
            live=None if mat is None else mat[p])
        pod_means.append(m)
        pod_resids.append(r)
    stacked = [jnp.stack([pod_means[p][b] for p in range(n_pod)])
               for b in range(len(buckets))]
    pod_live = None if mat is None else jnp.max(mat, axis=1)
    means, _ = bucketed_faithful_ring_mean(cfg, stacked, k2, use_pallas, bits,
                                           live=pod_live)
    resids = [jnp.concatenate([pod_resids[p][b] for p in range(n_pod)])
              for b in range(len(buckets))]
    return means, resids


# ---------------------------------------------------------------------------
# Top level: the shard_map body of ``_make_sync_fn``
# ---------------------------------------------------------------------------


def reference_sync_state(ts, stacked_leaves: list, dp_sizes: tuple, key: jax.Array,
                         ef=None, tstate=None, live: jax.Array | None = None):
    """Full bucketed-sync replica over the bucket-resident state layout.

    Replays ``train_step._sync_buckets`` for every peer on one device:
    per-peer fused EF correction + one-pass statistics
    (``adaptive.telemetry.correct_stats``), histogram-driven plans, the
    fused encode-pack-residual, and the collective replay.  ``ef`` is a
    list of stacked (n, m_b) bucket-resident residual arrays, ``tstate`` a
    per-peer-stacked :class:`~repro.adaptive.TelemetryState`.  Returns
    ``(mean_leaves, resid_stacked | None, new_tstate | None, metrics |
    None)`` — bit-identical to the mesh under a common jit for the codebook
    methods, which is what the EF+adaptive rows of
    ``tests/test_mesh_invariance.py`` pin.  ``metrics`` (under
    ``ts.metrics_compression``) replays the in-graph
    :class:`repro.obs.metrics.CompressionMetrics` per peer through the very
    same ``obs.metrics`` helpers the sync region calls — leaves stacked
    ``(n, n_buckets)``, bitwise equal to the mesh on meshes without model
    axes (``tests/test_obs.py`` pins this on a (2,2) pod×data mesh).
    """
    cfg = ts.compressor
    n = 1
    for s in dp_sizes:
        n *= s
    n_pod = n // dp_sizes[-1]
    shapes = [tuple(x.shape[1:]) for x in stacked_leaves]
    bp = compressors.plan_buckets([x[0].size for x in stacked_leaves],
                                  ts.bucket_elements)
    per_peer = [compressors.bucket_concat([x[j] for x in stacked_leaves], bp)
                for j in range(n)]
    compressed = not (ts.sync == "dsgd" or cfg.method == "dsgd")
    # Same size-adaptive tier rewrite as ``train_step._sync_buckets`` —
    # small buckets ship raw fp16 when ``ts.fp16_threshold`` is set.
    bits = size_adaptive_plan(cfg, ts.bits_plan, bp.sizes,
                              getattr(ts, "fp16_threshold", 0))
    # Split each EF row into the residual prefix and the codec-opaque aux
    # tail (``state_extra``; empty for the quantizers — rows pass untouched).
    cfgs = sc._bucket_cfgs(cfg, bp.n_buckets, bits)
    extras = [sc.get_codec(c.method).state_extra(c, m)
              for c, m in zip(cfgs, bp.sizes)]
    aux = None
    if ef is not None and any(extras):
        aux = [ef[b][:, bp.sizes[b]:] if x else None for b, x in enumerate(extras)]
        ef = [ef[b][:, :bp.sizes[b]] if x else ef[b] for b, x in enumerate(extras)]
    stats = None
    if compressed or tstate is not None:
        stats = []
        for j in range(n):
            row, srow = [], []
            for b, g in enumerate(per_peer[j]):
                c, st = adaptive_telemetry.correct_stats(
                    g, ef[b][j] if ef is not None else None,
                    use_pallas=cfg.use_pallas)
                row.append(c)
                srow.append(st)
            per_peer[j] = row
            stats.append(srow)
    new_t = None
    if tstate is not None:
        rows = [adaptive_telemetry.update_telemetry(
            jax.tree.map(lambda x, j=j: x[j], tstate), per_peer[j],
            decay=ts.adaptive.ema, use_pallas=cfg.use_pallas, stats=stats[j])
            for j in range(n)]
        new_t = jax.tree.map(lambda *xs: jnp.stack(xs), *rows)
    buckets = [jnp.stack([per_peer[j][b] for j in range(n)])
               for b in range(bp.n_buckets)]
    if not compressed:
        if live is None:
            means = [jnp.mean(sb, axis=0) for sb in buckets]
        else:
            scale = sc._live_scale(live, n)
            means = [jnp.mean(sb * live[:, None], axis=0) * scale
                     for sb in buckets]
        resids = None
    elif ts.sync == "faithful":
        means, resids = bucketed_faithful_ring_mean(cfg, buckets, key,
                                                    cfg.use_pallas, bits, stats,
                                                    aux, live)
    elif ts.sync == "two_phase" or len(dp_sizes) == 1:
        means, resids = bucketed_two_phase_mean(cfg, buckets, key,
                                                cfg.use_pallas, bits, stats, aux,
                                                live)
    else:
        means, resids = bucketed_hierarchical_mean(cfg, buckets, n_pod, key,
                                                   cfg.use_pallas, bits, stats,
                                                   aux, live)
    cm = None
    if ts.metrics_compression:
        rows = []
        for j in range(n):
            sums, static = obs_metrics.local_sums(
                ts, cfgs, per_peer[j],
                stats[j] if stats is not None else None,
                [resids[b][j] for b in range(bp.n_buckets)] if resids is not None else None,
                [ef[b][j] for b in range(bp.n_buckets)] if ef is not None else None,
                compressed)
            rows.append(obs_metrics.finalize(sums, static, 1))
        cm = jax.tree.map(lambda *xs: jnp.stack(xs), *rows)
    if not ts.error_feedback:
        resids = None
    return compressors.bucket_split(means, bp, shapes), resids, new_t, cm


def reference_sync(ts, stacked_leaves: list, dp_sizes: tuple, key: jax.Array,
                   live: jax.Array | None = None) -> list:
    """Synced gradient mean as every peer of the mesh must compute it.

    ``stacked_leaves``: one (n, *leaf_shape) fp32 array per gradient leaf
    (traversal order), peer axis row-major over ``dp_sizes`` = the mesh's
    (pods…, data) manual axis sizes.  Returns the mean leaves (leaf shapes).
    Mirrors ``train_step._sync_buckets`` / ``_sync_leaf`` dispatch, including
    the ``bucket_mb=0`` per-leaf codec and heterogeneous ``bits_plan``
    (:func:`reference_sync_state` adds the EF/telemetry outputs).
    """
    cfg = ts.compressor
    n = 1
    for s in dp_sizes:
        n *= s
    n_pod = n // dp_sizes[-1]
    shapes = [tuple(x.shape[1:]) for x in stacked_leaves]
    if ts.bucket_mb > 0:
        return reference_sync_state(ts, stacked_leaves, dp_sizes, key, live=live)[0]
    if live is not None:
        raise ValueError("elastic live masks require the bucketed codec "
                         "(bucket_mb > 0); the per-leaf path has no live-set "
                         "semantics")
    out = []
    for i, x in enumerate(stacked_leaves):
        ki = jax.random.fold_in(key, i)
        flat = x.reshape(n, -1).astype(jnp.float32)
        if ts.sync == "dsgd" or cfg.method == "dsgd":
            mean = jnp.mean(flat, axis=0)
        elif ts.sync == "faithful":
            mean = faithful_ring_mean(cfg, flat, ki, cfg.use_pallas)
        elif ts.sync == "two_phase" or len(dp_sizes) == 1:
            mean = two_phase_mean(cfg, flat, ki, cfg.use_pallas)
        else:
            k1, k2 = jax.random.split(ki)
            in_keys = [_fold(k1, j) for j in range(n)]
            nd = dp_sizes[-1]
            pod_means = [
                two_phase_mean(cfg, flat[p * nd:(p + 1) * nd],
                               in_keys[p * nd:(p + 1) * nd], cfg.use_pallas)
                for p in range(n_pod)
            ]
            mean = faithful_ring_mean(cfg, jnp.stack(pod_means), k2, cfg.use_pallas)
        out.append(mean.reshape(shapes[i]))
    return out
