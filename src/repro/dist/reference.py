"""Single-device reference of the sharded gradient-sync collectives.

Replays exactly what ``train_step._make_sync_fn``'s shard_map body computes
on an (pods…, data) mesh — same bucket plan, same per-peer RNG folding, same
encode/decode helpers — but with every collective replaced by explicit
indexing over a stacked peer axis on one device:

- ``all_gather_stacked``  →  the stacked array itself;
- ``all_to_all_rows``     →  a transpose of the stacked chunk rows;
- ``flat_axis_index``     →  the row index (row-major over the dp axes).

The *local* codec ops are not re-implemented: planning, encoding and the
fused decode go through the very same ``sharded_codec`` helpers the mesh
path calls (``_plan_encode_rows``, ``_encode_flat``, ``_encode_packed_flat``,
``decode_reduce``, ``decode_rows``), so under a common jit the reference is
**bit-identical** to the mesh result for every compressed mode — only the
collective wiring and key folding are spelled out here, which is precisely
what ``tests/test_mesh_invariance.py`` pins.  (``dsgd`` uses ``jnp.mean``
where the mesh runs ``lax.pmean``; the all-reduce's summation order is the
partitioner's, so that one mode is compared within float tolerance.)

``tests/test_golden_convergence.py`` reuses :func:`reference_sync` to run
fixed-seed multi-client training per sync mode without devices, so codec
refactors that silently bias the mean fail tier-1.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import compressors
from repro.core.compressors import CompressorConfig, plan
from repro.core.quantizers import pack_codes

from . import sharded_codec as sc


def _fold(key: jax.Array, i: int) -> jax.Array:
    """``sc._peer_key`` for the peer whose flat axis index is ``i``."""
    return jax.random.fold_in(key, i)


def _in_keys(key, n: int) -> list:
    """Per-peer *incoming* key list: collectives normally receive one
    replicated key, but the hierarchical intra-pod phase hands each peer an
    already-folded key — accept both."""
    return list(key) if isinstance(key, (list, tuple)) else [key] * n


# ---------------------------------------------------------------------------
# Single-tensor modes (the per-leaf codec, ``bucket_mb=0``)
# ---------------------------------------------------------------------------


def faithful_ring_mean(cfg: CompressorConfig, stacked: jax.Array, key,
                       use_pallas: bool = False) -> jax.Array:
    """``sc.faithful_ring_mean`` over ``stacked`` (n, m) per-peer tensors."""
    n = stacked.shape[0]
    keys = _in_keys(key, n)
    if n == 1:
        flat = stacked[0].reshape(-1).astype(jnp.float32)
        meta = plan(cfg, flat)
        codes = sc._encode_flat(cfg, flat, meta, keys[0], use_pallas)
        return jnp.take(meta.levels, codes.astype(jnp.int32))
    words, levels = [], []
    for i in range(n):
        flat = stacked[i].reshape(-1).astype(jnp.float32)
        meta = plan(cfg, flat)
        codes = sc._encode_flat(cfg, flat, meta, _fold(keys[i], i), use_pallas)
        words.append(pack_codes(codes, cfg.bits))
        levels.append(meta.levels)
    m = stacked.shape[1]
    return sc.decode_reduce(cfg, jnp.stack(words), jnp.stack(levels), m, use_pallas)


def two_phase_mean(cfg: CompressorConfig, stacked: jax.Array, key,
                   use_pallas: bool = False) -> jax.Array:
    """``sc.two_phase_mean`` over ``stacked`` (n, m): compressed
    reduce-scatter then compressed all-gather, identical on every peer."""
    n, size = stacked.shape
    if n == 1:
        return stacked[0]
    keys = [jax.random.split(_fold(k, j)) for j, k in enumerate(_in_keys(key, n))]
    pad = (-size) % n
    m = (size + pad) // n
    # Phase 1 (reduce-scatter): peer i packs its n chunk rows; peer j decodes
    # row j of every peer (the all-to-all transpose) into its mean chunk.
    words, levels = [], []
    for i in range(n):
        flats = jnp.pad(stacked[i].astype(jnp.float32), (0, pad)).reshape(n, m)
        w, metas = sc._plan_encode_rows(cfg, flats, _fold(keys[i][0], i), use_pallas)
        words.append(w)
        levels.append(metas.levels)
    chunks = [
        sc.decode_reduce(cfg, jnp.stack([words[i][j] for i in range(n)]),
                         jnp.stack([levels[i][j] for i in range(n)]), m, use_pallas)
        for j in range(n)
    ]
    # Phase 2 (all-gather): each peer re-quantizes its mean chunk.
    words2, levels2 = [], []
    for j in range(n):
        meta2 = plan(cfg, chunks[j])
        codes2 = sc._encode_flat(cfg, chunks[j], meta2, keys[j][1], use_pallas)
        words2.append(pack_codes(codes2, cfg.bits))
        levels2.append(meta2.levels)
    full = sc.decode_rows(cfg, jnp.stack(words2), jnp.stack(levels2), m, use_pallas)
    return full.reshape(n * m)[:size]


def hierarchical_mean(cfg: CompressorConfig, stacked: jax.Array, n_pod: int, key,
                      use_pallas: bool = False) -> jax.Array:
    """``train_step._sync_leaf``'s hierarchical composition: two-phase inside
    each pod's data axis, faithful exchange of the pod means across pods."""
    n = stacked.shape[0]
    nd = n // n_pod
    k1, k2 = jax.random.split(key)
    pod_means = []
    for p in range(n_pod):
        in_keys = [_fold(k1, p * nd + d) for d in range(nd)]
        pod_means.append(two_phase_mean(cfg, stacked[p * nd:(p + 1) * nd], in_keys,
                                        use_pallas))
    return faithful_ring_mean(cfg, jnp.stack(pod_means), k2, use_pallas)


# ---------------------------------------------------------------------------
# Bucketed modes (the default codec)
# ---------------------------------------------------------------------------


def bucketed_faithful_ring_mean(
    cfg: CompressorConfig, buckets: list, key, use_pallas: bool = False,
    bits: Optional[Sequence[int]] = None,
) -> list:
    """``sc.bucketed_faithful_ring_mean`` over stacked (n, m_b) buckets."""
    n = buckets[0].shape[0]
    keys = _in_keys(key, n)
    keys = [_fold(k, i) for i, k in enumerate(keys)] if n > 1 else keys
    cfgs = sc._bucket_cfgs(cfg, len(buckets), bits)
    means = []
    for b, sb in enumerate(buckets):
        words, levels, owns = [], [], []
        for i in range(n):
            flat = sb[i].astype(jnp.float32)
            meta = plan(cfgs[b], flat)
            w, codes = sc._encode_packed_flat(cfgs[b], flat, meta,
                                              jax.random.fold_in(keys[i], b), use_pallas)
            words.append(w)
            levels.append(meta.levels)
            owns.append(jnp.take(meta.levels, codes.astype(jnp.int32)))
        if n == 1:
            means.append(owns[0])
        else:
            means.append(sc.decode_reduce(cfgs[b], jnp.stack(words), jnp.stack(levels),
                                          sb.shape[1], use_pallas))
    return means


def bucketed_two_phase_mean(
    cfg: CompressorConfig, buckets: list, key, use_pallas: bool = False,
    bits: Optional[Sequence[int]] = None,
) -> list:
    """``sc.bucketed_two_phase_mean`` over stacked (n, m_b) buckets."""
    n = buckets[0].shape[0]
    if n == 1:
        return [sb[0].astype(jnp.float32) for sb in buckets]
    keys = [jax.random.split(_fold(k, j)) for j, k in enumerate(_in_keys(key, n))]
    cfgs = sc._bucket_cfgs(cfg, len(buckets), bits)
    means = []
    for b, sb in enumerate(buckets):
        size = sb.shape[1]
        mc = (size + (-size) % (n * 32)) // n
        words, levels = [], []
        for i in range(n):
            flat = sb[i].astype(jnp.float32)
            padded = jnp.pad(flat, (0, (-size) % (n * 32)))
            meta = plan(cfgs[b], flat)
            w, _ = sc._encode_packed_flat(cfgs[b], padded, meta,
                                          jax.random.fold_in(keys[i][0], b), use_pallas)
            words.append(w.reshape(n, -1))
            levels.append(meta.levels)
        chunks = [
            sc.decode_reduce(cfgs[b], jnp.stack([words[i][j] for i in range(n)]),
                             jnp.stack(levels), mc, use_pallas)
            for j in range(n)
        ]
        words2, levels2 = [], []
        for j in range(n):
            meta2 = plan(cfgs[b], chunks[j])
            w2, _ = sc._encode_packed_flat(cfgs[b], chunks[j], meta2,
                                           jax.random.fold_in(keys[j][1], b), use_pallas)
            words2.append(w2)
            levels2.append(meta2.levels)
        vals = sc.decode_rows(cfgs[b], jnp.stack(words2), jnp.stack(levels2), mc,
                              use_pallas)
        means.append(vals.reshape(n * mc)[:size])
    return means


def bucketed_hierarchical_mean(
    cfg: CompressorConfig, buckets: list, n_pod: int, key, use_pallas: bool = False,
    bits: Optional[Sequence[int]] = None,
) -> list:
    """``sc.bucketed_hierarchical_mean``: intra-pod two-phase (keys folded by
    the *full* dp index), faithful pod-mean exchange across pods."""
    n = buckets[0].shape[0]
    nd = n // n_pod
    k1, k2 = jax.random.split(key)
    pod_means = []
    for p in range(n_pod):
        in_keys = [_fold(k1, p * nd + d) for d in range(nd)]
        pod_means.append(bucketed_two_phase_mean(
            cfg, [sb[p * nd:(p + 1) * nd] for sb in buckets], in_keys, use_pallas, bits))
    stacked = [jnp.stack([pod_means[p][b] for p in range(n_pod)])
               for b in range(len(buckets))]
    return bucketed_faithful_ring_mean(cfg, stacked, k2, use_pallas, bits)


# ---------------------------------------------------------------------------
# Top level: the shard_map body of ``_make_sync_fn``
# ---------------------------------------------------------------------------


def reference_sync(ts, stacked_leaves: list, dp_sizes: tuple, key: jax.Array) -> list:
    """Synced gradient mean as every peer of the mesh must compute it.

    ``stacked_leaves``: one (n, *leaf_shape) fp32 array per gradient leaf
    (traversal order), peer axis row-major over ``dp_sizes`` = the mesh's
    (pods…, data) manual axis sizes.  Returns the mean leaves (leaf shapes).
    Mirrors ``train_step._sync_buckets`` / ``_sync_leaf`` dispatch, including
    the ``bucket_mb=0`` per-leaf codec and heterogeneous ``bits_plan``.
    """
    cfg = ts.compressor
    n = 1
    for s in dp_sizes:
        n *= s
    n_pod = n // dp_sizes[-1]
    shapes = [tuple(x.shape[1:]) for x in stacked_leaves]
    if ts.bucket_mb > 0:
        bp = compressors.plan_buckets([x[0].size for x in stacked_leaves],
                                      ts.bucket_elements)
        per_peer = [compressors.bucket_concat([x[j] for x in stacked_leaves], bp)
                    for j in range(n)]
        buckets = [jnp.stack([per_peer[j][b] for j in range(n)])
                   for b in range(bp.n_buckets)]
        if ts.sync == "dsgd" or cfg.method == "dsgd":
            means = [jnp.mean(sb, axis=0) for sb in buckets]
        elif ts.sync == "faithful":
            means = bucketed_faithful_ring_mean(cfg, buckets, key,
                                                cfg.use_pallas, ts.bits_plan)
        elif ts.sync == "two_phase" or len(dp_sizes) == 1:
            means = bucketed_two_phase_mean(cfg, buckets, key,
                                            cfg.use_pallas, ts.bits_plan)
        else:
            means = bucketed_hierarchical_mean(cfg, buckets, n_pod, key,
                                               cfg.use_pallas, ts.bits_plan)
        return compressors.bucket_split(means, bp, shapes)
    out = []
    for i, x in enumerate(stacked_leaves):
        ki = jax.random.fold_in(key, i)
        flat = x.reshape(n, -1).astype(jnp.float32)
        if ts.sync == "dsgd" or cfg.method == "dsgd":
            mean = jnp.mean(flat, axis=0)
        elif ts.sync == "faithful":
            mean = faithful_ring_mean(cfg, flat, ki, cfg.use_pallas)
        elif ts.sync == "two_phase" or len(dp_sizes) == 1:
            mean = two_phase_mean(cfg, flat, ki, cfg.use_pallas)
        else:
            k1, k2 = jax.random.split(ki)
            in_keys = [_fold(k1, j) for j in range(n)]
            nd = dp_sizes[-1]
            pod_means = [
                two_phase_mean(cfg, flat[p * nd:(p + 1) * nd],
                               in_keys[p * nd:(p + 1) * nd], cfg.use_pallas)
                for p in range(n_pod)
            ]
            mean = faithful_ring_mean(cfg, jnp.stack(pod_means), k2, cfg.use_pallas)
        out.append(mean.reshape(shapes[i]))
    return out
