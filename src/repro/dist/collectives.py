"""Analytic wire accounting for the gradient-sync collectives.

``wire_bytes_per_device(cfg, n, shards, mode)`` returns the bytes one device
puts on the wire **per hop of a bandwidth-optimal ring schedule** for one
n-element gradient sync over ``shards`` devices.  Per-hop payload is the
right unit for roofline math: a ring schedule runs ``O(shards)`` hops
back-to-back, so step latency on the interconnect is ``hops × per-hop
bytes / link bandwidth``, and the per-hop payload is what each link carries
at any instant.

- ``dsgd``          — fp32 ring all-reduce: each hop moves one fp32 chunk,
                      ``4 · n / shards`` bytes (reduce-scatter and all-gather
                      phases have identical per-hop cost);
- ``two_phase``     — both phases move one *quantized* chunk + its codebook:
                      ``wire_bytes(cfg, ceil(n/shards))``;
- ``faithful``      — chunk-pipelined ring all-gather of each peer's full
                      quantized tensor: ``wire_bytes(cfg, n) / shards`` per
                      hop (codebooks amortized over the ring);
- ``hierarchical``  — two-phase inside the pod plus the cross-pod faithful
                      exchange of the pod mean amortized over pod members.

The compression ratio vs fp32 is therefore ~``32 / bits`` for two_phase and
faithful — independent of ``shards`` — matching the paper's wire model.
"""
from __future__ import annotations

from repro.core.compressors import CompressorConfig, wire_bytes

MODES = ("dsgd", "two_phase", "hierarchical", "faithful")


def wire_bytes_per_device(cfg: CompressorConfig, n, shards: int, mode: str, bits=None) -> float:
    """Per-device, per-hop wire bytes for one n-element gradient sync.

    ``n`` may be a sequence of per-bucket sizes with a matching sequence of
    per-bucket ``bits`` (the adaptive fused wire format); the cost is then
    the sum over buckets, each chunked per the mode.
    """
    if isinstance(n, (list, tuple)):
        bl = bits if isinstance(bits, (list, tuple)) else [bits] * len(n)
        if len(bl) != len(n):
            raise ValueError(f"{len(bl)} bit-widths vs {len(n)} buckets")
        return sum(wire_bytes_per_device(cfg, nb, shards, mode, b) for nb, b in zip(n, bl))
    if mode not in MODES:
        raise ValueError(f"unknown sync mode {mode!r}; expected one of {MODES}")
    if shards < 1:
        raise ValueError("shards must be >= 1")
    if mode == "dsgd" or cfg.method == "dsgd":
        return 4.0 * n / shards
    chunk = -(-n // shards)
    if mode == "two_phase":
        return float(wire_bytes(cfg, chunk, bits))
    if mode == "faithful":
        return wire_bytes(cfg, n, bits) / shards
    # hierarchical: intra-pod two-phase chunk + the pod-mean faithful
    # exchange across pods, spread over the pod's members.
    return float(wire_bytes(cfg, chunk, bits)) + wire_bytes(cfg, n, bits) / shards


def decode_hbm_bytes(cfg: CompressorConfig, n, peers: int, fused: bool, bits=None) -> float:
    """HBM bytes one device moves to decode + average ``peers`` gathered
    n-element wire rows (the decode half of ``faithful`` / the reduce side of
    ``two_phase``).

    - unfused (the pre-fusion ``vmap(unpack_codes)`` → ``take`` → ``mean``
      path): reads the packed words, then writes *and re-reads* the
      (peers, n) int32 unpacked code tensor and the (peers, n) fp32
      dequantized tensor before reducing to the (n,) output;
    - fused (``kernels.decode``): reads the packed words once and writes the
      (n,) mean once — codes and values never leave VMEM.

    Both include the per-peer codebook reads.  ``n``/``bits`` may be
    per-bucket sequences (the adaptive fused wire format); the cost sums.
    """
    if isinstance(n, (list, tuple)):
        bl = bits if isinstance(bits, (list, tuple)) else [bits] * len(n)
        if len(bl) != len(n):
            raise ValueError(f"{len(bl)} bit-widths vs {len(n)} buckets")
        return sum(decode_hbm_bytes(cfg, nb, peers, fused, b) for nb, b in zip(n, bl))
    from repro.core.quantizers import num_levels, packed_size

    b = cfg.bits if bits is None else int(bits)
    words = 4.0 * peers * packed_size(n, b) + 4.0 * peers * (num_levels(b) + 1)
    if fused:
        return words + 4.0 * n
    return words + 2 * 4.0 * peers * n + 2 * 4.0 * peers * n + 4.0 * n
