"""Analytic wire accounting for the gradient-sync collectives.

``wire_bytes_per_device(cfg, n, shards, mode)`` returns the bytes one device
puts on the wire **per hop of a bandwidth-optimal ring schedule** for one
n-element gradient sync over ``shards`` devices.  Per-hop payload is the
right unit for roofline math: a ring schedule runs ``O(shards)`` hops
back-to-back, so step latency on the interconnect is ``hops × per-hop
bytes / link bandwidth``, and the per-hop payload is what each link carries
at any instant.

- ``dsgd``          — fp32 ring all-reduce: each hop moves one fp32 chunk,
                      ``4 · n / shards`` bytes (reduce-scatter and all-gather
                      phases have identical per-hop cost);
- ``two_phase``     — both phases move one *quantized* chunk + its codebook:
                      ``wire_bytes(cfg, ceil(n/shards))``;
- ``faithful``      — chunk-pipelined ring all-gather of each peer's full
                      quantized tensor: ``wire_bytes(cfg, n) / shards`` per
                      hop (codebooks amortized over the ring);
- ``hierarchical``  — two-phase inside the pod plus the cross-pod faithful
                      exchange of the pod mean amortized over pod members.

The compression ratio vs fp32 is therefore ~``32 / bits`` for two_phase and
faithful — independent of ``shards`` — matching the paper's wire model.
"""
from __future__ import annotations

from repro.core.compressors import METHODS, CompressorConfig, wire_bytes

MODES = ("dsgd", "two_phase", "hierarchical", "faithful")


def _plan_entry(bits):
    """True for a ``("method", value)`` plan entry or a full config."""
    if isinstance(bits, CompressorConfig):
        return True
    return (isinstance(bits, tuple | list) and len(bits) == 2
            and isinstance(bits[0], str))


def _bucket_cfg(cfg: CompressorConfig, bits) -> CompressorConfig:
    """Resolve a per-bucket plan entry (int bits / method tuple / config)
    against the base config; plain ``None``/int keep the quantizer path."""
    from repro.core.codecs import bucket_cfg_entry

    return cfg if bits is None else bucket_cfg_entry(cfg, bits)


def wire_bytes_per_device(cfg: CompressorConfig, n, shards: int, mode: str, bits=None) -> float:
    """Per-device, per-hop wire bytes for one n-element gradient sync.

    ``n`` may be a sequence of per-bucket sizes with a matching sequence of
    per-bucket ``bits`` entries — plain bit widths or ``("method", value)``
    codec-plan entries (the adaptive fused wire format); the cost is then
    the sum over buckets, each chunked per the mode.  Rank-based codecs put
    an indivisible factor pair on the wire, so their two-phase cost is the
    full wire (tiled all-to-all rows, no phase-2 refinement).
    """
    if isinstance(n, list | tuple):
        bl = bits if isinstance(bits, list | tuple) and not _plan_entry(bits) \
            else [bits] * len(n)
        if len(bl) != len(n):
            raise ValueError(f"{len(bl)} bit-widths vs {len(n)} buckets")
        return sum(wire_bytes_per_device(cfg, nb, shards, mode, b) for nb, b in zip(n, bl))
    if mode not in MODES:
        raise ValueError(f"unknown sync mode {mode!r}; expected one of {MODES}")
    if shards < 1:
        raise ValueError("shards must be >= 1")
    if mode == "dsgd" or cfg.method == "dsgd":
        return 4.0 * n / shards
    bcfg = _bucket_cfg(cfg, bits)
    if bcfg.method not in METHODS:
        from repro.core.codecs import get_codec

        full = float(get_codec(bcfg.method).wire_bytes(bcfg, n))
        if mode == "two_phase":
            return full          # full wire tiled into every all-to-all row
        if mode == "faithful":
            return full / shards
        return full + full / shards
    chunk = -(-n // shards)
    if mode == "two_phase":
        return float(wire_bytes(bcfg, chunk))
    if mode == "faithful":
        return wire_bytes(bcfg, n) / shards
    # hierarchical: intra-pod two-phase chunk + the pod-mean faithful
    # exchange across pods, spread over the pod's members.
    return float(wire_bytes(bcfg, chunk)) + wire_bytes(bcfg, n) / shards


def decode_hbm_bytes(cfg: CompressorConfig, n, peers: int, fused: bool, bits=None) -> float:
    """HBM bytes one device moves to decode + average ``peers`` gathered
    n-element wire rows (the decode half of ``faithful`` / the reduce side of
    ``two_phase``).

    - unfused (the pre-fusion ``vmap(unpack_codes)`` → ``take`` → ``mean``
      path): reads the packed words, then writes *and re-reads* the
      (peers, n) int32 unpacked code tensor and the (peers, n) fp32
      dequantized tensor before reducing to the (n,) output;
    - fused (``kernels.decode``): reads the packed words once and writes the
      (n,) mean once — codes and values never leave VMEM.

    Both include the per-peer codebook reads.  ``n``/``bits`` may be
    per-bucket sequences (the adaptive fused wire format); the cost sums.
    """
    if isinstance(n, list | tuple):
        bl = bits if isinstance(bits, list | tuple) and not _plan_entry(bits) \
            else [bits] * len(n)
        if len(bl) != len(n):
            raise ValueError(f"{len(bl)} bit-widths vs {len(n)} buckets")
        return sum(decode_hbm_bytes(cfg, nb, peers, fused, b) for nb, b in zip(n, bl))
    from repro.core.codecs import get_codec

    bcfg = _bucket_cfg(cfg, bits)
    # The registry is the single source of truth for wire geometry: one
    # (wire_words,) uint32 row per peer — packed codes + bitcast codebook
    # for the quantizers, the bitcast factor pair for rank-based codecs
    # (cross-checked against the traced collective operands in
    # ``tests/test_analysis.py``).
    words = 4.0 * peers * get_codec(bcfg.method).wire_words(bcfg, n)
    if bcfg.method not in METHODS:
        # Rank-based decode: read every peer's factor pair, reconstruct
        # (fused keeps the per-peer (n,) reconstructions in VMEM; unfused
        # writes + re-reads them before the mean).
        if fused:
            return words + 4.0 * n
        return words + 2 * 4.0 * peers * n + 4.0 * n
    if fused:
        return words + 4.0 * n
    return words + 2 * 4.0 * peers * n + 2 * 4.0 * peers * n + 4.0 * n


def encode_hbm_bytes(cfg: CompressorConfig, n, fused: bool, *, ef: bool = True,
                     adaptive: bool = True, bits=None) -> float:
    """HBM bytes one device moves to turn an n-element gradient bucket into
    wire words + next EF residual (the encode half of every sync mode).

    The model covers the encode pipeline from corrected-gradient formation
    through residual write-back; the leaf→bucket coalescing copy is
    identical in both layouts and excluded from both.

    - unfused (the pre-fusion path): the leaf-wise EF add (read g, read e,
      write corrected), a telemetry statistics sweep (``adaptive``), the
      ``plan()`` statistics pass — a subsample gather plus an
      O(s·log s) sort for the exact quantile (``cfg.approx_gmin`` swaps the
      sort for ~2 extra histogram passes over the sample), the encode (read
      corrected, write uint8 codes), a separate ``pack_codes`` pass (read
      codes, write words), the own-dequantization (read codes, write fp32
      owns), the ``corrected − owns`` residual (read both, write), the
      ``bucket_split`` of the residual back to leaf layout, and the
      leaf-pytree EF restack/constraint round-trip on the next step;
    - fused (``kernels.encode_fused``): ``ef_correct_stats`` reads g and e
      once and writes the corrected bucket (statistics stay in VMEM — the
      telemetry sweep and the whole ``plan()`` pass disappear into it), and
      ``encode_pack_residual`` reads the corrected bucket and writes the
      wire words + the bucket-resident residual.  Codes and owns never
      reach HBM, and the EF state needs no split/restack.

    ``ef=False`` drops the correction/residual terms on both sides (the
    fused side still pays the full-bucket stats read that replaces the
    subsampled sort — better statistics for strictly fewer bytes only once
    the EF/telemetry sweeps are in play).  ``n``/``bits`` may be per-bucket
    sequences (the heterogeneous adaptive wire); the cost sums.
    """
    if isinstance(n, list | tuple):
        bl = bits if isinstance(bits, list | tuple) and not _plan_entry(bits) \
            else [bits] * len(n)
        if len(bl) != len(n):
            raise ValueError(f"{len(bl)} bit-widths vs {len(n)} buckets")
        return sum(encode_hbm_bytes(cfg, nb, fused, ef=ef, adaptive=adaptive, bits=b)
                   for nb, b in zip(n, bl))
    from math import ceil, log2

    from repro.core.codecs import get_codec

    bcfg = _bucket_cfg(cfg, bits)
    if bcfg.method not in METHODS:
        # Rank-based encode: EF-correct sweep, two power-iteration matmul
        # reads of the bucket, the factor-pair wire write, the own
        # reconstruction, and the residual write-back.  The factorization
        # is one jitted graph either way, so fused == unfused here.
        words = 4.0 * get_codec(bcfg.method).wire_words(bcfg, n)
        total = 4.0 * n                      # stats/correct: read g
        if ef:
            total += 8.0 * n                 # ... read e, write corrected
        total += 2 * 4.0 * n + words         # M@Q and M.T@P reads + wire
        total += 4.0 * n                     # own P@Q.T reconstruction
        if ef:
            total += 4.0 * n                 # residual write-back
        return total
    # packed code words only: the codebook rides the registry wire row
    # (wire_words = packed + s + 1) but is written straight from VMEM
    words = 4.0 * (get_codec(bcfg.method).wire_words(bcfg, n) - (bcfg.s + 1))
    if fused:
        total = 4.0 * n                      # ef_correct_stats: read g
        if ef:
            total += 8.0 * n                 # ... read e, write corrected
        total += 4.0 * n + words             # encode_pack: read corrected, write wire
        if ef:
            total += 4.0 * n                 # ... write bucket-resident residual
        return total
    s = min(n, cfg.plan_sample) if cfg.plan_sample else n
    plan_pass = 4.0 * s * 3 if cfg.approx_gmin \
        else 4.0 * s * (1 + 2 * max(ceil(log2(max(s, 2))), 1))  # gather + hists/sort
    total = plan_pass + 4.0 * n + 1.0 * n + 1.0 * n + words   # encode + pack passes
    if adaptive:
        total += 4.0 * n                     # standalone telemetry stats sweep
    if ef:
        total += 12.0 * n                    # leaf-wise EF add: read g, read e, write c
        total += 1.0 * n + 4.0 * n           # own-decode: read codes, write owns
        total += 12.0 * n                    # residual: read c + owns, write resid
        total += 8.0 * n                     # bucket_split of the residual
        total += 8.0 * n                     # leaf EF restack/constraint round-trip
    return total
