"""Analytic wire accounting for the gradient-sync collectives.

``wire_bytes_per_device(cfg, n, shards, mode)`` returns the bytes one device
puts on the wire **per hop of a bandwidth-optimal ring schedule** for one
n-element gradient sync over ``shards`` devices.  Per-hop payload is the
right unit for roofline math: a ring schedule runs ``O(shards)`` hops
back-to-back, so step latency on the interconnect is ``hops × per-hop
bytes / link bandwidth``, and the per-hop payload is what each link carries
at any instant.

- ``dsgd``          — fp32 ring all-reduce: each hop moves one fp32 chunk,
                      ``4 · n / shards`` bytes (reduce-scatter and all-gather
                      phases have identical per-hop cost);
- ``two_phase``     — both phases move one *quantized* chunk + its codebook:
                      ``wire_bytes(cfg, ceil(n/shards))``;
- ``faithful``      — chunk-pipelined ring all-gather of each peer's full
                      quantized tensor: ``wire_bytes(cfg, n) / shards`` per
                      hop (codebooks amortized over the ring);
- ``hierarchical``  — two-phase inside the pod plus the cross-pod faithful
                      exchange of the pod mean amortized over pod members.

The compression ratio vs fp32 is therefore ~``32 / bits`` for two_phase and
faithful — independent of ``shards`` — matching the paper's wire model.

Elastic accounting: every function accepts ``live`` (the number of peers
actually contributing gradients this step).  ``wire_bytes_per_device``
scales pro-rata by ``live / shards`` — dead peers ship a zeroed wire row
whose bytes never traverse their links, and the two-phase relay is
attributed to the contributions it carries; ``decode_hbm_bytes`` decodes
``live`` meaningful rows; ``encode_hbm_bytes`` is *unchanged* by ``live``
(the straggler contract: every peer's encode runs even when its wire is
masked, so the encode-side HBM traffic is paid regardless).
"""
from __future__ import annotations

from repro.core.compressors import METHODS, CompressorConfig

MODES = ("dsgd", "two_phase", "hierarchical", "faithful")


def _check_live(live, shards: int) -> None:
    if live is not None and not 1 <= live <= shards:
        raise ValueError(f"live peer count {live} outside [1, {shards}]")


def _plan_entry(bits):
    """True for a ``("method", value)`` plan entry or a full config."""
    if isinstance(bits, CompressorConfig):
        return True
    return (isinstance(bits, tuple | list) and len(bits) == 2
            and isinstance(bits[0], str))


def _bucket_cfg(cfg: CompressorConfig, bits) -> CompressorConfig:
    """Resolve a per-bucket plan entry (int bits / method tuple / config)
    against the base config; plain ``None``/int keep the quantizer path."""
    from repro.core.codecs import bucket_cfg_entry

    return cfg if bits is None else bucket_cfg_entry(cfg, bits)


def wire_bytes_per_device(cfg: CompressorConfig, n, shards: int, mode: str, bits=None,
                          live: int | None = None) -> float:
    """Per-device, per-hop wire bytes for one n-element gradient sync.

    ``n`` may be a sequence of per-bucket sizes with a matching sequence of
    per-bucket ``bits`` entries — plain bit widths or ``("method", value)``
    codec-plan entries (the adaptive fused wire format); the cost is then
    the sum over buckets, each chunked per the mode.  Codecs without
    chunk-aligned wires (rank-based factor pairs) put an indivisible wire
    on the all-to-all rows, so their two-phase cost is the full wire.

    ``live`` (elastic): with ``k`` of ``shards`` peers contributing, each
    link carries ``k/shards`` of the full-participation payload — dead
    peers' zeroed rows never leave their HBM, and the two-phase phase-2
    relay (structural, always on) is attributed pro-rata to the live
    contributions it forwards.
    """
    if isinstance(n, list | tuple):
        bl = bits if isinstance(bits, list | tuple) and not _plan_entry(bits) \
            else [bits] * len(n)
        if len(bl) != len(n):
            raise ValueError(f"{len(bl)} bit-widths vs {len(n)} buckets")
        return sum(wire_bytes_per_device(cfg, nb, shards, mode, b, live)
                   for nb, b in zip(n, bl))
    if mode not in MODES:
        raise ValueError(f"unknown sync mode {mode!r}; expected one of {MODES}")
    if shards < 1:
        raise ValueError("shards must be >= 1")
    _check_live(live, shards)
    frac = 1.0 if live is None else live / shards
    if mode == "dsgd" or cfg.method == "dsgd":
        return frac * 4.0 * n / shards
    bcfg = _bucket_cfg(cfg, bits)
    from repro.core.codecs import get_codec

    codec = get_codec(bcfg.method)
    if not codec.chunkable:
        full = float(codec.wire_bytes(bcfg, n))
        if mode == "two_phase":
            base = full          # full wire tiled into every all-to-all row
        elif mode == "faithful":
            base = full / shards
        else:
            base = full + full / shards
        return frac * base
    # analytic ceil chunk (the codec's actual chunk_elems pads a little
    # further for pack alignment; the model ignores that sub-percent slack)
    chunk = -(-n // shards)
    if mode == "two_phase":
        return frac * float(codec.wire_bytes(bcfg, chunk))
    if mode == "faithful":
        return frac * codec.wire_bytes(bcfg, n) / shards
    # hierarchical: intra-pod two-phase chunk + the pod-mean faithful
    # exchange across pods, spread over the pod's members.
    return frac * (float(codec.wire_bytes(bcfg, chunk))
                   + codec.wire_bytes(bcfg, n) / shards)


def decode_hbm_bytes(cfg: CompressorConfig, n, peers: int, fused: bool, bits=None,
                     live: int | None = None) -> float:
    """HBM bytes one device moves to decode + average ``peers`` gathered
    n-element wire rows (the decode half of ``faithful`` / the reduce side of
    ``two_phase``).

    - unfused (the pre-fusion ``vmap(unpack_codes)`` → ``take`` → ``mean``
      path): reads the packed words, then writes *and re-reads* the
      (peers, n) int32 unpacked code tensor and the (peers, n) fp32
      dequantized tensor before reducing to the (n,) output;
    - fused (``kernels.decode``): reads the packed words once and writes the
      (n,) mean once — codes and values never leave VMEM.

    Both include the per-peer codebook reads.  ``n``/``bits`` may be
    per-bucket sequences (the adaptive fused wire format); the cost sums.
    ``live`` (elastic) overrides the row multiplier: only ``live`` of the
    gathered rows carry meaningful payload, so
    ``decode_hbm_bytes(cfg, n, peers, fused, live=k) ==
    decode_hbm_bytes(cfg, n, k, fused)``.
    """
    _check_live(live, peers)
    if live is not None:
        peers = live
    if isinstance(n, list | tuple):
        bl = bits if isinstance(bits, list | tuple) and not _plan_entry(bits) \
            else [bits] * len(n)
        if len(bl) != len(n):
            raise ValueError(f"{len(bl)} bit-widths vs {len(n)} buckets")
        return sum(decode_hbm_bytes(cfg, nb, peers, fused, b) for nb, b in zip(n, bl))
    from repro.core.codecs import get_codec

    bcfg = _bucket_cfg(cfg, bits)
    # The registry is the single source of truth for wire geometry: one
    # (wire_words,) uint32 row per peer — packed codes + bitcast codebook
    # for the quantizers, the bitcast factor pair for rank-based codecs,
    # packed half words for the fp16 tier (cross-checked against the traced
    # collective operands in ``tests/test_analysis.py``).
    words = 4.0 * peers * get_codec(bcfg.method).wire_words(bcfg, n)
    if bcfg.method not in METHODS:
        # Registry codecs without an unpack-codes pass (rank-based factor
        # reconstruction, the fp16 bitcast): read every peer's row,
        # materialize the per-peer (n,) values (fused keeps them in VMEM;
        # unfused writes + re-reads them before the mean).
        if fused:
            return words + 4.0 * n
        return words + 2 * 4.0 * peers * n + 4.0 * n
    if fused:
        return words + 4.0 * n
    return words + 2 * 4.0 * peers * n + 2 * 4.0 * peers * n + 4.0 * n


def encode_hbm_bytes(cfg: CompressorConfig, n, fused: bool, *, ef: bool = True,
                     adaptive: bool = True, bits=None,
                     live: int | None = None) -> float:
    """HBM bytes one device moves to turn an n-element gradient bucket into
    wire words + next EF residual (the encode half of every sync mode).

    The model covers the encode pipeline from corrected-gradient formation
    through residual write-back; the leaf→bucket coalescing copy is
    identical in both layouts and excluded from both.

    - unfused (the pre-fusion path): the leaf-wise EF add (read g, read e,
      write corrected), a telemetry statistics sweep (``adaptive``), the
      ``plan()`` statistics pass — a subsample gather plus an
      O(s·log s) sort for the exact quantile (``cfg.approx_gmin`` swaps the
      sort for ~2 extra histogram passes over the sample), the encode (read
      corrected, write uint8 codes), a separate ``pack_codes`` pass (read
      codes, write words), the own-dequantization (read codes, write fp32
      owns), the ``corrected − owns`` residual (read both, write), the
      ``bucket_split`` of the residual back to leaf layout, and the
      leaf-pytree EF restack/constraint round-trip on the next step;
    - fused (``kernels.encode_fused``): ``ef_correct_stats`` reads g and e
      once and writes the corrected bucket (statistics stay in VMEM — the
      telemetry sweep and the whole ``plan()`` pass disappear into it), and
      ``encode_pack_residual`` reads the corrected bucket and writes the
      wire words + the bucket-resident residual.  Codes and owns never
      reach HBM, and the EF state needs no split/restack.

    ``ef=False`` drops the correction/residual terms on both sides (the
    fused side still pays the full-bucket stats read that replaces the
    subsampled sort — better statistics for strictly fewer bytes only once
    the EF/telemetry sweeps are in play).  ``n``/``bits`` may be per-bucket
    sequences (the heterogeneous adaptive wire); the cost sums.

    ``live`` is accepted for signature symmetry but **does not change the
    cost**: the elastic straggler contract keeps every peer's encode
    running (masking happens on the wire tensor afterwards), so the
    encode-side HBM traffic is paid whether or not the peer is live.
    """
    del live  # encode always runs — see the docstring
    if isinstance(n, list | tuple):
        bl = bits if isinstance(bits, list | tuple) and not _plan_entry(bits) \
            else [bits] * len(n)
        if len(bl) != len(n):
            raise ValueError(f"{len(bl)} bit-widths vs {len(n)} buckets")
        return sum(encode_hbm_bytes(cfg, nb, fused, ef=ef, adaptive=adaptive, bits=b)
                   for nb, b in zip(n, bl))
    from math import ceil, log2

    from repro.core.codecs import get_codec

    bcfg = _bucket_cfg(cfg, bits)
    codec = get_codec(bcfg.method)
    if bcfg.method not in METHODS and codec.chunkable:
        # Plan-less passthrough (fp16): one cast+pack sweep — read g
        # (+ the EF read/write when on), write the packed half words,
        # write the cast residual.  One jitted graph, fused == unfused.
        words = 4.0 * codec.wire_words(bcfg, n)
        total = 4.0 * n
        if ef:
            total += 8.0 * n
        total += words
        if ef:
            total += 4.0 * n
        return total
    if bcfg.method not in METHODS:
        # Rank-based encode: EF-correct sweep, two power-iteration matmul
        # reads of the bucket, the factor-pair wire write, the own
        # reconstruction, and the residual write-back.  The factorization
        # is one jitted graph either way, so fused == unfused here.
        words = 4.0 * codec.wire_words(bcfg, n)
        total = 4.0 * n                      # stats/correct: read g
        if ef:
            total += 8.0 * n                 # ... read e, write corrected
        total += 2 * 4.0 * n + words         # M@Q and M.T@P reads + wire
        total += 4.0 * n                     # own P@Q.T reconstruction
        if ef:
            total += 4.0 * n                 # residual write-back
        return total
    # packed code words only: the codebook rides the registry wire row
    # (wire_words = packed + s + 1) but is written straight from VMEM
    words = 4.0 * (get_codec(bcfg.method).wire_words(bcfg, n) - (bcfg.s + 1))
    if fused:
        total = 4.0 * n                      # ef_correct_stats: read g
        if ef:
            total += 8.0 * n                 # ... read e, write corrected
        total += 4.0 * n + words             # encode_pack: read corrected, write wire
        if ef:
            total += 4.0 * n                 # ... write bucket-resident residual
        return total
    s = min(n, cfg.plan_sample) if cfg.plan_sample else n
    plan_pass = 4.0 * s * 3 if cfg.approx_gmin \
        else 4.0 * s * (1 + 2 * max(ceil(log2(max(s, 2))), 1))  # gather + hists/sort
    total = plan_pass + 4.0 * n + 1.0 * n + 1.0 * n + words   # encode + pack passes
    if adaptive:
        total += 4.0 * n                     # standalone telemetry stats sweep
    if ef:
        total += 12.0 * n                    # leaf-wise EF add: read g, read e, write c
        total += 1.0 * n + 4.0 * n           # own-decode: read codes, write owns
        total += 12.0 * n                    # residual: read c + owns, write resid
        total += 8.0 * n                     # bucket_split of the residual
        total += 8.0 * n                     # leaf EF restack/constraint round-trip
    return total
