"""Analytic wire accounting for the gradient-sync collectives.

``wire_bytes_per_device(cfg, n, shards, mode)`` returns the bytes one device
puts on the wire **per hop of a bandwidth-optimal ring schedule** for one
n-element gradient sync over ``shards`` devices.  Per-hop payload is the
right unit for roofline math: a ring schedule runs ``O(shards)`` hops
back-to-back, so step latency on the interconnect is ``hops × per-hop
bytes / link bandwidth``, and the per-hop payload is what each link carries
at any instant.

- ``dsgd``          — fp32 ring all-reduce: each hop moves one fp32 chunk,
                      ``4 · n / shards`` bytes (reduce-scatter and all-gather
                      phases have identical per-hop cost);
- ``two_phase``     — both phases move one *quantized* chunk + its codebook:
                      ``wire_bytes(cfg, ceil(n/shards))``;
- ``faithful``      — chunk-pipelined ring all-gather of each peer's full
                      quantized tensor: ``wire_bytes(cfg, n) / shards`` per
                      hop (codebooks amortized over the ring);
- ``hierarchical``  — two-phase inside the pod plus the cross-pod faithful
                      exchange of the pod mean amortized over pod members.

The compression ratio vs fp32 is therefore ~``32 / bits`` for two_phase and
faithful — independent of ``shards`` — matching the paper's wire model.
"""
from __future__ import annotations

from repro.core.compressors import CompressorConfig, wire_bytes

MODES = ("dsgd", "two_phase", "hierarchical", "faithful")


def wire_bytes_per_device(cfg: CompressorConfig, n, shards: int, mode: str, bits=None) -> float:
    """Per-device, per-hop wire bytes for one n-element gradient sync.

    ``n`` may be a sequence of per-bucket sizes with a matching sequence of
    per-bucket ``bits`` (the adaptive fused wire format); the cost is then
    the sum over buckets, each chunked per the mode.
    """
    if isinstance(n, (list, tuple)):
        bl = bits if isinstance(bits, (list, tuple)) else [bits] * len(n)
        if len(bl) != len(n):
            raise ValueError(f"{len(bl)} bit-widths vs {len(n)} buckets")
        return sum(wire_bytes_per_device(cfg, nb, shards, mode, b) for nb, b in zip(n, bl))
    if mode not in MODES:
        raise ValueError(f"unknown sync mode {mode!r}; expected one of {MODES}")
    if shards < 1:
        raise ValueError("shards must be >= 1")
    if mode == "dsgd" or cfg.method == "dsgd":
        return 4.0 * n / shards
    chunk = -(-n // shards)
    if mode == "two_phase":
        return float(wire_bytes(cfg, chunk, bits))
    if mode == "faithful":
        return wire_bytes(cfg, n, bits) / shards
    # hierarchical: intra-pod two-phase chunk + the pod-mean faithful
    # exchange across pods, spread over the pod's members.
    return float(wire_bytes(cfg, chunk, bits)) + wire_bytes(cfg, n, bits) / shards
