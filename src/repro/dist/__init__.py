"""Distributed runtime: sharding rules, compressed gradient sync, serving.

Modules:

- :mod:`repro.dist.compat`        — JAX version shims + manual-region collective helpers
- :mod:`repro.dist.sharding`      — logical-axis annotations and logical→mesh rules
- :mod:`repro.dist.sharded_codec` — quantized reduce-scatter / ring-mean wire codecs
- :mod:`repro.dist.train_step`    — jitted shard_map train step (dsgd / two_phase /
  hierarchical / faithful sync, optional layer-streamed backward)
- :mod:`repro.dist.serve_step`    — sharded prefill + decode entry points
- :mod:`repro.dist.collectives`   — analytic per-device wire accounting

``train_step`` and ``serve_step`` import the model zoo (which itself uses
:func:`repro.dist.sharding.shard`), so they are exposed lazily to keep the
``models ⇄ dist`` import cycle one-directional at package-init time.
"""
from . import compat  # noqa: F401  (must import first: installs jax shims)
from . import collectives, sharded_codec, sharding
from .collectives import wire_bytes_per_device
from .sharding import shard

_LAZY = {
    "train_step": ("repro.dist.train_step", None),
    "serve_step": ("repro.dist.serve_step", None),
    "make_train_step": ("repro.dist.train_step", "make_train_step"),
    "TrainStepConfig": ("repro.dist.train_step", "TrainStepConfig"),
    "SYNC_MODES": ("repro.dist.train_step", "SYNC_MODES"),
    "make_serve_fns": ("repro.dist.serve_step", "make_serve_fns"),
}

__all__ = [
    "collectives",
    "compat",
    "shard",
    "sharded_codec",
    "sharding",
    "wire_bytes_per_device",
    *sorted(_LAZY),
]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        module_name, attr = _LAZY[name]
        module = importlib.import_module(module_name)
        return module if attr is None else getattr(module, attr)
    raise AttributeError(f"module 'repro.dist' has no attribute {name!r}")
