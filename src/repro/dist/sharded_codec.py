"""Sharded wire codec: quantized gradient collectives inside shard_map.

These are the per-device bodies of the compressed synchronization modes
(paper Alg. 1 deployed as collectives).  Every function runs *inside* a
shard_map region and sees only the local shard of the gradient:

- :func:`two_phase_reduce_scatter_sharded` — phase 1 of two-phase sync:
  quantize each of the n peer-chunks of the local tensor, exchange codes
  all-to-all, dequantize and average.  Each peer ends with its own chunk of
  the mean (a compressed reduce-scatter).
- :func:`two_phase_mean` — both phases: reduce-scatter, then re-quantize the
  mean chunk and all-gather it back (each direction ships ~bits/32 of the
  fp32 payload).
- :func:`faithful_ring_mean` — the Error-Compensated-QSGD-style worker
  exchange: each peer's *full* tensor is quantized exactly once, every peer
  decodes the same n codewords, so all peers agree bit-for-bit on the mean
  and the per-peer quantizers stay unbiased.
- :func:`pack_dim` / :func:`unpack_dim` — the uint32 wire format of
  ``core.quantizers`` applied along an arbitrary axis, so code tensors can be
  exchanged without first flattening away the peer axis.

Per-chunk codebooks ride along with the codes as (levels, alpha) pairs —
``wire_bytes`` in ``core.compressors`` accounts for them.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.compressors import CompressorConfig, plan
from repro.core.quantizers import QuantMeta, pack_codes, stochastic_encode, unpack_codes

from . import compat


# ---------------------------------------------------------------------------
# Bit packing along an arbitrary axis
# ---------------------------------------------------------------------------


def pack_dim(codes: jax.Array, dim: int, bits: int) -> jax.Array:
    """Bit-pack uint8 codes into uint32 words along axis ``dim``.

    Shape change: ``n -> ceil(n/32) * bits`` on that axis; all other axes
    are preserved (the packing is independent per lane).
    """
    moved = jnp.moveaxis(codes, dim, -1)
    lead = moved.shape[:-1]
    flat = moved.reshape(-1, moved.shape[-1])
    words = jax.vmap(lambda row: pack_codes(row, bits))(flat)
    return jnp.moveaxis(words.reshape(lead + (words.shape[-1],)), -1, dim)


def unpack_dim(words: jax.Array, dim: int, bits: int, n: Optional[int] = None) -> jax.Array:
    """Inverse of :func:`pack_dim`; ``n`` recovers a non-multiple-of-32 axis."""
    moved = jnp.moveaxis(words, dim, -1)
    lead = moved.shape[:-1]
    if n is None:
        n = (moved.shape[-1] // bits) * 32
    flat = moved.reshape(-1, moved.shape[-1])
    codes = jax.vmap(lambda row: unpack_codes(row, n, bits))(flat)
    return jnp.moveaxis(codes.reshape(lead + (n,)), -1, dim)


# ---------------------------------------------------------------------------
# Local encode/decode helpers (flat fp32 <-> packed words + codebook)
# ---------------------------------------------------------------------------


def _encode_flat(cfg: CompressorConfig, flat: jax.Array, meta: QuantMeta, key: jax.Array,
                 use_pallas: bool) -> jax.Array:
    """Flat fp32 -> uint8 codes, via the Pallas fast path when requested."""
    if use_pallas and cfg.method in ("qsgd", "tqsgd", "dsgd"):
        from repro.kernels import ops as kops

        return kops.uniform_encode(flat, meta.alpha, cfg.bits, key)
    if use_pallas:
        from repro.kernels import ops as kops

        return kops.codebook_encode(flat, meta.levels, key)
    return stochastic_encode(flat, meta, key)


def _decode_rows(words: jax.Array, levels: jax.Array, n: int, bits: int) -> jax.Array:
    """(peers, packed_words) + (peers, s+1) codebooks -> (peers, n) fp32."""
    codes = jax.vmap(lambda w: unpack_codes(w, n, bits))(words)
    return jax.vmap(lambda c, lv: jnp.take(lv, c.astype(jnp.int32)))(codes, levels)


def _plan_encode_rows(cfg: CompressorConfig, rows: jax.Array, key: jax.Array,
                      use_pallas: bool) -> tuple[jax.Array, QuantMeta]:
    """Per-row plan + encode + pack.  rows: (k, m) fp32 -> ((k, words), metas)."""
    k = rows.shape[0]
    metas = jax.vmap(lambda r: plan(cfg, r))(rows)
    keys = jax.random.split(key, k)
    codes = jax.vmap(lambda r, m_lv, m_a, kk: _encode_flat(
        cfg, r, QuantMeta(levels=m_lv, alpha=m_a), kk, use_pallas))(
        rows, metas.levels, metas.alpha, keys)
    return pack_dim(codes, 1, cfg.bits), metas


# ---------------------------------------------------------------------------
# Collective codecs
# ---------------------------------------------------------------------------


def two_phase_reduce_scatter_sharded(
    cfg: CompressorConfig,
    g: jax.Array,
    dim: int,
    axis_name,
    key: jax.Array,
    use_pallas: bool = False,
) -> jax.Array:
    """Compressed reduce-scatter: returns this peer's chunk of the peer-mean.

    The local tensor is split into n equal chunks along ``dim`` (n = size of
    ``axis_name``); chunk j is quantized with its own codebook and shipped to
    peer j; each peer dequantizes the n received codewords and averages.

"""
    n = compat.axis_size(axis_name)
    if n == 1:
        return g
    if g.shape[dim] % n:
        raise ValueError(f"dim {dim} of shape {g.shape} not divisible by axis size {n}")

    chunk_shape = g.shape[:dim] + (g.shape[dim] // n,) + g.shape[dim + 1:]
    parts = jnp.moveaxis(g, dim, 0).reshape(n, g.shape[dim] // n, -1)
    flat = parts.reshape(n, -1).astype(jnp.float32)                  # (n, m)
    m = flat.shape[1]

    words, metas = _plan_encode_rows(cfg, flat, key, use_pallas)
    recv_words = compat.all_to_all_rows(words, axis_name)            # (n, w)
    recv_levels = compat.all_to_all_rows(metas.levels, axis_name)
    mean_flat = jnp.mean(_decode_rows(recv_words, recv_levels, m, cfg.bits), axis=0)
    return jnp.moveaxis(mean_flat.reshape((chunk_shape[dim],) + g.shape[:dim] + g.shape[dim + 1:]),
                        0, dim)


def two_phase_mean(
    cfg: CompressorConfig,
    g: jax.Array,
    axis_name,
    key: jax.Array,
    use_pallas: bool = False,
) -> jax.Array:
    """Full two-phase compressed mean: reduce-scatter then all-gather.

    Both phases move quantized chunks, so per-device wire cost is
    ~2 · bits/32 of the fp32 all-reduce (see ``collectives.wire_bytes_per_device``).
    """
    n = compat.axis_size(axis_name)
    if n == 1:
        return g
    k1, k2 = jax.random.split(key)

    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % n
    padded = jnp.pad(flat, (0, pad))
    chunk = two_phase_reduce_scatter_sharded(cfg, padded, 0, axis_name, k1, use_pallas)

    # Phase 2: broadcast this peer's mean chunk, freshly quantized.
    meta2 = plan(cfg, chunk)
    codes2 = _encode_flat(cfg, chunk, meta2, k2, use_pallas)
    words2 = pack_codes(codes2, cfg.bits)
    all_words = compat.all_gather_stacked(words2, axis_name)             # (n, w)
    all_levels = compat.all_gather_stacked(meta2.levels, axis_name)
    full = _decode_rows(all_words, all_levels, chunk.size, cfg.bits).reshape(-1)
    return full[: flat.size].reshape(g.shape).astype(g.dtype)


def faithful_ring_mean(
    cfg: CompressorConfig,
    g: jax.Array,
    axis_name,
    key: jax.Array,
    use_pallas: bool = False,
) -> jax.Array:
    """Unbiased ring mean: each peer's full tensor is quantized exactly once.

    All peers decode the same n codewords, so the result is bitwise identical
    everywhere and E[result] is the true mean of the peers' local tensors
    (the quantizer is unbiased per element, per peer).
    """
    n = compat.axis_size(axis_name)
    flat = g.reshape(-1).astype(jnp.float32)
    meta = plan(cfg, flat)
    codes = _encode_flat(cfg, flat, meta, key, use_pallas)
    if n == 1:
        return jnp.take(meta.levels, codes.astype(jnp.int32)).reshape(g.shape).astype(g.dtype)
    words = pack_codes(codes, cfg.bits)
    all_words = compat.all_gather_stacked(words, axis_name)              # (n, w)
    all_levels = compat.all_gather_stacked(meta.levels, axis_name)
    vals = _decode_rows(all_words, all_levels, flat.size, cfg.bits)      # (n, m)
    return jnp.mean(vals, axis=0).reshape(g.shape).astype(g.dtype)
