"""Sharded wire codec: quantized gradient collectives inside shard_map.

These are the per-device bodies of the compressed synchronization modes
(paper Alg. 1 deployed as collectives).  Every function runs *inside* a
shard_map region and sees only the local shard of the gradient:

- :func:`two_phase_reduce_scatter_sharded` — phase 1 of two-phase sync:
  quantize each of the n peer-chunks of the local tensor, exchange codes
  all-to-all, dequantize and average.  Each peer ends with its own chunk of
  the mean (a compressed reduce-scatter).
- :func:`two_phase_mean` — both phases: reduce-scatter, then re-quantize the
  mean chunk and all-gather it back (each direction ships ~bits/32 of the
  fp32 payload).
- :func:`faithful_ring_mean` — the Error-Compensated-QSGD-style worker
  exchange: each peer's *full* tensor is quantized exactly once, every peer
  decodes the same n codewords, so all peers agree bit-for-bit on the mean
  and the per-peer quantizers stay unbiased.
- :func:`pack_dim` / :func:`unpack_dim` — the uint32 wire format of
  ``core.quantizers`` applied along an arbitrary axis, so code tensors can be
  exchanged without first flattening away the peer axis.

The bucketed fast path (:func:`bucketed_two_phase_mean`,
:func:`bucketed_faithful_ring_mean`, :func:`bucketed_hierarchical_mean`)
takes a *list* of coalesced fp32 buckets (``core.compressors.plan_buckets``),
plans one codebook per bucket, and fuses every bucket's packed codes and
bitcast codebook into a single wire tensor so each phase issues exactly one
collective regardless of bucket or leaf count.  An optional per-bucket
``bits`` plan (``repro.adaptive``) gives each bucket its own static wire
width inside the same fused tensor — offsets stay trace-time static, and the
collective count does not change.  Each function also returns the peer's own
per-bucket **EF residual** ``corrected − C(corrected)``, produced inside the
fused encode.

Per-chunk codebooks ride along with the codes as (levels, alpha) pairs —
``wire_bytes`` in ``core.compressors`` accounts for them.

Encode side: the bucketed paths plan from precomputed one-pass statistics
(``compressors.plan_from_stats`` over the histogram/Hill-sum tuples the
train step's fused EF-correct→stats pass hands in via ``stats=``; computed
inline for secondary stages like the two-phase phase-2 re-quantization) —
the sort-based ``plan`` stays only on the per-leaf legacy codec.  All
encodes route through :func:`encode_pack` / :func:`encode_pack_residual`,
a kernel/jnp dispatch mirroring the decode side: ``use_pallas`` selects the
``kernels.encode_fused`` Pallas kernels (quantize → bit-pack → residual in
one VMEM pass; codes and the dequantized ``own`` tensor never reach HBM),
otherwise the key-compatible sequential oracles in ``kernels.ref`` run the
same op sequence (bit-identical wire words; the uniform residual's dequant
multiply-add keeps ulp-level FMA slack) and stay shard_map-safe on the
pinned toolchain.

Decode side: every decode site routes through :func:`decode_reduce` /
:func:`decode_rows` — fused unpack → dequant → (mean) passes over the
gathered wire rows (``kernels.decode`` Pallas kernels under ``use_pallas``,
the bit-identical ``kernels.ref`` sequential-peer jnp oracle otherwise) that
never materialize the (n_peers, m) unpacked code tensor the old
``vmap(unpack_codes)`` + ``jnp.mean`` path staged in HBM.

Peer RNG independence: every encode folds ``compat.flat_axis_index`` of the
collective's own axes into the key.  The paper's Lemma 2 (mean error
concentrating as 1/n across workers) assumes independent stochastic rounding
per peer; a verbatim replicated key correlates the draws and the mean never
concentrates (``tests/test_rng_independence.py`` pins this).  Folding the
index of the *collective's* axes only — not every mesh axis — keeps the
hierarchical mode's replication invariant: peers that must produce identical
bytes (same pod, different data rank in the cross-pod exchange) still share
a stream.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.compressors import CompressorConfig, plan, plan_from_stats
from repro.core.quantizers import (
    QuantMeta,
    pack_codes,
    packed_size,
    unpack_codes,
)

from . import compat


# ---------------------------------------------------------------------------
# Bit packing along an arbitrary axis
# ---------------------------------------------------------------------------


def pack_dim(codes: jax.Array, dim: int, bits: int) -> jax.Array:
    """Bit-pack uint8 codes into uint32 words along axis ``dim``.

    Shape change: ``n -> ceil(n/32) * bits`` on that axis; all other axes
    are preserved (the packing is independent per lane).
    """
    moved = jnp.moveaxis(codes, dim, -1)
    lead = moved.shape[:-1]
    flat = moved.reshape(-1, moved.shape[-1])
    words = jax.vmap(lambda row: pack_codes(row, bits))(flat)
    return jnp.moveaxis(words.reshape(lead + (words.shape[-1],)), -1, dim)


def unpack_dim(words: jax.Array, dim: int, bits: int, n: Optional[int] = None) -> jax.Array:
    """Inverse of :func:`pack_dim`; ``n`` recovers a non-multiple-of-32 axis."""
    moved = jnp.moveaxis(words, dim, -1)
    lead = moved.shape[:-1]
    if n is None:
        n = (moved.shape[-1] // bits) * 32
    flat = moved.reshape(-1, moved.shape[-1])
    codes = jax.vmap(lambda row: unpack_codes(row, n, bits))(flat)
    return jnp.moveaxis(codes.reshape(lead + (n,)), -1, dim)


# ---------------------------------------------------------------------------
# Local encode/decode helpers (flat fp32 <-> packed words + codebook)
# ---------------------------------------------------------------------------


def _peer_key(key: jax.Array, axis_name) -> jax.Array:
    """Decorrelate the replicated step key across the peers of a collective.

    Inside the fully-manual shard_map every peer receives the same key; the
    quantizer's unbiasedness across peers needs independent uniforms, so the
    peer's linear index over the collective's axes is folded in.
    """
    return jax.random.fold_in(key, compat.flat_axis_index(axis_name))


# Methods whose codebook is the uniform linspace: the fused kernels encode/
# dequantize them straight from α (code · 2α/s − α) instead of a table walk.
_UNIFORM_DECODE = ("qsgd", "tqsgd", "dsgd")


def _encode_dispatch(cfg: CompressorConfig, op: str, flat: jax.Array, meta: QuantMeta,
                     key: jax.Array, use_pallas: bool):
    """Kernel/jnp dispatch for the fused encode ops (mirror of
    ``_decode_dispatch``): ``use_pallas`` selects ``kernels.encode_fused``
    via the ``kernels.ops`` wrappers, else the key-compatible sequential
    oracles in ``kernels.ref`` (shard_map-safe, bit-identical words)."""
    if use_pallas:
        from repro.kernels import ops as mod
    else:
        from repro.kernels import ref as mod
    if cfg.method in _UNIFORM_DECODE:
        return getattr(mod, f"uniform_{op}")(flat, meta.alpha, cfg.bits, key)
    return getattr(mod, f"codebook_{op}")(flat, meta.levels, cfg.bits, key)


def encode_pack(cfg: CompressorConfig, flat: jax.Array, meta: QuantMeta, key: jax.Array,
                use_pallas: bool) -> jax.Array:
    """Flat fp32 -> packed uint32 wire words in one fused pass (no codes,
    no residual reach HBM)."""
    return _encode_dispatch(cfg, "encode_pack", flat, meta, key, use_pallas)


def encode_pack_residual(cfg: CompressorConfig, flat: jax.Array, meta: QuantMeta,
                         key: jax.Array, use_pallas: bool) -> tuple[jax.Array, jax.Array]:
    """Flat fp32 -> (uint32 wire words, ``flat − dequant(code)`` residual).

    The fused EF encode: the residual is written in the same pass as the
    pack, so the unpacked codes and the dequantized ``own`` tensor never
    leave VMEM on the kernel path.  Exact for codebook methods
    (``levels[code]`` is the interval endpoint the rounding chose); the
    uniform dequant keeps ulp-level FMA slack.
    """
    return _encode_dispatch(cfg, "encode_pack_residual", flat, meta, key, use_pallas)


def decode_reduce(cfg: CompressorConfig, words: jax.Array, levels: jax.Array, n: int,
                  use_pallas: bool) -> jax.Array:
    """Fused unpack → dequant → peer mean of gathered codec rows.

    ``words``: (peers, packed_words) uint32 wire rows; ``levels``: (peers,
    s+1) codebooks; returns the (n,) fp32 mean over peers, never
    materializing the (peers, n) unpacked tensor.  ``use_pallas`` selects the
    ``kernels.decode`` Pallas kernels (interpret-mode off-TPU); the fallback
    is the sequential-peer jnp oracle from ``kernels.ref``, which runs the
    same op sequence (bit-exact for codebook methods, ulp-level FMA slack
    for the uniform dequant — see ``tests/test_decode_kernels.py``) and is
    safe under shard_map tracing on the pinned toolchain.  Every peer of a
    collective runs one compiled program over identical gathered bytes, so
    peers agree bit-for-bit on the result regardless of path (the
    peer-agreement contract).
    """
    return _decode_dispatch(cfg, "decode_reduce", words, levels, n, use_pallas)


def decode_rows(cfg: CompressorConfig, words: jax.Array, levels: jax.Array, n: int,
                use_pallas: bool) -> jax.Array:
    """Fused unpack → dequant of gathered rows, one (n,) row per peer.

    The all-gather phase-2 shape: peer j's decode is output chunk j, so the
    (peers, n) result *is* the payload (no reduction) — the fusion removes
    the (peers, n) int32 code intermediate.  Same dispatch contract as
    :func:`decode_reduce`.
    """
    return _decode_dispatch(cfg, "decode_rows", words, levels, n, use_pallas)


def _decode_dispatch(cfg: CompressorConfig, op: str, words: jax.Array, levels: jax.Array,
                     n: int, use_pallas: bool) -> jax.Array:
    """Select kernel vs fallback module and uniform vs codebook variant.

    Uniform-codebook methods dequantize from α alone (``levels[:, -1]``);
    everything else walks the shipped codebook.
    """
    if use_pallas:
        from repro.kernels import ops as mod
    else:
        from repro.kernels import ref as mod
    if cfg.method in _UNIFORM_DECODE:
        return getattr(mod, f"uniform_{op}")(words, levels[:, -1], n, cfg.bits)
    return getattr(mod, f"codebook_{op}")(words, levels, n, cfg.bits)


def _bucket_stats(flat: jax.Array, use_pallas: bool):
    """One-pass (counts, log_sums, g_max, …) statistics dispatch for the
    secondary plan sites (phase-2 chunks, pod means) that have no
    precomputed stats from the train step's fused EF-correct pass."""
    from repro.adaptive.telemetry import bucket_statistics

    return bucket_statistics(flat, use_pallas=use_pallas)


def _plan_bucket(cfg: CompressorConfig, flat: jax.Array, stat, use_pallas: bool) -> QuantMeta:
    """Histogram-driven plan from precomputed or inline one-pass stats."""
    if stat is None:
        stat = _bucket_stats(flat, use_pallas)
    return plan_from_stats(cfg, stat[0], stat[1], stat[2])


def _plan_encode_rows(cfg: CompressorConfig, rows: jax.Array, key: jax.Array,
                      use_pallas: bool) -> tuple[jax.Array, QuantMeta]:
    """Per-row plan + fused encode-pack.  rows: (k, m) fp32 -> ((k, words), metas).

    The per-leaf two-phase site: each peer chunk keeps the sort-based
    ``plan`` (the raw-tensor fallback fit), but the encode routes through
    the fused :func:`encode_pack` dispatch, so no unpacked code row is
    staged between encode and pack.
    """
    k = rows.shape[0]
    metas = jax.vmap(lambda r: plan(cfg, r))(rows)
    keys = jax.random.split(key, k)
    return jax.vmap(lambda r, m_lv, m_a, kk: encode_pack(
        cfg, r, QuantMeta(levels=m_lv, alpha=m_a), kk, use_pallas))(
        rows, metas.levels, metas.alpha, keys), metas


# ---------------------------------------------------------------------------
# Collective codecs
# ---------------------------------------------------------------------------


def two_phase_reduce_scatter_sharded(
    cfg: CompressorConfig,
    g: jax.Array,
    dim: int,
    axis_name,
    key: jax.Array,
    use_pallas: bool = False,
) -> jax.Array:
    """Compressed reduce-scatter: returns this peer's chunk of the peer-mean.

    The local tensor is split into n equal chunks along ``dim`` (n = size of
    ``axis_name``); chunk j is quantized with its own codebook and shipped to
    peer j; each peer dequantizes the n received codewords and averages.

"""
    n = compat.axis_size(axis_name)
    if n == 1:
        return g
    if g.shape[dim] % n:
        raise ValueError(f"dim {dim} of shape {g.shape} not divisible by axis size {n}")
    key = _peer_key(key, axis_name)

    chunk_shape = g.shape[:dim] + (g.shape[dim] // n,) + g.shape[dim + 1:]
    parts = jnp.moveaxis(g, dim, 0).reshape(n, g.shape[dim] // n, -1)
    flat = parts.reshape(n, -1).astype(jnp.float32)                  # (n, m)
    m = flat.shape[1]

    words, metas = _plan_encode_rows(cfg, flat, key, use_pallas)
    recv_words = compat.all_to_all_rows(words, axis_name)            # (n, w)
    recv_levels = compat.all_to_all_rows(metas.levels, axis_name)
    mean_flat = decode_reduce(cfg, recv_words, recv_levels, m, use_pallas)
    return jnp.moveaxis(mean_flat.reshape((chunk_shape[dim],) + g.shape[:dim] + g.shape[dim + 1:]),
                        0, dim)


def two_phase_mean(
    cfg: CompressorConfig,
    g: jax.Array,
    axis_name,
    key: jax.Array,
    use_pallas: bool = False,
) -> jax.Array:
    """Full two-phase compressed mean: reduce-scatter then all-gather.

    Both phases move quantized chunks, so per-device wire cost is
    ~2 · bits/32 of the fp32 all-reduce (see ``collectives.wire_bytes_per_device``).
    """
    n = compat.axis_size(axis_name)
    if n == 1:
        return g
    k1, k2 = jax.random.split(_peer_key(key, axis_name))

    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % n
    padded = jnp.pad(flat, (0, pad))
    chunk = two_phase_reduce_scatter_sharded(cfg, padded, 0, axis_name, k1, use_pallas)

    # Phase 2: broadcast this peer's mean chunk, freshly quantized.
    meta2 = plan(cfg, chunk)
    words2 = encode_pack(cfg, chunk, meta2, k2, use_pallas)
    all_words = compat.all_gather_stacked(words2, axis_name)             # (n, w)
    all_levels = compat.all_gather_stacked(meta2.levels, axis_name)
    full = decode_rows(cfg, all_words, all_levels, chunk.size, use_pallas).reshape(-1)
    return full[: flat.size].reshape(g.shape).astype(g.dtype)


def faithful_ring_mean(
    cfg: CompressorConfig,
    g: jax.Array,
    axis_name,
    key: jax.Array,
    use_pallas: bool = False,
) -> jax.Array:
    """Unbiased ring mean: each peer's full tensor is quantized exactly once.

    All peers decode the same n codewords, so the result is bitwise identical
    everywhere and E[result] is the true mean of the peers' local tensors
    (the quantizer is unbiased per element, per peer).
    """
    n = compat.axis_size(axis_name)
    flat = g.reshape(-1).astype(jnp.float32)
    meta = plan(cfg, flat)
    words = encode_pack(cfg, flat, meta, _peer_key(key, axis_name) if n > 1 else key,
                        use_pallas)
    if n == 1:
        own = decode_reduce(cfg, words[None], meta.levels[None], flat.size, use_pallas)
        return own.reshape(g.shape).astype(g.dtype)
    all_words = compat.all_gather_stacked(words, axis_name)              # (n, w)
    all_levels = compat.all_gather_stacked(meta.levels, axis_name)
    mean_flat = decode_reduce(cfg, all_words, all_levels, flat.size, use_pallas)
    return mean_flat.reshape(g.shape).astype(g.dtype)


# ---------------------------------------------------------------------------
# Bucketed fast path: one fused wire tensor per phase for a whole bucket list
# ---------------------------------------------------------------------------


def _levels_to_wire(levels: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(levels.astype(jnp.float32), jnp.uint32)


def _levels_from_wire(words: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(words, jnp.float32)


def _bucket_cfgs(
    cfg: CompressorConfig, n_buckets: int, bits: Optional[Sequence[int]]
) -> list[CompressorConfig]:
    """Per-bucket compressor configs for a (possibly heterogeneous) bit plan.

    ``bits=None`` keeps ``cfg`` everywhere; otherwise one config per bucket
    with that bucket's static wire width.  The bit plan is trace-time
    Python, so bucket offsets in the fused wire tensor stay static.
    """
    if bits is None:
        return [cfg] * n_buckets
    if len(bits) != n_buckets:
        raise ValueError(f"bit plan has {len(bits)} entries for {n_buckets} buckets")
    return [cfg if int(b) == cfg.bits else dataclasses.replace(cfg, bits=int(b))
            for b in bits]


def bucketed_faithful_ring_mean(
    cfg: CompressorConfig,
    buckets: list,
    axis_name,
    key: jax.Array,
    use_pallas: bool = False,
    bits: Optional[Sequence[int]] = None,
    stats: Optional[list] = None,
) -> tuple[list, list]:
    """Faithful ring mean over a bucket list with ONE all-gather total.

    Each bucket is quantized once with its own codebook — planned with
    ``compressors.plan_from_stats`` from the one-pass ``stats`` tuples (the
    fused EF-correct→stats pass; computed inline when None) — and all
    buckets' packed words and bitcast codebooks are concatenated into a
    single uint32 wire tensor.  ``bits`` optionally assigns each bucket its
    own static wire width (the adaptive bit plan) — bucket offsets stay
    static because the plan is trace-time Python.  Returns ``(mean_buckets,
    resid_buckets)`` with ``resid = corrected − own dequant``, the next EF
    residual, produced inside the fused encode.
    """
    n = compat.axis_size(axis_name)
    if n > 1:
        key = _peer_key(key, axis_name)
    cfgs = _bucket_cfgs(cfg, len(buckets), bits)
    parts, resids, sizes, metas = [], [], [], []
    for b, g in enumerate(buckets):
        flat = g.reshape(-1).astype(jnp.float32)
        meta = _plan_bucket(cfgs[b], flat, stats[b] if stats is not None else None,
                            use_pallas)
        words, resid = encode_pack_residual(cfgs[b], flat, meta,
                                            jax.random.fold_in(key, b), use_pallas)
        resids.append(resid)
        parts.append(words)
        parts.append(_levels_to_wire(meta.levels))
        sizes.append(flat.size)
        metas.append(meta)
    if n == 1:
        # Degenerate single-peer ring: the "mean" is this peer's own
        # dequantized transmission, recovered through the same fused decode
        # every multi-peer site uses (exact codebook lookup).
        means = [
            decode_reduce(cfgb, parts[2 * b][None], metas[b].levels[None], m, use_pallas)
            for b, (m, cfgb) in enumerate(zip(sizes, cfgs))
        ]
        return means, resids
    wire = jnp.concatenate(parts)
    rows = compat.all_gather_stacked(wire, axis_name)                    # (n, T)
    means, off = [], 0
    for m, cfgb in zip(sizes, cfgs):
        w = packed_size(m, cfgb.bits)
        nl = cfgb.s + 1
        words = rows[:, off:off + w]
        levels = _levels_from_wire(rows[:, off + w:off + w + nl])
        off += w + nl
        means.append(decode_reduce(cfgb, words, levels, m, use_pallas))
    return means, resids


def bucketed_two_phase_mean(
    cfg: CompressorConfig,
    buckets: list,
    axis_name,
    key: jax.Array,
    use_pallas: bool = False,
    bits: Optional[Sequence[int]] = None,
    stats: Optional[list] = None,
) -> tuple[list, list]:
    """Two-phase compressed mean over a bucket list: ONE all-to-all (phase 1)
    plus ONE all-gather (phase 2) for every bucket together.

    Each bucket gets a single per-bucket codebook shared by its n peer
    chunks (padded to ``n*32`` elements so packed chunk words slice
    cleanly); the codebook rides along once per all-to-all row.  Phase-1
    plans come from the one-pass ``stats``; the phase-2 mean-chunk
    re-quantization computes its own inline.  ``bits`` optionally assigns
    per-bucket wire widths (both phases use the bucket's width).  Returns
    ``(mean_buckets, resid_buckets)``.
    """
    n = compat.axis_size(axis_name)
    flats = [g.reshape(-1).astype(jnp.float32) for g in buckets]
    if n == 1:
        # Size-1 axis: nothing is transmitted (identity mean), so the EF
        # residual of this stage is exactly zero.
        return flats, [jnp.zeros_like(f) for f in flats]
    k1, k2 = jax.random.split(_peer_key(key, axis_name))
    cfgs = _bucket_cfgs(cfg, len(buckets), bits)
    parts, resids, chunk_meta = [], [], []
    for b, flat in enumerate(flats):
        padded = jnp.pad(flat, (0, (-flat.size) % (n * 32)))
        meta = _plan_bucket(cfgs[b], flat, stats[b] if stats is not None else None,
                            use_pallas)
        words, resid = encode_pack_residual(cfgs[b], padded, meta,
                                            jax.random.fold_in(k1, b), use_pallas)
        resids.append(resid[: flat.size])
        mc = padded.size // n                                            # chunk elements
        wc = packed_size(mc, cfgs[b].bits)                               # chunk words
        parts.append(words.reshape(n, wc))
        parts.append(jnp.tile(_levels_to_wire(meta.levels)[None], (n, 1)))
        chunk_meta.append((mc, wc))
    wire = jnp.concatenate(parts, axis=1)                                # (n, T1)
    recv = compat.all_to_all_rows(wire, axis_name)                       # (n, T1)

    # Phase 1 decode: this peer's chunk of every bucket's mean.
    mean_chunks, off = [], 0
    for (mc, wc), cfgb in zip(chunk_meta, cfgs):
        nl = cfgb.s + 1
        words = recv[:, off:off + wc]
        levels = _levels_from_wire(recv[:, off + wc:off + wc + nl])
        off += wc + nl
        mean_chunks.append(decode_reduce(cfgb, words, levels, mc, use_pallas))

    # Phase 2: re-quantize the mean chunks, one fused all-gather back.
    parts2 = []
    for b, ch in enumerate(mean_chunks):
        meta2 = _plan_bucket(cfgs[b], ch, None, use_pallas)
        words2 = encode_pack(cfgs[b], ch, meta2, jax.random.fold_in(k2, b), use_pallas)
        parts2.append(words2)
        parts2.append(_levels_to_wire(meta2.levels))
    rows2 = compat.all_gather_stacked(jnp.concatenate(parts2), axis_name)  # (n, T2)
    means, off = [], 0
    for (mc, wc), cfgb, flat in zip(chunk_meta, cfgs, flats):
        nl = cfgb.s + 1
        words = rows2[:, off:off + wc]
        levels = _levels_from_wire(rows2[:, off + wc:off + wc + nl])
        off += wc + nl
        vals = decode_rows(cfgb, words, levels, mc, use_pallas)          # row j = chunk j
        means.append(vals.reshape(n * mc)[: flat.size])
    return means, resids


def bucketed_hierarchical_mean(
    cfg: CompressorConfig,
    buckets: list,
    dp: tuple,
    key: jax.Array,
    use_pallas: bool = False,
    bits: Optional[Sequence[int]] = None,
    stats: Optional[list] = None,
) -> tuple[list, list]:
    """Two-phase inside the innermost data axis, faithful exchange of the
    pod means across the leading pod axes — 3 collectives total.

    The intra-pod phase folds the *full* dp index into its key: same-data-rank
    workers in different pods encode different data, so nothing forces them to
    share a stream, and leaving them correlated caps the phase-1 error at
    1/sqrt(data) instead of 1/sqrt(n).  (The cross-pod faithful stage keeps
    per-pod streams — members of one pod must emit identical bytes.)
    The EF residual comes from the intra-pod stage (what this peer actually
    transmitted); the cross-pod stage plans from inline pod-mean stats.
    """
    pod_axes, data_axis = dp[:-1], dp[-1:]
    k1, k2 = jax.random.split(key)
    k1 = _peer_key(k1, dp)
    means, resids = bucketed_two_phase_mean(cfg, buckets, data_axis, k1, use_pallas,
                                            bits, stats)
    means, _ = bucketed_faithful_ring_mean(cfg, means, pod_axes, k2, use_pallas, bits)
    return means, resids
