"""Sharded wire codec: quantized gradient collectives inside shard_map.

These are the per-device bodies of the compressed synchronization modes
(paper Alg. 1 deployed as collectives).  Every function runs *inside* a
shard_map region and sees only the local shard of the gradient:

- :func:`two_phase_reduce_scatter_sharded` — phase 1 of two-phase sync:
  quantize each of the n peer-chunks of the local tensor, exchange codes
  all-to-all, dequantize and average.  Each peer ends with its own chunk of
  the mean (a compressed reduce-scatter).
- :func:`two_phase_mean` — both phases: reduce-scatter, then re-quantize the
  mean chunk and all-gather it back (each direction ships ~bits/32 of the
  fp32 payload).
- :func:`faithful_ring_mean` — the Error-Compensated-QSGD-style worker
  exchange: each peer's *full* tensor is quantized exactly once, every peer
  decodes the same n codewords, so all peers agree bit-for-bit on the mean
  and the per-peer quantizers stay unbiased.
- :func:`pack_dim` / :func:`unpack_dim` — the uint32 wire format of
  ``core.quantizers`` applied along an arbitrary axis, so code tensors can be
  exchanged without first flattening away the peer axis.

The bucketed fast path (:func:`bucketed_two_phase_mean`,
:func:`bucketed_faithful_ring_mean`, :func:`bucketed_hierarchical_mean`)
takes a *list* of coalesced fp32 buckets (``core.compressors.plan_buckets``),
plans one codebook per bucket, and fuses every bucket's packed codes and
bitcast codebook into a single wire tensor so each phase issues exactly one
collective regardless of bucket or leaf count.  An optional per-bucket
``bits`` plan (``repro.adaptive``) gives each bucket its own static wire
width inside the same fused tensor — offsets stay trace-time static, and the
collective count does not change.  Each function also returns the peer's own
per-bucket **EF residual** ``corrected − C(corrected)``, produced inside the
fused encode.

Per-chunk codebooks ride along with the codes as (levels, alpha) pairs —
``wire_bytes`` in ``core.compressors`` accounts for them.

The bucketed collective bodies are codec-agnostic: every local half of the
sync — planning, the fused encode-pack(-residual), the fused
decode(-reduce), and the static wire/state geometry — goes through the
:mod:`repro.core.codecs` registry (``get_codec(cfg.method)``), and the
bodies branch only on the codec *interface* (``chunkable``, aux state),
never on method strings.  The quantizer family's hooks preserve the
pre-registry wire layout byte-for-byte (codes then bitcast codebook per
bucket), so existing methods stay bit-identical; non-chunkable codecs
(``powersgd`` low-rank factors) ride the same fused tensors by tiling
their full wire into every two-phase row (an embedded all-gather) with a
zero-width phase-2 contribution.  Codec-opaque per-bucket state (the
warm-started PowerSGD Q) flows in via ``aux=`` and comes back concatenated
onto the EF residual row (``concat(resid, aux_new)``) — quantizers carry
no aux and return the residual rows unchanged.

The kernel/jnp dispatch helpers (:func:`encode_pack`,
:func:`encode_pack_residual`, :func:`decode_reduce`, :func:`decode_rows`,
``_plan_bucket``, …) live in ``core.codecs`` and are re-exported here for
the reference replay and the per-leaf codec: ``use_pallas`` selects the
``kernels.encode_fused``/``kernels.decode`` Pallas kernels (one VMEM pass;
codes and the dequantized ``own`` tensor never reach HBM), otherwise the
key-compatible sequential oracles in ``kernels.ref`` run the same op
sequence (bit-identical wire words; uniform dequant keeps ulp-level FMA
slack) and stay shard_map-safe on the pinned toolchain.

Peer RNG independence: every encode folds ``compat.flat_axis_index`` of the
collective's own axes into the key.  The paper's Lemma 2 (mean error
concentrating as 1/n across workers) assumes independent stochastic rounding
per peer; a verbatim replicated key correlates the draws and the mean never
concentrates (``tests/test_rng_independence.py`` pins this).  Folding the
index of the *collective's* axes only — not every mesh axis — keeps the
hierarchical mode's replication invariant: peers that must produce identical
bytes (same pod, different data rank in the cross-pod exchange) still share
a stream.
"""
from __future__ import annotations

from collections.abc import Sequence

import jax
import jax.numpy as jnp

# Local codec halves live in core.codecs; re-exported names keep the
# long-standing import surface (reference replay, benches, tests) stable.
from repro.core.codecs import (  # noqa: F401  (re-exports)
    _UNIFORM_DECODE,
    _bucket_stats,
    _decode_dispatch,
    _encode_dispatch,
    _levels_from_wire,
    _levels_to_wire,
    _plan_bucket,
    bucket_cfgs as _registry_bucket_cfgs,
    bucket_state_sizes,
    decode_reduce,
    decode_rows,
    encode_pack,
    encode_pack_residual,
    get_codec,
)
from repro.core.compressors import CompressorConfig, plan
from repro.core.quantizers import (
    QuantMeta,
    pack_codes,
    unpack_codes,
)

from . import compat


# ---------------------------------------------------------------------------
# Bit packing along an arbitrary axis
# ---------------------------------------------------------------------------


def pack_dim(codes: jax.Array, dim: int, bits: int) -> jax.Array:
    """Bit-pack uint8 codes into uint32 words along axis ``dim``.

    Shape change: ``n -> ceil(n/32) * bits`` on that axis; all other axes
    are preserved (the packing is independent per lane).
    """
    moved = jnp.moveaxis(codes, dim, -1)
    lead = moved.shape[:-1]
    flat = moved.reshape(-1, moved.shape[-1])
    words = jax.vmap(lambda row: pack_codes(row, bits))(flat)
    return jnp.moveaxis(words.reshape(lead + (words.shape[-1],)), -1, dim)


def unpack_dim(words: jax.Array, dim: int, bits: int, n: int | None = None) -> jax.Array:
    """Inverse of :func:`pack_dim`; ``n`` recovers a non-multiple-of-32 axis."""
    moved = jnp.moveaxis(words, dim, -1)
    lead = moved.shape[:-1]
    if n is None:
        n = (moved.shape[-1] // bits) * 32
    flat = moved.reshape(-1, moved.shape[-1])
    codes = jax.vmap(lambda row: unpack_codes(row, n, bits))(flat)
    return jnp.moveaxis(codes.reshape(lead + (n,)), -1, dim)


# ---------------------------------------------------------------------------
# Local encode/decode helpers (flat fp32 <-> packed words + codebook)
# ---------------------------------------------------------------------------


def _peer_key(key: jax.Array, axis_name) -> jax.Array:
    """Decorrelate the replicated step key across the peers of a collective.

    Inside the fully-manual shard_map every peer receives the same key; the
    quantizer's unbiasedness across peers needs independent uniforms, so the
    peer's linear index over the collective's axes is folded in.
    """
    return jax.random.fold_in(key, compat.flat_axis_index(axis_name))


def _plan_encode_rows(cfg: CompressorConfig, rows: jax.Array, key: jax.Array,
                      use_pallas: bool) -> tuple[jax.Array, QuantMeta]:
    """Per-row plan + fused encode-pack.  rows: (k, m) fp32 -> ((k, words), metas).

    The per-leaf two-phase site: each peer chunk keeps the sort-based
    ``plan`` (the raw-tensor fallback fit), but the encode routes through
    the fused :func:`encode_pack` dispatch, so no unpacked code row is
    staged between encode and pack.
    """
    k = rows.shape[0]
    metas = jax.vmap(lambda r: plan(cfg, r))(rows)
    keys = jax.random.split(key, k)
    return jax.vmap(lambda r, m_lv, m_a, kk: encode_pack(
        cfg, r, QuantMeta(levels=m_lv, alpha=m_a), kk, use_pallas))(
        rows, metas.levels, metas.alpha, keys), metas


# ---------------------------------------------------------------------------
# Collective codecs
# ---------------------------------------------------------------------------


def two_phase_reduce_scatter_sharded(
    cfg: CompressorConfig,
    g: jax.Array,
    dim: int,
    axis_name,
    key: jax.Array,
    use_pallas: bool = False,
) -> jax.Array:
    """Compressed reduce-scatter: returns this peer's chunk of the peer-mean.

    The local tensor is split into n equal chunks along ``dim`` (n = size of
    ``axis_name``); chunk j is quantized with its own codebook and shipped to
    peer j; each peer dequantizes the n received codewords and averages.

"""
    n = compat.axis_size(axis_name)
    if n == 1:
        return g
    if g.shape[dim] % n:
        raise ValueError(f"dim {dim} of shape {g.shape} not divisible by axis size {n}")
    key = _peer_key(key, axis_name)

    chunk_shape = g.shape[:dim] + (g.shape[dim] // n,) + g.shape[dim + 1:]
    parts = jnp.moveaxis(g, dim, 0).reshape(n, g.shape[dim] // n, -1)
    flat = parts.reshape(n, -1).astype(jnp.float32)                  # (n, m)
    m = flat.shape[1]

    words, metas = _plan_encode_rows(cfg, flat, key, use_pallas)
    recv_words = compat.all_to_all_rows(words, axis_name)            # (n, w)
    recv_levels = compat.all_to_all_rows(metas.levels, axis_name)
    mean_flat = decode_reduce(cfg, recv_words, recv_levels, m, use_pallas)
    return jnp.moveaxis(mean_flat.reshape((chunk_shape[dim],) + g.shape[:dim] + g.shape[dim + 1:]),
                        0, dim)


def two_phase_mean(
    cfg: CompressorConfig,
    g: jax.Array,
    axis_name,
    key: jax.Array,
    use_pallas: bool = False,
) -> jax.Array:
    """Full two-phase compressed mean: reduce-scatter then all-gather.

    Both phases move quantized chunks, so per-device wire cost is
    ~2 · bits/32 of the fp32 all-reduce (see ``collectives.wire_bytes_per_device``).
    """
    n = compat.axis_size(axis_name)
    if n == 1:
        return g
    k1, k2 = jax.random.split(_peer_key(key, axis_name))

    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % n
    padded = jnp.pad(flat, (0, pad))
    chunk = two_phase_reduce_scatter_sharded(cfg, padded, 0, axis_name, k1, use_pallas)

    # Phase 2: broadcast this peer's mean chunk, freshly quantized.
    meta2 = plan(cfg, chunk)
    words2 = encode_pack(cfg, chunk, meta2, k2, use_pallas)
    all_words = compat.all_gather_stacked(words2, axis_name)             # (n, w)
    all_levels = compat.all_gather_stacked(meta2.levels, axis_name)
    full = decode_rows(cfg, all_words, all_levels, chunk.size, use_pallas).reshape(-1)
    return full[: flat.size].reshape(g.shape).astype(g.dtype)


def faithful_ring_mean(
    cfg: CompressorConfig,
    g: jax.Array,
    axis_name,
    key: jax.Array,
    use_pallas: bool = False,
) -> jax.Array:
    """Unbiased ring mean: each peer's full tensor is quantized exactly once.

    All peers decode the same n codewords, so the result is bitwise identical
    everywhere and E[result] is the true mean of the peers' local tensors
    (the quantizer is unbiased per element, per peer).
    """
    n = compat.axis_size(axis_name)
    flat = g.reshape(-1).astype(jnp.float32)
    meta = plan(cfg, flat)
    words = encode_pack(cfg, flat, meta, _peer_key(key, axis_name) if n > 1 else key,
                        use_pallas)
    if n == 1:
        own = decode_reduce(cfg, words[None], meta.levels[None], flat.size, use_pallas)
        return own.reshape(g.shape).astype(g.dtype)
    all_words = compat.all_gather_stacked(words, axis_name)              # (n, w)
    all_levels = compat.all_gather_stacked(meta.levels, axis_name)
    mean_flat = decode_reduce(cfg, all_words, all_levels, flat.size, use_pallas)
    return mean_flat.reshape(g.shape).astype(g.dtype)


# ---------------------------------------------------------------------------
# Bucketed fast path: one fused wire tensor per phase for a whole bucket list
# ---------------------------------------------------------------------------


def _bucket_cfgs(
    cfg: CompressorConfig, n_buckets: int, bits: Sequence | None
) -> list[CompressorConfig]:
    """Per-bucket compressor configs for a (possibly heterogeneous) plan.

    Entries may be ints (bit widths), ``("method", value)`` pairs, or full
    configs — see ``core.codecs.bucket_cfgs``.  The plan is trace-time
    Python, so bucket offsets in the fused wire tensor stay static.
    """
    return _registry_bucket_cfgs(cfg, n_buckets, bits)


def _state_row(resid: jax.Array, aux_new) -> jax.Array:
    """One bucket's EF/state row: the residual plus any codec aux tail."""
    return resid if aux_new is None else jnp.concatenate([resid, aux_new])


def _bucket_aux(aux: list | None, b: int):
    return aux[b] if aux is not None else None


# Elastic partial participation (``repro.elastic``): each bucketed body
# optionally takes ``live``, a replicated (n,) float32 0/1 mask over the
# collective's peers.  The semantics, identical across modes:
#
# - a dropped peer's encode still runs (the straggler-timeout contract: the
#   codec path is side-effect-free), but its *wire* is zeroed before the
#   collective — a zeroed row decodes to exactly 0.0 for every registered
#   codec (uniform: α=0 ⇒ code·2α/s−α=0; codebook: all-zero levels look up
#   0; low-rank: P=0,Q=0 ⇒ PQᵀ=0; fp16: bits 0 ⇒ 0.0) — so the decode-
#   reduce mean is Σ_live/n and one multiply by ``n / max(n_live, 1)``
#   renormalizes it to the live-peer mean;
# - the dropped peer's EF row keeps the whole corrected bucket
#   (``e ← g + e``, nothing transmitted), so the residual is recovered —
#   not lost — when the peer rejoins;
# - liveness itself is a replicated pure function of ``(seed, step)``
#   (``elastic.schedule.live_mask``) — no mask collective, so the traced
#   collective count per mode is unchanged (REPRO101 budgets hold).
#
# ``live=None`` (the default) skips every masking op: elastic-off graphs
# stay byte-identical to the pre-elastic codec.


def _self_live(live, axis_name, n: int):
    """This peer's own liveness scalar from the replicated (n,) mask."""
    if live is None:
        return None
    return live[compat.flat_axis_index(axis_name)] if n > 1 else live[0]


def _live_scale(live: jax.Array, n: int) -> jax.Array:
    """The ``n / max(n_live, 1)`` renormalization of a zero-filled mean."""
    return jnp.float32(n) / jnp.maximum(jnp.sum(live), jnp.float32(1.0))


def _mask_wire(wire: jax.Array, self_live) -> jax.Array:
    """Zero a dropped peer's wire words (uint32 select; see above)."""
    if self_live is None:
        return wire
    return jnp.where(self_live > 0, wire, jnp.zeros_like(wire))


def _mask_resid(resid: jax.Array, flat: jax.Array, self_live) -> jax.Array:
    """Dropped peers keep the whole corrected bucket as their EF residual."""
    if self_live is None:
        return resid
    return jnp.where(self_live > 0, resid, flat)


def bucketed_faithful_ring_mean(
    cfg: CompressorConfig,
    buckets: list,
    axis_name,
    key: jax.Array,
    use_pallas: bool = False,
    bits: Sequence | None = None,
    stats: list | None = None,
    aux: list | None = None,
    live: jax.Array | None = None,
) -> tuple[list, list]:
    """Faithful ring mean over a bucket list with ONE all-gather total.

    Each bucket is encoded once by its registered codec — planned from the
    one-pass ``stats`` tuples (the fused EF-correct→stats pass; computed
    inline when None) — and all buckets' wire vectors are concatenated into
    a single uint32 tensor sliced back by ``codec.wire_words`` (static)
    offsets.  ``bits`` optionally assigns per-bucket plan entries (bit
    widths or ``("method", value)`` pairs).  ``aux`` threads codec-opaque
    warm state in; returns ``(mean_buckets, state_rows)`` with row ``b`` =
    ``concat(resid, aux_new)`` (just the EF residual for aux-free codecs).
    """
    n = compat.axis_size(axis_name)
    if n > 1:
        key = _peer_key(key, axis_name)
    self_live = _self_live(live, axis_name, n)
    scale = None if live is None else _live_scale(live, n)
    cfgs = _bucket_cfgs(cfg, len(buckets), bits)
    codecs = [get_codec(c.method) for c in cfgs]
    parts, states, sizes = [], [], []
    # obs.* named scopes label the encode/collective/decode phases in
    # profiler traces (see repro.obs.trace); they change no numerics.
    with jax.named_scope("obs.encode"):
        for b, g in enumerate(buckets):
            flat = g.reshape(-1).astype(jnp.float32)
            pln = codecs[b].plan(cfgs[b], flat, stats[b] if stats is not None else None,
                                 use_pallas)
            wire, resid, aux_new = codecs[b].encode_residual(
                cfgs[b], flat, pln, jax.random.fold_in(key, b), use_pallas,
                aux=_bucket_aux(aux, b))
            states.append(_state_row(_mask_resid(resid, flat, self_live), aux_new))
            parts.append(wire)
            sizes.append(flat.size)
    if n == 1:
        # Degenerate single-peer ring: the "mean" is this peer's own
        # dequantized transmission, recovered through the same fused decode
        # every multi-peer site uses (exact codebook lookup).
        with jax.named_scope("obs.decode"):
            means = [
                codecs[b].decode_reduce(cfgs[b], _mask_wire(parts[b], self_live)[None],
                                        m, use_pallas)
                for b, m in enumerate(sizes)
            ]
            if scale is not None:
                means = [m * scale for m in means]
        return means, states
    with jax.named_scope("obs.collective"):
        wire = _mask_wire(jnp.concatenate(parts), self_live)
        rows = compat.all_gather_stacked(wire, axis_name)                # (n, T)
    with jax.named_scope("obs.decode"):
        means, off = [], 0
        for b, m in enumerate(sizes):
            w = codecs[b].wire_words(cfgs[b], m)
            mean_b = codecs[b].decode_reduce(cfgs[b], rows[:, off:off + w], m,
                                             use_pallas)
            means.append(mean_b if scale is None else mean_b * scale)
            off += w
    return means, states


def bucketed_two_phase_mean(
    cfg: CompressorConfig,
    buckets: list,
    axis_name,
    key: jax.Array,
    use_pallas: bool = False,
    bits: Sequence | None = None,
    stats: list | None = None,
    aux: list | None = None,
    live: jax.Array | None = None,
) -> tuple[list, list]:
    """Two-phase compressed mean over a bucket list: ONE all-to-all (phase 1)
    plus ONE all-gather (phase 2) for every bucket together.

    Chunkable codecs ship one plan per bucket shared by its n peer-chunk
    rows (``codec.encode_chunks``); non-chunkable codecs tile their full
    wire into every row and finish in phase 1 (see ``core.codecs``).
    Phase-1 plans come from the one-pass ``stats``; the phase-2 mean-chunk
    re-encode computes its own inline.  ``bits`` optionally assigns
    per-bucket plan entries (both phases use the bucket's width).  ``aux``
    threads codec warm state; returns ``(mean_buckets, state_rows)`` as in
    :func:`bucketed_faithful_ring_mean`.

    Elastic note: dropout applies to *gradient contributions*, not to
    transport — chunk ownership is structural, so phase 2 runs unmasked
    (every peer, live or dropped, relays its chunk of the already-
    renormalized live mean; a dropped peer only zeroes its phase-1 rows).
    """
    n = compat.axis_size(axis_name)
    flats = [g.reshape(-1).astype(jnp.float32) for g in buckets]
    cfgs = _bucket_cfgs(cfg, len(buckets), bits)
    codecs = [get_codec(c.method) for c in cfgs]
    self_live = _self_live(live, axis_name, n)
    if n == 1:
        # Size-1 axis: nothing is transmitted (identity mean), so the EF
        # residual of this stage is exactly zero; codec aux passes through.
        # A dropped solo member keeps the whole bucket as residual — the
        # hierarchical caller excludes it at the cross-pod stage (pod_live).
        return flats, [
            _state_row(jnp.zeros_like(f) if self_live is None
                       else jnp.where(self_live > 0, jnp.zeros_like(f), f),
                       _bucket_aux(aux, b))
            for b, f in enumerate(flats)]
    scale = None if live is None else _live_scale(live, n)
    k1, k2 = jax.random.split(_peer_key(key, axis_name))
    parts, states, widths = [], [], []
    with jax.named_scope("obs.encode"):
        for b, flat in enumerate(flats):
            pln = codecs[b].plan(cfgs[b], flat, stats[b] if stats is not None else None,
                                 use_pallas)
            kb = jax.random.fold_in(k1, b)
            if codecs[b].chunkable:
                rows_b, resid = codecs[b].encode_chunks(cfgs[b], flat, pln, kb, n,
                                                        use_pallas)
                aux_new = None
            else:
                # Non-chunkable wire (low-rank factors): tile the full wire into
                # every all-to-all row — an embedded all-gather riding the same
                # fused tensor, decoded entirely in phase 1.
                wire_b, resid, aux_new = codecs[b].encode_residual(
                    cfgs[b], flat, pln, kb, use_pallas, aux=_bucket_aux(aux, b))
                rows_b = jnp.tile(wire_b[None], (n, 1))
            states.append(_state_row(_mask_resid(resid, flat, self_live), aux_new))
            parts.append(rows_b)
            widths.append(rows_b.shape[1])
    with jax.named_scope("obs.collective"):
        wire = _mask_wire(jnp.concatenate(parts, axis=1), self_live)     # (n, T1)
        recv = compat.all_to_all_rows(wire, axis_name)                   # (n, T1)

    # Phase 1 decode: this peer's chunk of each chunkable bucket's mean;
    # non-chunkable buckets decode their full mean here (every peer holds
    # every peer's tiled wire after the all-to-all).
    with jax.named_scope("obs.decode"):
        mean_chunks, full_means, off = [], {}, 0
        for b, flat in enumerate(flats):
            rows_b = recv[:, off:off + widths[b]]
            off += widths[b]
            if codecs[b].chunkable:
                mc = codecs[b].chunk_elems(cfgs[b], flat.size, n)
                ch = codecs[b].decode_reduce(cfgs[b], rows_b, mc, use_pallas)
                mean_chunks.append(ch if scale is None else ch * scale)
            else:
                fm = codecs[b].decode_reduce(cfgs[b], rows_b, flat.size, use_pallas)
                full_means[b] = fm if scale is None else fm * scale
                mean_chunks.append(None)

    # Phase 2: re-encode the mean chunks, one fused all-gather back (skipped
    # entirely when no bucket chunks — then phase 1 already produced every
    # full mean).
    parts2, widths2 = [], []
    with jax.named_scope("obs.encode"):
        for b, ch in enumerate(mean_chunks):
            if ch is None:
                widths2.append(0)
                continue
            pln2 = codecs[b].plan(cfgs[b], ch, None, use_pallas)
            parts2.append(codecs[b].encode(cfgs[b], ch, pln2, jax.random.fold_in(k2, b),
                                           use_pallas))
            widths2.append(parts2[-1].shape[0])
    rows2 = None
    if parts2:
        with jax.named_scope("obs.collective"):
            rows2 = compat.all_gather_stacked(jnp.concatenate(parts2), axis_name)  # (n, T2)
    with jax.named_scope("obs.decode"):
        means, off = [], 0
        for b, flat in enumerate(flats):
            if mean_chunks[b] is None:
                means.append(full_means[b])
                continue
            mc = mean_chunks[b].size
            vals = codecs[b].decode_rows(cfgs[b], rows2[:, off:off + widths2[b]], mc,
                                         use_pallas)                     # row j = chunk j
            off += widths2[b]
            means.append(vals.reshape(n * mc)[: flat.size])
    return means, states


def bucketed_hierarchical_mean(
    cfg: CompressorConfig,
    buckets: list,
    dp: tuple,
    key: jax.Array,
    use_pallas: bool = False,
    bits: Sequence | None = None,
    stats: list | None = None,
    aux: list | None = None,
    live: jax.Array | None = None,
) -> tuple[list, list]:
    """Two-phase inside the innermost data axis, faithful exchange of the
    pod means across the leading pod axes — 3 collectives total.

    The intra-pod phase folds the *full* dp index into its key: same-data-rank
    workers in different pods encode different data, so nothing forces them to
    share a stream, and leaving them correlated caps the phase-1 error at
    1/sqrt(data) instead of 1/sqrt(n).  (The cross-pod faithful stage keeps
    per-pod streams — members of one pod must emit identical bytes.)
    The EF state (residual + codec aux) comes from the intra-pod stage (what
    this peer actually transmitted); the cross-pod stage plans from inline
    pod-mean stats and runs aux-cold (its encode is of a pod *mean*, not
    this peer's gradient, so warm factors would be the wrong subspace).
    """
    pod_axes, data_axis = dp[:-1], dp[-1:]
    k1, k2 = jax.random.split(key)
    k1 = _peer_key(k1, dp)
    live_sub = pod_live = None
    if live is not None:
        # Renormalization is per stage: the intra-pod mean over the pod's
        # live members (this pod's row of the (n_pod, nd) mask), the
        # cross-pod mean over live pods (a pod is live iff any member is).
        # Pods weigh equally regardless of live count — the same mean-of-
        # pod-means composition as full participation.
        n_pod = compat.axis_size(pod_axes)
        nd = compat.axis_size(data_axis)
        mat = live.reshape(n_pod, nd)
        live_sub = mat[compat.flat_axis_index(pod_axes)]
        pod_live = jnp.max(mat, axis=1)
    means, states = bucketed_two_phase_mean(cfg, buckets, data_axis, k1, use_pallas,
                                            bits, stats, aux, live_sub)
    means, _ = bucketed_faithful_ring_mean(cfg, means, pod_axes, k2, use_pallas, bits,
                                           live=pod_live)
    return means, states
