"""JAX version-compatibility layer for the distributed runtime.

The runtime (and its tests) target the modern JAX surface — ``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType`` — while the
pinned toolchain ships jax 0.4.37, where ``shard_map`` still lives in
``jax.experimental`` (with ``check_rep``/``auto`` instead of
``check_vma``/``axis_names``) and meshes carry no axis types.  Importing this
module installs forward-compatible shims onto ``jax`` when the modern names
are missing; on newer JAX every shim is a no-op.

It also provides thin collective helpers used by the sharded codec.  These
assume *fully manual* shard_map regions: the bundled jaxlib's SPMD
partitioner aborts on ``all_gather`` / ``all_to_all`` / ``axis_index`` (and
on any ``lax.scan``) inside manual subgroups when auto axes are present,
which is why the train step never runs model code under partial-auto —
fwd/bwd is plain GSPMD jit and only the gradient codec enters shard_map,
fully manual over every mesh axis.
"""
from __future__ import annotations

import enum
import inspect
from collections.abc import Sequence

import jax
import jax.numpy as jnp


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    sig = inspect.signature(jax.make_mesh)
    if "axis_types" in sig.parameters:
        return
    orig = jax.make_mesh

    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        del axis_types  # pre-AxisType meshes are implicitly fully Auto
        return orig(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = make_mesh


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh=None, in_specs=None, out_specs=None, *, axis_names=None,
                  check_vma=None, check_rep=None, auto=frozenset()):
        if axis_names is not None and mesh is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if check_rep is None:
            check_rep = bool(check_vma) if check_vma is not None else False
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=check_rep, auto=auto)

    jax.shard_map = shard_map


_install_axis_type()
_install_make_mesh()
_install_shard_map()

shard_map = jax.shard_map


def axis_size(axis_name) -> int:
    """Static size of a (possibly tuple of) named mesh axis inside shard_map.

    ``lax.psum`` of a Python literal is constant-folded to the axis size, so
    this is trace-time static and free.
    """
    return jax.lax.psum(1, axis_name)


def flat_axis_index(axis_name) -> jax.Array:
    """Row-major linear index over one or more manual mesh axes."""
    names: Sequence = axis_name if isinstance(axis_name, tuple) else (axis_name,)
    idx = jnp.int32(0)
    for name in names:
        idx = idx * jax.lax.psum(1, name) + jax.lax.axis_index(name)
    return idx


def all_gather_stacked(x: jax.Array, axis_name) -> jax.Array:
    """All-gather ``x`` over ``axis_name`` into a stacked (n, *x.shape) array."""
    if axis_size(axis_name) == 1:
        return x[None]
    return jax.lax.all_gather(x, axis_name, axis=0, tiled=False)


def all_to_all_rows(parts: jax.Array, axis_name) -> jax.Array:
    """All-to-all over the leading axis: row p of ``parts`` goes to peer p.

    ``parts`` has leading dim n = size of ``axis_name``; the result's row p
    holds the row peer p addressed to this shard.
    """
    if axis_size(axis_name) == 1:
        return parts
    return jax.lax.all_to_all(parts, axis_name, split_axis=0, concat_axis=0, tiled=False)
