"""Sharded serving: jitted prefill + single-token decode with KV caches.

Serving has no gradient sync, so it runs as plain auto-sharded jit: the
logical→mesh rules constrain activations (batch over data/pod, heads/ff/
vocab over model) and GSPMD inserts the collectives.  ``make_serve_fns``
returns the two jitted entry points plus the PartitionSpec trees callers use
to place params and caches.

Prefill reserves ``DECODE_MARGIN`` extra cache slots beyond the prompt so
decode steps can append without reallocating (decode writes at
``cache.length``; rolling sliding-window caches index by absolute position
instead and need no margin).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.models import transformer

from . import sharding

DECODE_MARGIN = 64


def cache_pspecs(cfg, B: int, cache_len: int, mesh) -> Any:
    """PartitionSpecs for a stacked cache tree: batch dim (axis 1, after the
    layer-stack axis) over the data axes; everything else replicated."""
    dp = sharding.data_axes(mesh)
    caches_like = jax.eval_shape(lambda: transformer.init_caches(cfg, B, cache_len))

    def one(x) -> P:
        if dp is None or x.ndim < 2 or x.shape[1] != B or B % _size(mesh, dp):
            return P(*(None,) * x.ndim)
        return P(None, dp, *(None,) * (x.ndim - 2))

    return jax.tree.map(one, caches_like)


def _size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def make_serve_fns(
    cfg,
    mesh,
    logical: Any,
    batch: Any | None,
    B: int,
    T: int,
    *,
    params_like: Any = None,
):
    """Build ``(prefill_fn, decode_fn, pspecs, cspecs)``.

    - ``prefill_fn(params, batch) -> (last-token logits (B, vocab), caches)``
    - ``decode_fn(params, token (B,1), caches, position) -> (logits, caches)``
    - ``pspecs``/``cspecs``: PartitionSpec trees for params and caches.

    ``batch`` is only used for tree structure and may be ``None`` for
    decode-only use (the dry-run's decode shapes build caches abstractly).
    """
    del batch  # structure comes from cfg; kept for call-site symmetry
    if params_like is None:
        # repro: allow REPRO204 (eval_shape aval-only trace; value never used)
        params_like = jax.eval_shape(lambda: transformer.init_lm(jax.random.key(0), cfg)[0])
    pspecs = sharding.param_pspecs(logical, mesh, cfg.fsdp, params_like)
    rules = sharding.activation_rules(mesh, fsdp=cfg.fsdp)
    capacity = T if cfg.sliding_window else T + DECODE_MARGIN

    @jax.jit
    def prefill_fn(params, batch_in):
        with sharding.axis_rules(mesh, rules):
            return transformer.prefill(cfg, params, batch_in, capacity=capacity)

    @jax.jit
    def decode_fn(params, token, caches, position):
        with sharding.axis_rules(mesh, rules):
            return transformer.decode_step(cfg, params, token, caches, position)

    cspecs = cache_pspecs(cfg, B, capacity, mesh)
    return prefill_fn, decode_fn, pspecs, cspecs
