"""Distributed train step: GSPMD fwd/bwd + fully-manual compressed grad sync.

``make_train_step`` builds one jitted step function over an arbitrary
(data[, model][, pod]) mesh.  The step has two regions:

1. **Auto (GSPMD) region** — the global batch is split into one *client* per
   data shard and per-client loss/grads are computed with ``vmap`` (params
   broadcast, batch mapped).  The stacked client axis is sharded over the
   data/pod mesh axes, so each device computes and holds exactly its own
   worker's gradient — the DSGD worker model, with tensor/expert parallelism
   and fsdp parameter sharding left to the partitioner.
2. **Manual (shard_map) region** — the stacked gradients enter a fully
   manual shard_map (every mesh axis manual; the pinned toolchain cannot mix
   manual data axes with auto model axes around ``lax.scan``) where the
   selected mode averages clients with real collectives:

   ======================  ====================================================
   ``dsgd``                exact fp32 ``pmean`` (the uncompressed baseline)
   ``two_phase``           compressed reduce-scatter + compressed all-gather
   ``hierarchical``        two-phase inside each pod, then a faithful
                           quantized exchange of pod-means across ``pod``
   ``faithful``            ring mean — each peer's tensor quantized once,
                           unbiased across peers (Wu et al., 1806.08054)
   ======================  ====================================================

   Gradients arrive model-sharded, so each (data, model) shard quantizes its
   own slice and the collectives only cross the data/pod axes.

The optimizer update then runs back in the auto region on sharded
params/state.  ``streamed=True`` swaps the one-shot ``value_and_grad`` for a
layer-streamed schedule: a forward scan that saves per-unit activations and
a reverse scan of per-unit VJPs, so at most one scan unit's backward graph
is live at a time.  It is numerically equivalent to the plain schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.adaptive import telemetry as adaptive_telemetry
from repro.adaptive.controller import AdaptiveConfig
from repro.core import compressors
from repro.core.codecs import size_adaptive_plan
from repro.core.compressors import CompressorConfig
from repro.elastic.schedule import ElasticConfig, live_mask
from repro.models import transformer
from repro.obs import metrics as obs_metrics
from repro.optim.optimizers import Optimizer

from . import compat, sharded_codec as sc, sharding

SYNC_MODES = ("dsgd", "two_phase", "hierarchical", "faithful")

_KEY_SEED = 0x5EED


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    """Per-step gradient synchronization configuration.

    ``bucket_mb > 0`` (the default) routes the sync through the bucketed
    codec: the gradient pytree is coalesced into ~``bucket_mb``-MB fp32
    buckets with one codebook per bucket and one fused collective per phase
    (vs one plan + 2-4 collectives per *leaf* on the per-leaf path, selected
    with ``bucket_mb=0``).  ``error_feedback=True`` carries a per-client
    EF residual through the step signature — ``step_fn(params,
    opt_state, ef_state, batch, step) -> (params, opt_state, ef_state,
    metrics)`` — compensating the truncated quantizers' bias
    (``core.error_feedback`` semantics: transmit C(g+e), keep e' = g+e-C(g+e)).

    ``adaptive`` (an :class:`repro.adaptive.AdaptiveConfig`) threads a
    per-client telemetry pytree through the signature the same way — the
    state slot follows ``ef_state`` when both are on — updated inside the
    sync region from the exact buckets the codec quantizes, with no extra
    collectives.  ``bits_plan`` assigns each bucket its own static wire
    width; bit plans are static per compiled step, so the adaptive runtime
    (``repro.adaptive.runtime``) swaps between compiled steps through a
    cache keyed on the bit tuple instead of retracing.

    The EF residual is **bucket-resident**: one stacked (n_clients,
    bucket_elems) fp32 array per codec bucket (:func:`init_ef_state`), not
    a leaf pytree — the residual the fused encode emits is carried to the
    next step as-is, with no per-step ``bucket_concat``/``bucket_split`` of
    the EF state and no leaf-spec constraint round-trip.

    ``metrics_gnorm=False`` drops the global gradient-norm metric; when on
    (the default) it is computed from the already-flat mean buckets inside
    the sync region (one ``psum`` over the model axes) instead of
    re-reducing the leaf pytree in the auto region.

    ``metrics_compression=True`` additionally emits a per-bucket
    :class:`repro.obs.metrics.CompressionMetrics` pytree under
    ``metrics["compression"]`` (leaves ``(n_dp, n_buckets)``, one row per
    data peer), gated exactly like ``metrics_gnorm``: everything is
    computed from tensors already resident inside the sync region (the
    fused encode's residual, the one-pass stats, the plan), the
    model-shard reduction rides the *same* vectorized ``psum`` as the
    gnorm, and the traced collective count per sync mode is unchanged
    (``analysis.count_collectives`` asserts this in ``tests/test_obs.py``).
    Requires the bucketed codec; omitted (like the sync itself) on meshes
    without data axes.
    """

    sync: str = "dsgd"
    streamed: bool = False
    compressor: CompressorConfig = dataclasses.field(default_factory=CompressorConfig)
    bucket_mb: float = 4.0
    error_feedback: bool = False
    adaptive: AdaptiveConfig | None = None
    bits_plan: tuple[int, ...] | None = None
    metrics_gnorm: bool = True
    metrics_compression: bool = False
    #: deterministic partial participation (``repro.elastic``): the step
    #: computes a per-step live mask in-graph and the sync renormalizes the
    #: peer mean over the live count; dropped peers' EF rows keep
    #: accumulating.  Adds ``metrics["live"]`` / ``metrics["live_count"]``.
    elastic: ElasticConfig | None = None
    #: size-adaptive compression tier: buckets of at most this many (local)
    #: elements ship raw half precision through the registered ``fp16``
    #: passthrough codec instead of the quantizer (0 = off).
    fp16_threshold: int = 0

    def __post_init__(self):
        if self.sync not in SYNC_MODES:
            raise ValueError(f"unknown sync mode {self.sync!r}; expected one of {SYNC_MODES}")
        if self.bucket_mb < 0:
            raise ValueError("bucket_mb must be >= 0 (0 selects the per-leaf codec)")
        if self.error_feedback:
            if self.sync == "dsgd" or self.compressor.method == "dsgd":
                raise ValueError("error_feedback requires a compressed sync mode/method")
            if self.bucket_mb <= 0:
                raise ValueError("error_feedback requires the bucketed codec (bucket_mb > 0)")
        if self.adaptive is not None:
            if self.sync == "dsgd" or self.compressor.method == "dsgd":
                raise ValueError("adaptive telemetry requires a compressed sync mode/method")
            if self.bucket_mb <= 0:
                raise ValueError("adaptive telemetry requires the bucketed codec (bucket_mb > 0)")
        if self.metrics_compression and self.bucket_mb <= 0:
            raise ValueError("metrics_compression requires the bucketed codec (bucket_mb > 0)")
        if self.elastic is not None and self.bucket_mb <= 0:
            raise ValueError("elastic sync requires the bucketed codec (bucket_mb > 0)")
        if self.fp16_threshold < 0:
            raise ValueError("fp16_threshold must be >= 0 (0 disables the tier)")
        if self.fp16_threshold > 0 and self.bucket_mb <= 0:
            raise ValueError("fp16_threshold targets the bucketed codec (bucket_mb > 0)")
        if self.bits_plan is not None:
            if self.bucket_mb <= 0:
                raise ValueError("bits_plan targets the bucketed codec (bucket_mb > 0)")
            norm = []
            for b in self.bits_plan:
                if isinstance(b, tuple | list):
                    # method-aware plan entry: ("method", value) — value is
                    # the rank for rank-based codecs, the bit width otherwise.
                    # The registry validates the shape, the method name, and
                    # the value range with actionable messages.
                    from repro.core.codecs import bucket_cfg_entry

                    bucket_cfg_entry(self.compressor, b)
                    norm.append((str(b[0]), int(b[1])))
                else:
                    if not (1 <= int(b) <= 8):
                        raise ValueError("bits_plan entries must be in [1, 8]")
                    norm.append(int(b))
            object.__setattr__(self, "bits_plan", tuple(norm))

    @property
    def bucket_elements(self) -> int:
        return int(self.bucket_mb * (1 << 20) / 4)


# ---------------------------------------------------------------------------
# Spec derivation helpers (shared with the launch/dryrun tooling)
# ---------------------------------------------------------------------------


def batch_pspecs(batch_like: Any, dp) -> Any:
    """PartitionSpecs for a ``models.transformer.Batch``: batch dim over ``dp``."""

    def for_field(x, batch_dim: int):
        if x is None:
            return None
        return P(*(dp if d == batch_dim else None for d in range(x.ndim)))

    b = batch_like
    return transformer.Batch(
        tokens=for_field(b.tokens, 0),
        labels=for_field(b.labels, 0),
        positions=for_field(b.positions, 1 if (b.positions is not None and b.positions.ndim == 3) else 0),
        patches=for_field(b.patches, 0),
        frames=for_field(b.frames, 0),
    )


def _opt_specs(opt_state_like: Any, params_like: Any, pspecs: Any) -> Any:
    """Optimizer-state specs: mirror leaves get the matching param's spec.

    State trees mirror the param tree leaf-for-leaf (momentum: one mirror;
    AdamW: two), but may interleave non-mirroring leaves such as a scalar
    step counter.  Each state leaf is shape-matched against the param tree
    in cyclic traversal order: a match takes the param's spec and advances
    the cursor; anything else (true scalars, odd bookkeeping) stays
    replicated with ``P()`` instead of silently replicating the *entire*
    state the way blanket cyclic indexing did.
    """
    p_shapes = [tuple(x.shape) for x in jax.tree.leaves(params_like)]
    spec_leaves = jax.tree.leaves(pspecs, is_leaf=lambda s: isinstance(s, P))
    leaves, treedef = jax.tree.flatten(opt_state_like)
    n = len(p_shapes)
    out, cursor = [], 0
    for leaf in leaves:
        if n and hasattr(leaf, "shape") and tuple(leaf.shape) == p_shapes[cursor % n]:
            out.append(spec_leaves[cursor % n])
            cursor += 1
        else:
            out.append(P())
    if n and cursor % n:
        # Partial mirror cycle: a non-mirror leaf with a coincidental param
        # shape desynced the cursor, so the assignment is unreliable — keep
        # the whole state replicated (always valid) rather than guessing.
        return jax.tree.unflatten(treedef, [P() for _ in leaves])
    return jax.tree.unflatten(treedef, out)


def _tree_map_with_specs(fn, tree: Any, spec_tree: Any) -> Any:
    leaves, treedef = jax.tree.flatten(tree)
    specs = jax.tree.leaves(spec_tree, is_leaf=lambda s: isinstance(s, P))
    return jax.tree.unflatten(treedef, [fn(x, s) for x, s in zip(leaves, specs)])


def _auto_only_entries(spec: P, mesh) -> tuple:
    """Spec entries with the manual (data/pod) axes removed — the stacked
    per-client gradients keep only their model-parallel sharding."""
    manual = set(sharding.manual_axes(mesh))
    entries = []
    for e in spec:
        axes = e if isinstance(e, tuple) else (e,) if e is not None else ()
        kept = tuple(a for a in axes if a not in manual)
        entries.append(kept[0] if len(kept) == 1 else (kept if kept else None))
    return tuple(entries)


# ---------------------------------------------------------------------------
# Gradient synchronization (runs inside a fully manual shard_map)
# ---------------------------------------------------------------------------


def _sync_leaf(ts: TrainStepConfig, g: jax.Array, key: jax.Array, dp: tuple) -> jax.Array:
    if ts.sync == "dsgd" or ts.compressor.method == "dsgd":
        return jax.lax.pmean(g, dp)
    cfg = ts.compressor
    if ts.sync == "faithful":
        return sc.faithful_ring_mean(cfg, g, dp, key, cfg.use_pallas)
    if ts.sync == "two_phase" or len(dp) == 1:
        return sc.two_phase_mean(cfg, g, dp, key, cfg.use_pallas)
    # hierarchical: compress within the innermost data axis, then exchange
    # pod-level means across the leading pod axes with a fresh quantization.
    # The intra-pod key folds the full dp index so same-data-rank workers in
    # different pods stay decorrelated (see bucketed_hierarchical_mean).
    pod_axes, data_axis = dp[:-1], dp[-1:]
    k1, k2 = jax.random.split(key)
    g = sc.two_phase_mean(cfg, g, data_axis, sc._peer_key(k1, dp), cfg.use_pallas)
    return sc.faithful_ring_mean(cfg, g, pod_axes, k2, cfg.use_pallas)


def _sync_buckets(ts: TrainStepConfig, vals: list, key: jax.Array, dp: tuple,
                  ef=None, tstate=None, live=None):
    """Bucketed sync of a flat leaf list.
    Returns (mean_leaves, resid_buckets, new_telemetry, mean_buckets,
    metric_sums) — ``metric_sums`` is the pre-psum
    ``repro.obs.metrics.local_sums`` pair under
    ``ts.metrics_compression`` (else ``None``); the caller reduces it over
    the model axes together with the gnorm scalar.

    The bucket plan is derived at trace time from the *local* (post-shard)
    leaf sizes; each phase of the selected mode moves one fused wire tensor
    for the whole bucket list, so the per-step collective count is bounded
    by the mode (1-3), not by the leaf or bucket count — including under a
    heterogeneous ``bits_plan``.

    The encode side is one-pass: each gradient bucket is read once by the
    fused EF-correct→stats pass (``adaptive.telemetry.correct_stats``),
    which adds the bucket-resident EF residual ``ef`` and emits the
    corrected bucket plus all statistics the codec's
    ``plan_from_stats`` codebook fit *and* the telemetry EMA consume — no
    separate telemetry sweep, no sort/quantile inside ``plan``.  The new
    residual comes back from the fused encode itself (bucket-resident, so
    the caller carries it to the next step without a ``bucket_split``);
    ``bucket_split`` runs once, on the final mean.  The flat mean buckets
    are also returned so the caller can derive ``gnorm`` without
    re-reducing the leaf pytree.

    ``live`` (elastic partial participation) is the replicated (n,) 0/1
    float mask over the dp peers; see the elastic block in
    ``dist.sharded_codec`` for the masking/renormalization semantics.
    """
    cfg = ts.compressor
    bp = compressors.plan_buckets([v.size for v in vals], ts.bucket_elements)
    buckets = compressors.bucket_concat(vals, bp)
    compressed = not (ts.sync == "dsgd" or cfg.method == "dsgd")
    # The size-adaptive fp16 tier rewrites the per-bucket plan before any
    # geometry (EF row split, wire offsets) is derived from it.
    bits = size_adaptive_plan(cfg, ts.bits_plan, bp.sizes, ts.fp16_threshold)
    # Split each bucket's EF row into the residual prefix and the codec-
    # opaque aux tail (``state_extra``; quantizer rows pass through whole,
    # keeping those graphs unchanged).
    cfgs = sc._bucket_cfgs(cfg, bp.n_buckets, bits)
    extras = [sc.get_codec(c.method).state_extra(c, g.size)
              for c, g in zip(cfgs, buckets)]
    aux = None
    if ef is not None and any(extras):
        aux = [ef[b][g.size:] if x else None
               for b, (g, x) in enumerate(zip(buckets, extras))]
        ef = [ef[b][:g.size] if x else ef[b]
              for b, (g, x) in enumerate(zip(buckets, extras))]
    stats = None
    if compressed or tstate is not None:
        corrected, stats = [], []
        for b, g in enumerate(buckets):
            c, st = adaptive_telemetry.correct_stats(
                g, ef[b] if ef is not None else None, use_pallas=cfg.use_pallas)
            corrected.append(c)
            stats.append(st)
        buckets = corrected
    new_t = None
    if tstate is not None:
        new_t = adaptive_telemetry.update_telemetry(
            tstate, buckets, decay=ts.adaptive.ema, use_pallas=cfg.use_pallas,
            stats=stats)
    if not compressed:
        if live is None:
            means = [jax.lax.pmean(b, dp) for b in buckets]
        else:
            # Uncompressed elastic dsgd: zero dead contributions inside the
            # same per-bucket pmean, renormalize over the live count — the
            # collective count (one pmean per bucket) is unchanged.
            n = compat.axis_size(dp)
            self_live = live[compat.flat_axis_index(dp)]
            scale = jnp.float32(n) / jnp.maximum(jnp.sum(live), jnp.float32(1.0))
            means = [jax.lax.pmean(b * self_live, dp) * scale for b in buckets]
        resids = None
    elif ts.sync == "faithful":
        means, resids = sc.bucketed_faithful_ring_mean(cfg, buckets, dp, key,
                                                       cfg.use_pallas, bits, stats, aux,
                                                       live)
    elif ts.sync == "two_phase" or len(dp) == 1:
        means, resids = sc.bucketed_two_phase_mean(cfg, buckets, dp, key,
                                                   cfg.use_pallas, bits, stats, aux,
                                                   live)
    else:
        means, resids = sc.bucketed_hierarchical_mean(cfg, buckets, dp, key,
                                                      cfg.use_pallas, bits, stats, aux,
                                                      live)
    shapes = [v.shape for v in vals]
    mean_leaves = compressors.bucket_split(means, bp, shapes)
    cm = None
    if ts.metrics_compression:
        # The fused encode's residual IS the realized quantization error of
        # this peer's own transmission, so the metric sums cost no extra
        # collective — the model-axis reduction is fused with the gnorm
        # psum by the caller.
        cm = obs_metrics.local_sums(ts, cfgs, buckets, stats, resids, ef,
                                    compressed)
    if not ts.error_feedback:
        resids = None
    return mean_leaves, resids, new_t, means, cm


def ef_bucket_spec(mesh) -> P:
    """PartitionSpec of one bucket-resident EF state array.

    Axis 0 is the client (data/pod) axis; axis 1 concatenates the model
    shards' local buckets (each shard round-trips its own row segment
    through the sync region, so the cross-shard element order is opaque and
    never reinterpreted leaf-wise).
    """
    dp = sharding.manual_axes(mesh)
    rest = tuple(a for a in mesh.axis_names if a not in dp)
    return P(dp if dp else None, rest if rest else None)


def _make_sync_fn(ts: TrainStepConfig, mesh, pspecs: Any, grads_like: Any):
    """Fully-manual shard_map averaging stacked per-client grads.

    Input leaves are (n_dp, *param_shape), client axis over the data/pod
    axes; output leaves are the synced mean with the param's model sharding,
    replicated over data/pod (every mode leaves all peers with identical
    bytes, so the unchecked replication in ``out_specs`` is sound).

    With ``ts.error_feedback`` the callable takes and returns the
    **bucket-resident** EF state (a tuple of stacked per-client bucket
    arrays, :func:`init_ef_state`) alongside the grads; with ``ts.adaptive``
    the stacked per-client telemetry state follows it; with
    ``ts.metrics_gnorm`` the global gradient norm (computed from the flat
    mean buckets, ``psum`` over the model axes) follows; with
    ``ts.metrics_compression`` the per-bucket
    :class:`~repro.obs.metrics.CompressionMetrics` pytree (leaves stacked
    per data peer) is the last output:
    ``sync_fn(grads, key[, live][, ef][, tstate]) ->
    (mean[, new_ef][, new_tstate][, gnorm][, metrics])``.

    Migration note (elastic): with ``ts.elastic`` set the replicated
    ``(n_dp,)`` float live mask is a positional input directly after the
    key — callers holding the raw sync fn must thread it like the key
    (``make_train_step`` computes it in-graph from the step counter).

    Collective accounting: the compression metrics share ONE vectorized
    ``psum`` over the model axes with the gnorm scalar, so enabling them
    never changes the traced collective count; with both metrics off the
    sync body is byte-identical to the metrics-free graph.
    """
    dp = sharding.manual_axes(mesh)
    model_axes = tuple(a for a in mesh.axis_names if a not in dp)
    n_model = 1
    for a in model_axes:
        n_model *= mesh.shape[a]

    def in_spec(x, spec):
        return P(dp, *_auto_only_entries(spec, mesh))

    def out_spec(x, spec):
        return P(*_auto_only_entries(spec, mesh))

    g_in = _tree_map_with_specs(in_spec, grads_like, pspecs)
    g_out = _tree_map_with_specs(out_spec, grads_like, pspecs)

    def sync(stacked, key, *extras):
        idx = 0
        ef = tstate = live = None
        if ts.elastic is not None:
            # the replicated (n_dp,) live mask rides the signature like the
            # key: computed in-graph by the caller (_step), no collective
            live, idx = extras[idx], idx + 1
        if ts.error_feedback:
            ef, idx = extras[idx], idx + 1
        if ts.adaptive is not None:
            tstate = extras[idx]
        leaves, treedef = jax.tree.flatten(stacked)
        vals = [g[0] for g in leaves]
        if ts.bucket_mb > 0:
            t_in = None if tstate is None else jax.tree.map(lambda x: x[0], tstate)
            ef_in = None if ef is None else [e[0] for e in ef]
            out, resid, new_t, gsrc, cm = _sync_buckets(ts, vals, key, dp, ef_in, t_in,
                                                        live)
        else:
            out = [_sync_leaf(ts, g, jax.random.fold_in(key, i), dp)
                   for i, g in enumerate(vals)]
            resid, new_t, gsrc, cm = None, None, out, None
        result = [jax.tree.unflatten(treedef, out)]
        if ts.error_feedback:
            result.append(tuple(r[None] for r in resid))
        if ts.adaptive is not None:
            result.append(jax.tree.map(lambda x: x[None], new_t))
        gsq = None
        if ts.metrics_gnorm:
            gsq = sum(jnp.sum(jnp.square(m.astype(jnp.float32))) for m in gsrc)
        if cm is not None:
            # One fused model-axis psum for the metric sums AND the gnorm
            # scalar: the collective count matches the metrics-off graph.
            sums, static = cm
            vec = sums.reshape(-1)
            if gsq is not None:
                vec = jnp.concatenate([vec, gsq[None]])
            if model_axes:
                vec = jax.lax.psum(vec, model_axes)
            if gsq is not None:
                gsq, vec = vec[-1], vec[:-1]
            cm = obs_metrics.finalize(vec.reshape(sums.shape), static, n_model)
        elif gsq is not None and model_axes:
            gsq = jax.lax.psum(gsq, model_axes)
        if gsq is not None:
            result.append(jnp.sqrt(gsq))
        if cm is not None:
            result.append(jax.tree.map(lambda x: x[None], cm))
        return tuple(result) if len(result) > 1 else result[0]

    in_specs = [g_in, P()]
    out_specs = [g_out]
    if ts.elastic is not None:
        in_specs.append(P())  # the replicated live mask
    if ts.error_feedback:
        in_specs.append(ef_bucket_spec(mesh))
        out_specs.append(ef_bucket_spec(mesh))
    if ts.adaptive is not None:
        t_spec = jax.tree.map(lambda _: P(dp), adaptive_telemetry.init_telemetry(1))
        in_specs.append(t_spec)
        out_specs.append(t_spec)
    if ts.metrics_gnorm:
        out_specs.append(P())
    if ts.metrics_compression:
        out_specs.append(obs_metrics.CompressionMetrics(
            *(P(dp) for _ in obs_metrics.CompressionMetrics._fields)))
    return compat.shard_map(
        sync, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=tuple(out_specs) if len(out_specs) > 1 else out_specs[0],
        axis_names=set(mesh.axis_names), check_vma=False,
    )


# ---------------------------------------------------------------------------
# Streamed (per-unit) backward schedule
# ---------------------------------------------------------------------------


def _streamed_loss_and_grads(cfg, full_params, batch):
    """Layer-streamed loss + grads: forward scan saving unit inputs, reverse
    scan of per-unit VJPs.  Same math as ``grad(loss_fn)``, but only one
    unit's backward graph is live at a time."""
    outer = transformer.outer_params(full_params)
    positions = transformer._positions_for(cfg, batch)

    h0, embed_vjp = jax.vjp(lambda o: transformer.embed_fn(cfg, o, batch), outer)

    def fwd(h, p_u):
        h2, aux = transformer.unit_fn(cfg, p_u, h, positions)
        return h2, (h, aux)

    h_final, (h_ins, auxs) = jax.lax.scan(fwd, h0, full_params["blocks"])
    aux_total = jnp.sum(auxs)

    xent, head_vjp = jax.vjp(lambda o, h: transformer.head_fn(cfg, o, h, batch), outer, h_final)
    loss = xent + transformer.AUX_LOSS_WEIGHT * aux_total
    g_outer_head, g_h = head_vjp(jnp.float32(1.0))

    def bwd(g_h_c, inp):
        p_u, h_in = inp
        _, unit_vjp = jax.vjp(lambda p, h: transformer.unit_fn(cfg, p, h, positions), p_u, h_in)
        g_p, g_h_in = unit_vjp((g_h_c, jnp.float32(transformer.AUX_LOSS_WEIGHT)))
        return g_h_in, g_p

    g_h0, g_blocks = jax.lax.scan(bwd, g_h, (full_params["blocks"], h_ins), reverse=True)
    (g_outer_embed,) = embed_vjp(g_h0)
    g_outer = jax.tree.map(jnp.add, g_outer_head, g_outer_embed)
    grads = dict(g_outer)
    grads["blocks"] = g_blocks
    return loss, grads


# ---------------------------------------------------------------------------
# make_train_step
# ---------------------------------------------------------------------------


def _client_batch(batch: Any, n_clients: int) -> tuple[Any, Any]:
    """Split the global batch into per-client slices + the vmap in_axes."""

    def split(x, batch_dim: int):
        if x is None:
            return None
        b = x.shape[batch_dim]
        return x.reshape(x.shape[:batch_dim] + (n_clients, b // n_clients) + x.shape[batch_dim + 1:])

    pos_dim = 1 if (batch.positions is not None and batch.positions.ndim == 3) else 0
    split_batch = transformer.Batch(
        tokens=split(batch.tokens, 0),
        labels=split(batch.labels, 0),
        positions=split(batch.positions, pos_dim),
        patches=split(batch.patches, 0),
        frames=split(batch.frames, 0),
    )
    axes = transformer.Batch(
        tokens=0, labels=0,
        positions=(pos_dim if batch.positions is not None else None),
        patches=0 if batch.patches is not None else None,
        frames=0 if batch.frames is not None else None,
    )
    return split_batch, axes


def make_train_step(
    cfg,
    mesh,
    logical: Any,
    opt: Optimizer,
    ts: TrainStepConfig,
    batch: Any,
    opt_state_like: Any = None,
    params_like: Any = None,
):
    """Build ``(step_fn, pspecs)`` for one training configuration.

    ``step_fn(params, opt_state, batch, step) -> (params, opt_state, metrics)``
    with ``metrics = {"loss": (n_dp,), "gnorm": (n_dp,)}`` (global values,
    replicated per data shard; ``gnorm`` is computed from the flat mean
    buckets inside the sync region and omitted under
    ``ts.metrics_gnorm=False``).  ``pspecs`` is the parameter PartitionSpec
    tree the caller uses for ``device_put``.

    Metrics contract (pinned by ``tests/test_obs.py``): ``metrics["loss"]``
    is ALWAYS shape ``(max(n_dp, 1),)`` float32, under every sync mode;
    ``metrics["gnorm"]`` has the same shape/dtype and is present iff
    ``ts.metrics_gnorm``.  With ``ts.metrics_compression`` (and a mesh with
    data axes) ``metrics["compression"]`` is a
    :class:`repro.obs.metrics.CompressionMetrics` pytree with
    ``(n_dp, n_buckets)`` leaves — row ``j`` is data peer ``j``'s own
    encode, model-shard reduced inside the sync region at zero extra
    collective cost (the reduction shares the gnorm psum).

    With ``ts.error_feedback`` the bucket-resident EF residual is an
    explicit extra pytree in the step signature — ``step_fn(params,
    opt_state, ef_state, batch, step) -> (params, opt_state, ef_state,
    metrics)`` — initialized with :func:`init_ef_state(params_like, mesh,
    pspecs, ts)`.  With ``ts.adaptive`` the telemetry state is one
    more explicit pytree in the slot after the EF residual (or in its place
    when EF is off) — ``step_fn(params, opt_state[, ef_state], tstate,
    batch, step) -> (params, opt_state[, ef_state], tstate, metrics)`` —
    initialized with :func:`init_telemetry_state`.
    """
    if params_like is None:
        # repro: allow REPRO204 (eval_shape aval-only trace; value never used)
        params_like = jax.eval_shape(lambda: transformer.init_lm(jax.random.key(0), cfg)[0])
    if opt_state_like is None:
        opt_state_like = jax.eval_shape(opt.init, params_like)

    pspecs = sharding.param_pspecs(logical, mesh, cfg.fsdp, params_like)
    dp = sharding.manual_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    B = batch.tokens.shape[0]
    if dp and B % n_dp:
        raise ValueError(
            f"global batch {B} must be divisible by the {n_dp} data shards of mesh "
            f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")
    n_clients = n_dp if dp else 1

    rules = sharding.activation_rules(mesh, manual_data=True)
    o_specs = _opt_specs(opt_state_like, params_like, pspecs)
    grads_like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n_clients,) + x.shape, jnp.float32), params_like)
    if ts.error_feedback and not dp:
        raise ValueError("error_feedback needs data-parallel mesh axes (the sync path)")
    sync_fn = _make_sync_fn(ts, mesh, pspecs, grads_like) if dp else None
    streamed = ts.streamed and not cfg.enc_dec

    def constrain(tree, spec_tree):
        return _tree_map_with_specs(
            lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
            tree, spec_tree)

    def constrain_client_grads(grads):
        # One client per data shard on axis 0; keep each leaf's model-parallel
        # sharding (same entries the sync shard_map's in_specs use) so the
        # codec quantizes model-local slices without a pre-sync all-gather.
        def one(g, spec):
            entries = _auto_only_entries(spec, mesh)
            return jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, P(dp if dp else None, *entries)))

        return _tree_map_with_specs(one, grads, pspecs)

    def constrain_ef(ef):
        # Bucket-resident EF state: every bucket array shares one spec (the
        # sync shard_map's in/out spec), pinned so the residual stays put
        # between steps.
        sh = NamedSharding(mesh, ef_bucket_spec(mesh))
        return tuple(jax.lax.with_sharding_constraint(e, sh) for e in ef)

    adaptive = ts.adaptive is not None
    if adaptive and not dp:
        raise ValueError("adaptive telemetry needs data-parallel mesh axes (the sync path)")

    def _step(params, opt_state, ef_state, tstate, batch_g, step):
        with sharding.axis_rules(mesh, rules):
            cbatch, caxes = _client_batch(batch_g, n_clients)

            def one_client(p, b):
                if streamed:
                    return _streamed_loss_and_grads(cfg, p, b)
                return jax.value_and_grad(lambda q: transformer.loss_fn(cfg, q, b))(p)

            losses, grads = jax.vmap(one_client, in_axes=(None, caxes))(params, cbatch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            # pin one client per data shard before the manual sync region
            grads = constrain_client_grads(grads)
            key = jax.random.fold_in(jax.random.key(_KEY_SEED), step)
            new_ef, new_t, gnorm, cmetrics = ef_state, tstate, None, None
            live = None
            if ts.elastic is not None and sync_fn is not None:
                live = live_mask(ts.elastic, step, n_dp)
            if sync_fn is not None:
                args = [grads, key]
                if live is not None:
                    args.append(live)
                if ts.error_feedback:
                    # bucket-resident EF state rides straight into the sync
                    # shard_map — no leaf-spec constraint round-trip
                    args.append(ef_state)
                if adaptive:
                    args.append(tstate)
                res = sync_fn(*args)
                n_extra = (int(ts.error_feedback) + int(adaptive)
                           + int(ts.metrics_gnorm) + int(ts.metrics_compression))
                if n_extra:
                    res = list(res)
                    g_mean = res.pop(0)
                    if ts.error_feedback:
                        new_ef = constrain_ef(res.pop(0))
                    if adaptive:
                        new_t = res.pop(0)
                    if ts.metrics_gnorm:
                        gnorm = res.pop(0)
                    if ts.metrics_compression:
                        cmetrics = res.pop(0)
                else:
                    g_mean = res
            else:
                g_mean = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
            if ts.metrics_gnorm and gnorm is None:
                gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(g_mean)))
            with jax.named_scope("obs.optimizer"):
                new_params, new_opt = opt.update(params, g_mean, opt_state, step)
            new_params = constrain(new_params, pspecs)
            new_opt = constrain(new_opt, o_specs)
        loss = jnp.mean(losses)
        metrics = {"loss": jnp.full((max(n_dp, 1),), loss, jnp.float32)}
        if ts.metrics_gnorm:
            metrics["gnorm"] = jnp.full((max(n_dp, 1),), gnorm, jnp.float32)
        if cmetrics is not None:
            metrics["compression"] = cmetrics
        if live is not None:
            metrics["live"] = live
            metrics["live_count"] = jnp.full((max(n_dp, 1),), jnp.sum(live), jnp.float32)
        return new_params, new_opt, new_ef, new_t, metrics

    if ts.error_feedback and adaptive:
        @jax.jit
        def step_fn(params, opt_state, ef_state, tstate, batch_g, step):
            return _step(params, opt_state, ef_state, tstate, batch_g, step)
    elif ts.error_feedback:
        @jax.jit
        def step_fn(params, opt_state, ef_state, batch_g, step):
            p, o, e, _, m = _step(params, opt_state, ef_state, None, batch_g, step)
            return p, o, e, m
    elif adaptive:
        @jax.jit
        def step_fn(params, opt_state, tstate, batch_g, step):
            p, o, _, t, m = _step(params, opt_state, None, tstate, batch_g, step)
            return p, o, t, m
    else:
        @jax.jit
        def step_fn(params, opt_state, batch_g, step):
            p, o, _, _, m = _step(params, opt_state, None, None, batch_g, step)
            return p, o, m

    return step_fn, pspecs


def init_ef_state(params_like: Any, mesh, pspecs: Any, ts: TrainStepConfig) -> Any:
    """Zero **bucket-resident** EF residual.

    One stacked fp32 array per codec bucket: axis 0 is the client (data/pod
    shard) row, axis 1 concatenates the model shards' local buckets
    (:func:`local_bucket_sizes` each).  This is exactly the layout the
    fused encode's residual comes back in, so the state round-trips the
    step signature with zero per-step reshaping — the pre-refactor leaf
    pytree (``init_ef_state(params_like, mesh)``) required a
    ``bucket_concat``/``bucket_split`` plus a leaf-spec constraint round
    trip every step.  Callers migrating from that layout now pass the
    ``pspecs`` returned by :func:`make_train_step` and the step's
    ``TrainStepConfig`` (mirroring :func:`init_telemetry_state`).
    """
    sizes = local_bucket_sizes(params_like, mesh, pspecs, ts)
    # Rank-based codecs carry extra per-shard state (e.g. the warm-started
    # powersgd Q factor) appended after the residual; quantizer buckets keep
    # their exact pre-registry row width.  The fp16 tier rewrite must be
    # applied here too so the rows match what the sync region splits off.
    plan = size_adaptive_plan(ts.compressor, ts.bits_plan, sizes, ts.fp16_threshold)
    state_sizes = sc.bucket_state_sizes(ts.compressor, sizes, plan)
    dp = sharding.manual_axes(mesh)
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    n_model = 1
    for a in mesh.axis_names:
        if a not in dp:
            n_model *= mesh.shape[a]
    return tuple(jnp.zeros((max(n, 1), n_model * s), jnp.float32) for s in state_sizes)


def local_bucket_sizes(params_like: Any, mesh, pspecs: Any, ts: TrainStepConfig) -> tuple[int, ...]:
    """Element counts of the codec's buckets as seen *inside* the sync region.

    Reproduces the trace-time bucket plan of :func:`_sync_buckets`: each
    gradient leaf is shrunk to its model-parallel local shard (the manual
    data/pod axes are the peer axis, not a size divisor) and the local sizes
    are coalesced by ``core.compressors.plan_buckets``.  The adaptive
    controller sizes its telemetry state and bit plans from this.
    """
    leaves = jax.tree.leaves(params_like)
    specs = jax.tree.leaves(pspecs, is_leaf=lambda s: isinstance(s, P))
    sizes = []
    for x, spec in zip(leaves, specs):
        entries = _auto_only_entries(spec, mesh)
        size = 1
        for d, dim in enumerate(tuple(x.shape)):
            axes = entries[d] if d < len(entries) else None
            axes = axes if isinstance(axes, tuple) else (axes,) if axes is not None else ()
            div = 1
            for a in axes:
                div *= mesh.shape[a]
            size *= dim // div
        sizes.append(size)
    return compressors.plan_buckets(sizes, ts.bucket_elements).sizes


def init_telemetry_state(params_like: Any, mesh, pspecs: Any, ts: TrainStepConfig) -> Any:
    """Zero telemetry: one stacked row per client over the bucket-plan-sized
    :class:`repro.adaptive.TelemetryState` (mirrors :func:`init_ef_state`)."""
    n_buckets = len(local_bucket_sizes(params_like, mesh, pspecs, ts))
    dp = sharding.manual_axes(mesh)
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    t0 = adaptive_telemetry.init_telemetry(n_buckets)
    return jax.tree.map(lambda x: jnp.tile(x[None], (max(n, 1),) + (1,) * x.ndim), t0)
