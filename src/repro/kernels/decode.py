"""Fused unpack → dequantize → peer-reduce Pallas kernels for decode.

The decode half of every bucketed collective receives, per peer, a row of
packed uint32 wire words plus that peer's (s+1,) codebook, and needs either

- the **peer mean** (ring-mean / reduce-scatter sites): one (m,) fp32 vector
  averaging all peers' dequantized tensors, or
- the **peer concatenation** (the all-gather phase-2 sites): peer j's chunk
  decoded into its own output segment.

The pre-existing path (``vmap(unpack_codes)`` + ``jnp.take`` + ``jnp.mean``)
materializes the full (n_peers, m) int32 code tensor *and* the (n_peers, m)
fp32 value tensor in HBM before reducing — O(n_peers·m) traffic for an (m,)
result.  These kernels stream one (BLOCK_ROWS, 4·bits)-word tile per peer
through VMEM, unpack it with the bit-plane arithmetic inverse of
``quantize._pack_block``, dequantize against that peer's codebook, and
accumulate straight into the output tile; the unpacked codes never leave
VMEM.

Grid: ``(row_blocks, n_peers)`` with the peer axis innermost, so the output
tile for one row block stays resident while every peer's contribution is
folded in (zero-init at peer 0, divide by n at the last peer — the mean is a
*sequential* peer accumulation, which the ``ref`` oracles and the
shard_map-safe jnp fallbacks reproduce op-for-op: bit-exact for the
codebook variants, whose dequant is an exact one-hot lookup; ulp-level FMA
discretion remains for the uniform multiply-add dequant).

Word layout matches ``core.quantizers.pack_codes``: flat element i lives in
group ``g = i // 32``, lane ``i % 32``; group g's ``bits`` bit-plane words
occupy word columns ``[g·bits, (g+1)·bits)``.  Reshaped to the kernel's
(rows, 128) element tiling that is exactly (rows, 4·bits) words per row.

Tiling: BLOCK_ROWS=128 for the uniform kernels (working set ≈ 0.5 MB);
BLOCK_ROWS=64 for the codebook kernels, whose one-hot (block_elems, s+1)
dequant matmul on the MXU peaks at 8 MB for b=8 (s+1=256) and well under
1 MB at the paper-default b=3.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 128            # uniform decode tiles
BLOCK_ROWS_CODEBOOK = 64    # bounds the one-hot dequant matmul at s+1=256


def words_per_row(bits: int) -> int:
    """uint32 wire words per (128,) element row: 4 groups of 32 × bit-planes."""
    return (LANES // 32) * bits


def _unpack_block(words: jax.Array, bits: int) -> jax.Array:
    """(BM, 4·bits) int32 bit-plane words -> (BM, 128) int32 codes.

    Inverse of ``quantize._pack_block``: lane l of word column q·bits+j holds
    bit j of element 32q+l.  Arithmetic vs logical shift is irrelevant under
    the &1 mask, so int32 words decode the uint32 wire exactly.
    """
    bm = words.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (bm, 32), 1)
    cols = []
    for q in range(LANES // 32):
        code = jnp.zeros((bm, 32), jnp.int32)
        for j in range(bits):
            w = words[:, q * bits + j][:, None]                   # (BM, 1)
            code = code + (((w >> lane) & 1) << j)
        cols.append(code)
    return jnp.concatenate(cols, axis=1)


def _uniform_vals(words_ref, alpha_ref, *, s: int, bits: int) -> jax.Array:
    codes = _unpack_block(words_ref[0], bits).astype(jnp.float32)
    alpha = alpha_ref[0, 0]
    step = 2.0 * alpha / s
    return codes * step - alpha


def _codebook_vals(words_ref, levels_ref, *, s: int, bits: int) -> jax.Array:
    levels = levels_ref[0]                                        # (s+1,)
    codes = _unpack_block(words_ref[0], bits)
    bm = codes.shape[0]
    flat = codes.reshape(bm * LANES).astype(jnp.float32)
    # Dequant as a one-hot matmul on the MXU (no gathers on TPU); each row of
    # the one-hot has exactly one 1, so the product is an exact table lookup.
    iota = jax.lax.broadcasted_iota(jnp.float32, (bm * LANES, s + 1), 1)
    onehot = (iota == flat[:, None]).astype(jnp.float32)
    return (onehot @ levels).reshape(bm, LANES)


def _reduce_tail(out_ref, vals: jax.Array, n_peers: int) -> None:
    """Accumulate one peer's dequantized tile; mean at the last peer."""
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = out_ref[...] + vals

    @pl.when(p == n_peers - 1)
    def _():
        out_ref[...] = out_ref[...] / n_peers


def _uniform_decode_reduce_kernel(words_ref, alpha_ref, out_ref, *, s, bits, n_peers):
    _reduce_tail(out_ref, _uniform_vals(words_ref, alpha_ref, s=s, bits=bits), n_peers)


def _codebook_decode_reduce_kernel(words_ref, levels_ref, out_ref, *, s, bits, n_peers):
    _reduce_tail(out_ref, _codebook_vals(words_ref, levels_ref, s=s, bits=bits), n_peers)


def _uniform_decode_rows_kernel(words_ref, alpha_ref, out_ref, *, s, bits):
    out_ref[0] = _uniform_vals(words_ref, alpha_ref, s=s, bits=bits)


def _codebook_decode_rows_kernel(words_ref, levels_ref, out_ref, *, s, bits):
    out_ref[0] = _codebook_vals(words_ref, levels_ref, s=s, bits=bits)


def _call(kernel, words3: jax.Array, meta2: jax.Array, *, bits: int, block_rows: int,
          reduce: bool, interpret: bool, **kw) -> jax.Array:
    """Shared pallas_call builder.

    ``words3``: (n_peers, rows_p, 4·bits) int32 with rows_p a multiple of
    ``block_rows``; ``meta2``: (n_peers, k) fp32 per-peer codebook operand
    ((n, 1) alphas or (n, s+1) levels).  ``reduce=True`` accumulates the peer
    mean into one (rows_p, 128) tile set; ``reduce=False`` writes each peer's
    decode into its own (rows_p, 128) band of a (n_peers, rows_p, 128) output.
    """
    n_peers, rows_p, wc = words3.shape
    assert wc == words_per_row(bits) and rows_p % block_rows == 0
    nblocks = rows_p // block_rows
    grid = (nblocks, n_peers)
    if reduce:
        out_spec = pl.BlockSpec((block_rows, LANES), lambda i, p: (i, 0))
        out_shape = jax.ShapeDtypeStruct((rows_p, LANES), jnp.float32)
        kw = dict(kw, n_peers=n_peers)
    else:
        out_spec = pl.BlockSpec((1, block_rows, LANES), lambda i, p: (p, i, 0))
        out_shape = jax.ShapeDtypeStruct((n_peers, rows_p, LANES), jnp.float32)
    return pl.pallas_call(
        functools.partial(kernel, bits=bits, **kw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_rows, wc), lambda i, p: (p, i, 0)),
            pl.BlockSpec((1, meta2.shape[1]), lambda i, p: (p, 0)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(words3, meta2)


def uniform_decode_reduce_3d(words3, alphas2, *, bits: int, interpret: bool) -> jax.Array:
    s = 2**bits - 1
    return _call(_uniform_decode_reduce_kernel, words3, alphas2, bits=bits,
                 block_rows=BLOCK_ROWS, reduce=True, interpret=interpret, s=s)


def codebook_decode_reduce_3d(words3, levels2, *, bits: int, interpret: bool) -> jax.Array:
    s = levels2.shape[1] - 1
    return _call(_codebook_decode_reduce_kernel, words3, levels2, bits=bits,
                 block_rows=BLOCK_ROWS_CODEBOOK, reduce=True, interpret=interpret, s=s)


def uniform_decode_rows_3d(words3, alphas2, *, bits: int, interpret: bool) -> jax.Array:
    s = 2**bits - 1
    return _call(_uniform_decode_rows_kernel, words3, alphas2, bits=bits,
                 block_rows=BLOCK_ROWS, reduce=False, interpret=interpret, s=s)


def codebook_decode_rows_3d(words3, levels2, *, bits: int, interpret: bool) -> jax.Array:
    s = levels2.shape[1] - 1
    return _call(_codebook_decode_rows_kernel, words3, levels2, bits=bits,
                 block_rows=BLOCK_ROWS_CODEBOOK, reduce=False, interpret=interpret, s=s)


__all__ = [
    "BLOCK_ROWS",
    "BLOCK_ROWS_CODEBOOK",
    "codebook_decode_reduce_3d",
    "codebook_decode_rows_3d",
    "uniform_decode_reduce_3d",
    "uniform_decode_rows_3d",
    "words_per_row",
]
