"""Pallas TPU kernel: Gram–Schmidt orthogonalization of the PowerSGD P factor.

PowerSGD's power iteration needs ``P̂ = orth(M @ Q)`` before the back-
projection ``Qn = Mᵀ @ P̂`` — a (rows, r) tall-skinny matrix with r ≤ 64
columns.  Modified Gram–Schmidt over so few columns is a chain of
column-wise dot products and AXPYs: each column is one (rows,) vector
reduction plus a rank-1 update, all VPU work over a tile that fits VMEM
whole (rows ≤ 64K at r ≤ 128 ⇒ ≤ 32 MB is far above real buckets; the
4-MB-bucket default gives rows ≈ 1024 ⇒ 0.5 MB).  The kernel runs the whole
factor in one grid step — no cross-block reduction tree, so the float op
order is a single static unroll.

Layout: columns are padded to the 128-lane register width and rows to the
8-sublane fp32 tile, zeros beyond (rows, r).  Zero padding is invariant
under the loop (projections and normalizations of zero columns stay zero —
`jax.lax.rsqrt(eps)` times a zero vector), so the wrapper just slices the
(rows, r) corner back out.

``_gs_padded`` is the single source of the loop for both the kernel body
and the ``kernels.ref`` oracle: interpret mode executes the identical jnp
op sequence, so kernel and oracle agree to fusion-level rounding (XLA may
fuse the reductions differently inside the interpreted ``pallas_call``;
``tests/test_lowrank.py`` pins the agreement at float32 ULP scale).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8
_EPS = 1e-30


def _gs_padded(p: jax.Array, r: int) -> jax.Array:
    """Modified Gram–Schmidt over the first ``r`` columns of a zero-padded
    (rows_p, LANES) tile; columns ≥ r pass through as zeros.

    MGS (normalize column j, then project it out of all later columns)
    with one reorthogonalization sweep per pivot ("twice is enough",
    Parlett/Kahan), rather than classical GS: on rank-deficient inputs —
    routine for PowerSGD, where ``M @ Q`` has at most rank(M) independent
    columns — single-pass GS leaves the near-dependent late columns with
    O(1) overlap against the earlier basis, and their back-projection
    ``Mᵀ @ P̂`` corrupts the reconstruction; the second projection pass
    drives the overlap back to working precision so surplus columns
    contribute ~0.  Zero columns degrade to zero vectors (0 · rsqrt(eps)).
    """
    cols = []
    rest = p[:, :r]
    for _ in range(r):
        v = rest[:, 0:1]
        for u in cols:  # reorthogonalize the pivot against the basis
            v = v - jnp.sum(u * v) * u
        v = v * jax.lax.rsqrt(jnp.maximum(jnp.sum(v * v), _EPS))
        cols.append(v)
        rest = rest[:, 1:]
        if rest.shape[1]:
            rest = rest - jnp.sum(v * rest, axis=0, keepdims=True) * v
    pad = p.shape[1] - r
    if pad:
        cols.append(jnp.zeros((p.shape[0], pad), jnp.float32))
    return jnp.concatenate(cols, axis=1)


def _gs_kernel(p_ref, out_ref, *, r: int):
    out_ref[...] = _gs_padded(p_ref[...], r)


def orthogonalize_2d(p: jax.Array, *, r: int, interpret: bool) -> jax.Array:
    """p: (rows_p, LANES) fp32, rows_p a multiple of 8, columns ≥ r zero.
    Returns the same tile with columns [0, r) orthonormalized."""
    return pl.pallas_call(
        lambda p_ref, out_ref: _gs_kernel(p_ref, out_ref, r=r),
        out_shape=jax.ShapeDtypeStruct(p.shape, jnp.float32),
        interpret=interpret,
    )(p)
