"""Fused Pallas bucket-statistics kernel for the adaptive telemetry pass.

One VMEM pass over a flat fp32 bucket produces everything the online tail
estimator needs — replacing the per-step full sort (``jnp.quantile``) the
offline fit uses:

- per-bin counts of a 128-bin log2-spaced |g| histogram (bin 0 catches
  underflow/zeros, bin K-1 catches overflow);
- per-bin sums of ln|g| (the Hill-estimator accumulator: the tail's
  ``sum log(g_j/g_min)`` is a suffix sum of these minus ``n_tail ln g_min``);
- max |g|, sum g, sum g² (scale envelope + EMA moments).

Tiling matches the quantize kernels: (rows, 128) fp32 input blocked
(BLOCK_ROWS, 128) per grid step; every grid step accumulates into the same
(8, 128) output tile (row 0 counts, row 1 log-sums, row 2 max, row 3 sum,
row 4 sum-of-squares — max rows combine with ``maximum``, the rest add).
The per-block histogram is built from a one-hot (block_elems, 128) compare
matrix reduced on the MXU; BLOCK_ROWS=64 keeps that matrix at 4 MB.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 64          # 64·128 elems/block -> (8192, 128) one-hot = 4 MB VMEM

NUM_BINS = 128           # == LANES so one output row holds the histogram
LOG2_LO = -24.0          # |g| below 2^-24 (incl. zeros) lands in bin 0
LOG2_HI = 8.0            # |g| above 2^8 lands in bin NUM_BINS-1
STATS_ROWS = 8           # output tile rows (5 used, see module docstring)

_LN2 = math.log(2.0)
_TINY = 1e-30            # ln argument floor for exact zeros


def bin_edges() -> jax.Array:
    """(NUM_BINS+1,) |g| bin edges: edges[0]=0, edges[k]=2^(LO+k·w) else.

    Bin k covers [edges[k], edges[k+1]); the telemetry quantile snaps to an
    upper edge so tail sums over whole bins are exact w.r.t. the histogram.
    """
    w = (LOG2_HI - LOG2_LO) / NUM_BINS
    e = jnp.exp2(LOG2_LO + w * jnp.arange(NUM_BINS + 1, dtype=jnp.float32))
    return e.at[0].set(0.0)


def _block_stats(g: jax.Array, valid: jax.Array) -> jax.Array:
    """(BM, 128) fp32 + validity mask -> (STATS_ROWS, NUM_BINS) partials."""
    bm = g.shape[0]
    n = bm * LANES
    vmask = valid.astype(jnp.float32)
    gabs = jnp.abs(g) * vmask
    lnab = jnp.log(jnp.maximum(gabs, _TINY))
    w = (LOG2_HI - LOG2_LO) / NUM_BINS
    b = jnp.floor((lnab / _LN2 - LOG2_LO) / w)
    b = jnp.clip(b, 0.0, NUM_BINS - 1.0)
    b = jnp.where(valid, b, -1.0)                     # padding matches no bin
    flat_b = b.reshape(n)
    iota = jax.lax.broadcasted_iota(jnp.float32, (n, NUM_BINS), 1)
    onehot = (iota == flat_b[:, None]).astype(jnp.float32)
    counts = (jnp.ones((1, n), jnp.float32) @ onehot)             # (1, K)
    logsum = ((lnab * vmask).reshape(1, n) @ onehot)              # (1, K)
    gv = g * vmask
    gmax = jnp.max(gabs)
    gsum = jnp.sum(gv)
    gsq = jnp.sum(gv * gv)
    return jnp.concatenate(
        [
            counts,
            logsum,
            jnp.full((1, NUM_BINS), gmax, jnp.float32),
            jnp.full((1, NUM_BINS), gsum, jnp.float32),
            jnp.full((1, NUM_BINS), gsq, jnp.float32),
            jnp.zeros((STATS_ROWS - 5, NUM_BINS), jnp.float32),
        ],
        axis=0,
    )


def _merge(acc: jax.Array, part: jax.Array) -> jax.Array:
    """Combine two stats tiles: row 2 (max) joins with maximum, the rest add."""
    row = jax.lax.broadcasted_iota(jnp.int32, (STATS_ROWS, NUM_BINS), 0)
    return jnp.where(row == 2, jnp.maximum(acc, part), acc + part)


def _bucket_stats_kernel(n_ref, g_ref, out_ref):
    g = g_ref[...]
    bm = g.shape[0]
    base = pl.program_id(0) * bm
    row = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 0) + base
    col = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 1)
    valid = row * LANES + col < n_ref[0]
    part = _block_stats(g, valid)

    @pl.when(pl.program_id(0) == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = _merge(out_ref[...], part)


def bucket_stats_2d(g: jax.Array, n: int, *, interpret: bool) -> jax.Array:
    """g: (rows, 128) fp32, n true elements -> (STATS_ROWS, NUM_BINS) fp32."""
    rows = g.shape[0]
    grid = (pl.cdiv(rows, BLOCK_ROWS),)
    return pl.pallas_call(
        _bucket_stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=None),       # n: full (1,) operand
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((STATS_ROWS, NUM_BINS), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((STATS_ROWS, NUM_BINS), jnp.float32),
        interpret=interpret,
    )(jnp.asarray([n], jnp.int32), g)


__all__ = [
    "BLOCK_ROWS",
    "LOG2_HI",
    "LOG2_LO",
    "NUM_BINS",
    "STATS_ROWS",
    "bin_edges",
    "bucket_stats_2d",
]
