"""Fused encode-side Pallas kernels: EF-correct→stats and quantize→pack→residual.

The encode half of every bucketed collective used to sweep the bucket bytes
through HBM several times per step — leaf-wise EF add, a telemetry stats
pass, the ``plan()`` statistics pass, encode, a separate bit-pack, the
own-dequantization and the ``corrected − own`` residual subtraction.  These
kernels collapse that pipeline to two VMEM passes:

- :func:`ef_correct_stats_2d` — reads a gradient bucket (and its EF
  residual) once, writes the corrected bucket ``c = g + e`` and accumulates
  the full statistics tile both ``core.compressors.plan_from_stats`` and
  ``adaptive.telemetry`` consume: per-bin counts of the ``kernels.stats``
  128-bin log2-spaced |g| histogram, per-bin ln|g| Hill sums, max |g| and
  the first two moments.  The block statistics and the merge rule are the
  *same functions* the standalone ``kernels.stats`` kernel uses, so the
  plan-relevant rows of ``c``'s stats (counts, log-sums, max) are
  bit-identical to ``bucket_stats_2d(c)``; the moment rows are plain
  reductions with ulp-level fusion discretion.

- :func:`uniform_encode_pack_resid_2d` / :func:`codebook_encode_pack_resid_2d`
  — quantize the corrected bucket, bit-pack the codes into the uint32 wire
  words (``quantize._pack_block`` layout, flattening row-major reproduces
  ``pack_codes`` exactly) and write ``c − dequant(code)`` — the next EF
  residual — in the same tile.  The int code tensor and the dequantized
  ``own`` tensor never reach HBM.  The codebook dequant uses the interval
  endpoints the encode already holds (``levels[code] == hi if up else lo``),
  so the residual is an *exact* match for ``c − take(levels, codes)``; the
  uniform dequant is the usual multiply-add with compiler-discretionary FMA
  (≤ ulp-level slack vs the oracle, same contract as ``kernels.decode``).

- :func:`uniform_encode_pack_2d` / :func:`codebook_encode_pack_2d` — the
  words-only variants for sites that need no residual (the two-phase
  phase-2 re-quantization, the per-leaf codec rows): unlike the PR-2
  ``quantize.*_encode_pack_2d`` kernels they do not write the code tensor
  back to HBM at all.

Tiling matches ``kernels.quantize``: (rows, 128) fp32 blocked
(BLOCK_ROWS, 128) per grid step; the stats kernel uses the smaller
``stats.BLOCK_ROWS`` tile that bounds its one-hot histogram matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import stats as _s
from .quantize import (
    BLOCK_ROWS,
    LANES,
    _mask_tail,
    _pack_block,
    codebook_select,
    uniform_select,
)

__all__ = [
    "codebook_encode_pack_2d",
    "codebook_encode_pack_resid_2d",
    "ef_correct_stats_2d",
    "uniform_encode_pack_2d",
    "uniform_encode_pack_resid_2d",
]


# ---------------------------------------------------------------------------
# One-pass EF correction + statistics
# ---------------------------------------------------------------------------


def _ef_correct_stats_kernel(n_ref, g_ref, e_ref, c_ref, out_ref):
    c = g_ref[...] + e_ref[...]
    c_ref[...] = c
    bm = c.shape[0]
    base = pl.program_id(0) * bm
    row = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 0) + base
    col = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 1)
    valid = row * LANES + col < n_ref[0]
    part = _s._block_stats(c, valid)

    @pl.when(pl.program_id(0) == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = _s._merge(out_ref[...], part)


def ef_correct_stats_2d(
    g: jax.Array, e: jax.Array, n: int, *, interpret: bool
) -> tuple[jax.Array, jax.Array]:
    """g, e: (rows, 128) fp32, n true elements ->
    ((rows, 128) corrected fp32, (STATS_ROWS, NUM_BINS) stats of corrected)."""
    rows = g.shape[0]
    grid = (pl.cdiv(rows, _s.BLOCK_ROWS),)
    return pl.pallas_call(
        _ef_correct_stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=None),       # n: full (1,) operand
            pl.BlockSpec((_s.BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((_s.BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((_s.BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((_s.STATS_ROWS, _s.NUM_BINS), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((_s.STATS_ROWS, _s.NUM_BINS), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray([n], jnp.int32), g, e)


# ---------------------------------------------------------------------------
# Fused quantize -> bit-pack [-> residual]
# ---------------------------------------------------------------------------


def _uniform_encode_pack_kernel(n_ref, alpha_ref, g_ref, rand_ref, words_ref, *, s, bits):
    g = g_ref[...]
    code_f = uniform_select(alpha_ref[0], g, rand_ref[...], s=s)
    codes = _mask_tail(code_f.astype(jnp.int32), n_ref, g.shape[0])
    words_ref[...] = _pack_block(codes, bits)


def _uniform_encode_pack_resid_kernel(n_ref, alpha_ref, g_ref, rand_ref, words_ref,
                                      resid_ref, *, s, bits):
    alpha = alpha_ref[0]
    g = g_ref[...]
    code_f = uniform_select(alpha, g, rand_ref[...], s=s)
    codes = _mask_tail(code_f.astype(jnp.int32), n_ref, g.shape[0])
    words_ref[...] = _pack_block(codes, bits)
    step = 2.0 * alpha / s
    resid_ref[...] = g - (codes.astype(jnp.float32) * step - alpha)


def _codebook_encode_pack_kernel(n_ref, g_ref, rand_ref, levels_ref, words_ref, *, s, bits):
    g = g_ref[...]
    code_f, _ = codebook_select(levels_ref[...], g, rand_ref[...], s=s)
    codes = _mask_tail(code_f.reshape(g.shape).astype(jnp.int32), n_ref, g.shape[0])
    words_ref[...] = _pack_block(codes, bits)


def _codebook_encode_pack_resid_kernel(n_ref, g_ref, rand_ref, levels_ref, words_ref,
                                       resid_ref, *, s, bits):
    g = g_ref[...]
    code_f, val = codebook_select(levels_ref[...], g, rand_ref[...], s=s)
    codes = _mask_tail(code_f.reshape(g.shape).astype(jnp.int32), n_ref, g.shape[0])
    words_ref[...] = _pack_block(codes, bits)
    resid_ref[...] = g - val.reshape(g.shape)


def _call_encode(kernel, operands, rows: int, *, bits: int, residual: bool,
                 interpret: bool, **kw):
    """Shared pallas_call builder for the encode-pack kernels.

    ``operands``: ordered list of (array, blocked) pairs — blocked operands
    tile (BLOCK_ROWS, 128); the rest ((1,) scalars / (s+1,) codebooks) ride
    unblocked.  Outputs: the (rows, 4·bits) word tensor, plus the
    (rows, 128) residual when ``residual``.
    """
    wc = (LANES // 32) * bits
    grid = (pl.cdiv(rows, BLOCK_ROWS),)
    in_specs = [
        pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)) if blocked
        else pl.BlockSpec(memory_space=None)
        for _, blocked in operands
    ]
    out_specs = [pl.BlockSpec((BLOCK_ROWS, wc), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((rows, wc), jnp.int32)]
    if residual:
        out_specs.append(pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((rows, LANES), jnp.float32))
    return pl.pallas_call(
        functools.partial(kernel, bits=bits, **kw),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs if residual else out_specs[0],
        out_shape=out_shape if residual else out_shape[0],
        interpret=interpret,
    )(*(x for x, _ in operands))


def uniform_encode_pack_2d(g, rand, alpha, n: int, *, bits: int, interpret: bool):
    """Fused uniform encode + bit-pack, words only: (rows, 4·bits) int32."""
    s = 2**bits - 1
    ops = [(jnp.asarray([n], jnp.int32), False), (alpha.reshape(1), False),
           (g, True), (rand, True)]
    return _call_encode(_uniform_encode_pack_kernel, ops, g.shape[0],
                        bits=bits, residual=False, interpret=interpret, s=s)


def uniform_encode_pack_resid_2d(g, rand, alpha, n: int, *, bits: int, interpret: bool):
    """Fused uniform encode + bit-pack + residual ``g − dequant(code)``.
    Returns ((rows, 4·bits) int32 words, (rows, 128) fp32 residual)."""
    s = 2**bits - 1
    ops = [(jnp.asarray([n], jnp.int32), False), (alpha.reshape(1), False),
           (g, True), (rand, True)]
    return _call_encode(_uniform_encode_pack_resid_kernel, ops, g.shape[0],
                        bits=bits, residual=True, interpret=interpret, s=s)


def codebook_encode_pack_2d(g, rand, levels, n: int, *, bits: int, interpret: bool):
    """Fused codebook encode + bit-pack, words only."""
    s = levels.shape[0] - 1
    ops = [(jnp.asarray([n], jnp.int32), False), (g, True), (rand, True),
           (levels, False)]
    return _call_encode(_codebook_encode_pack_kernel, ops, g.shape[0],
                        bits=bits, residual=False, interpret=interpret, s=s)


def codebook_encode_pack_resid_2d(g, rand, levels, n: int, *, bits: int, interpret: bool):
    """Fused codebook encode + bit-pack + exact residual."""
    s = levels.shape[0] - 1
    ops = [(jnp.asarray([n], jnp.int32), False), (g, True), (rand, True),
           (levels, False)]
    return _call_encode(_codebook_encode_pack_resid_kernel, ops, g.shape[0],
                        bits=bits, residual=True, interpret=interpret, s=s)
