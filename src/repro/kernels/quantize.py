"""Pallas TPU kernels for the two-stage quantizer hot path.

The paper's per-step hot spot is the element-wise encode/decode over every
gradient element (d can be billions).  On TPU we fuse
truncate → scale → stochastic-round → code  into one VMEM pass.

Tiling: inputs are reshaped to (rows, 128) — the 128-lane register width —
and blocked (BLOCK_ROWS, 128) per grid step.  BLOCK_ROWS=256 keeps the
working set (g + rand + codes + codebook compare matrix) well under VMEM:
uniform:  256·128·(4+4+4) B ≈ 0.4 MB;
codebook: adds a (s+1,) broadcast and two one-hot (256·128, s+1) matmuls on
the MXU at s+1 ≤ 256 ⇒ ≈ 16 MB peak for b=8; b=3 (paper default) ≈ 1 MB.

Codes are emitted as int32 in-kernel (TPU stores are word-aligned; the
wrapper narrows to uint8 / packs to uint32 lanes outside).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 256


def uniform_select(alpha, g: jax.Array, rand: jax.Array, *, s: int) -> jax.Array:
    """Shared uniform stochastic-encode body -> float codes in [0, s].

    The single source of the truncate → scale → floor → stochastic-round
    math for every uniform encode kernel (the plain, the packed, and the
    fused residual variants in ``encode_fused``) — keeping the bit-identical
    wire contract between kernel families in one place.
    """
    scale = s / (2.0 * alpha)
    u = (jnp.clip(g, -alpha, alpha) + alpha) * scale
    k = jnp.clip(jnp.floor(u), 0.0, float(s - 1))
    up = (rand < (u - k)).astype(jnp.float32)
    return jnp.clip(k + up, 0.0, float(s))


def codebook_select(levels: jax.Array, g: jax.Array, rand: jax.Array,
                    *, s: int) -> tuple[jax.Array, jax.Array]:
    """Shared codebook stochastic-encode body.

    Returns ``(codes float (BM·128,), dequant value (BM·128,))``: the
    dequant is the interval endpoint the rounding chose (``hi`` on
    round-up, ``lo`` otherwise), which equals ``levels[code]`` exactly —
    callers that don't need it (the non-residual kernels) leave it dead for
    DCE.  Single source of the compare-count + one-hot lo/hi + pr math for
    every codebook encode kernel.
    """
    alpha = levels[s]
    bm = g.shape[0]
    gt = jnp.clip(g, -alpha, alpha)
    flat = gt.reshape(bm * LANES)
    ge = (flat[:, None] >= levels[None, 1:]).astype(jnp.float32)
    k = jnp.clip(jnp.sum(ge, axis=1), 0.0, float(s - 1))
    iota = jax.lax.broadcasted_iota(jnp.float32, (flat.shape[0], s + 1), 1)
    onehot_lo = (iota == k[:, None]).astype(jnp.float32)
    onehot_hi = (iota == (k[:, None] + 1.0)).astype(jnp.float32)
    lo = onehot_lo @ levels
    hi = onehot_hi @ levels
    pr = (flat - lo) / jnp.maximum(hi - lo, 1e-12)
    up = (rand.reshape(bm * LANES) < pr).astype(jnp.float32)
    return k + up, jnp.where(up > 0.0, hi, lo)


def _uniform_encode_kernel(alpha_ref, g_ref, rand_ref, out_ref, *, s: int):
    out_ref[...] = uniform_select(alpha_ref[0], g_ref[...], rand_ref[...],
                                  s=s).astype(jnp.int32)


def uniform_encode_2d(
    g: jax.Array, rand: jax.Array, alpha: jax.Array, *, bits: int, interpret: bool
) -> jax.Array:
    """g, rand: (rows, 128) float32; returns (rows, 128) int32 codes."""
    rows = g.shape[0]
    s = 2**bits - 1
    grid = (pl.cdiv(rows, BLOCK_ROWS),)
    return pl.pallas_call(
        functools.partial(_uniform_encode_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=None),       # alpha: full (1,) operand
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(alpha.reshape(1), g, rand)


def _uniform_decode_kernel(alpha_ref, codes_ref, out_ref, *, s: int):
    alpha = alpha_ref[0]
    step = 2.0 * alpha / s
    out_ref[...] = codes_ref[...].astype(jnp.float32) * step - alpha


def uniform_decode_2d(
    codes: jax.Array, alpha: jax.Array, *, bits: int, interpret: bool
) -> jax.Array:
    rows = codes.shape[0]
    s = 2**bits - 1
    grid = (pl.cdiv(rows, BLOCK_ROWS),)
    return pl.pallas_call(
        functools.partial(_uniform_decode_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=None),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(alpha.reshape(1), codes)


def _codebook_encode_kernel(g_ref, rand_ref, levels_ref, out_ref, *, s: int):
    # compare-count interval index + one-hot lo/hi matmuls on the MXU (no
    # gathers on TPU) — shared body in codebook_select
    g = g_ref[...]
    code_f, _ = codebook_select(levels_ref[...], g, rand_ref[...], s=s)
    out_ref[...] = code_f.reshape(g.shape).astype(jnp.int32)


def codebook_encode_2d(
    g: jax.Array, rand: jax.Array, levels: jax.Array, *, interpret: bool
) -> jax.Array:
    rows = g.shape[0]
    s = levels.shape[0] - 1
    grid = (pl.cdiv(rows, BLOCK_ROWS),)
    return pl.pallas_call(
        functools.partial(_codebook_encode_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=None),       # levels: full operand
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(g, rand, levels)


# ---------------------------------------------------------------------------
# Fused encode -> bit-pack: codes leave VMEM already packed into uint32 lanes
# (wire layout identical to ``core.quantizers.pack_codes``: group g of 32
# consecutive flat codes -> ``bits`` bit-plane words at [g*bits, (g+1)*bits)).
# ---------------------------------------------------------------------------


def _pack_block(codes: jax.Array, bits: int) -> jax.Array:
    """(BM, 128) int32 codes -> (BM, 4*bits) int32 bit-plane words.

    Column q*bits+j holds bit-plane j of the 32 consecutive codes
    [r*128 + 32q, r*128 + 32q + 32); flattening row-major reproduces the
    ``pack_codes`` word order exactly.
    """
    bm = codes.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (bm, 32), 1)
    cols = []
    for q in range(LANES // 32):
        sub = codes[:, 32 * q:32 * (q + 1)]
        for j in range(bits):
            plane = (sub >> j) & 1
            cols.append(jnp.sum(plane << lane, axis=1, dtype=jnp.int32))
    return jnp.stack(cols, axis=1)


def _mask_tail(codes: jax.Array, n_ref, bm: int) -> jax.Array:
    """Zero codes past the true element count so padding words match
    ``pack_codes``' zero padding bit-for-bit."""
    base = pl.program_id(0) * bm
    row = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 0) + base
    col = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 1)
    return jnp.where(row * LANES + col < n_ref[0], codes, 0)


def _uniform_encode_pack_kernel(n_ref, alpha_ref, g_ref, rand_ref, codes_ref, words_ref,
                                *, s: int, bits: int):
    g = g_ref[...]
    code_f = uniform_select(alpha_ref[0], g, rand_ref[...], s=s)
    codes = _mask_tail(code_f.astype(jnp.int32), n_ref, g.shape[0])
    codes_ref[...] = codes
    words_ref[...] = _pack_block(codes, bits)


def uniform_encode_pack_2d(
    g: jax.Array, rand: jax.Array, alpha: jax.Array, n: int, *, bits: int, interpret: bool
) -> tuple[jax.Array, jax.Array]:
    """Fused uniform encode + bit-pack.  Returns ((rows,128) int32 codes,
    (rows, 4*bits) int32 words)."""
    rows = g.shape[0]
    s = 2**bits - 1
    wc = (LANES // 32) * bits
    grid = (pl.cdiv(rows, BLOCK_ROWS),)
    return pl.pallas_call(
        functools.partial(_uniform_encode_pack_kernel, s=s, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=None),       # n: full (1,) operand
            pl.BlockSpec(memory_space=None),       # alpha: full (1,) operand
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, wc), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((rows, wc), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray([n], jnp.int32), alpha.reshape(1), g, rand)


def _codebook_encode_pack_kernel(n_ref, g_ref, rand_ref, levels_ref, codes_ref, words_ref,
                                 *, s: int, bits: int):
    g = g_ref[...]
    code_f, _ = codebook_select(levels_ref[...], g, rand_ref[...], s=s)
    codes = _mask_tail(code_f.reshape(g.shape).astype(jnp.int32), n_ref, g.shape[0])
    codes_ref[...] = codes
    words_ref[...] = _pack_block(codes, bits)


def codebook_encode_pack_2d(
    g: jax.Array, rand: jax.Array, levels: jax.Array, n: int, *, bits: int, interpret: bool
) -> tuple[jax.Array, jax.Array]:
    """Fused non-uniform encode + bit-pack (codebook variant)."""
    rows = g.shape[0]
    s = levels.shape[0] - 1
    wc = (LANES // 32) * bits
    grid = (pl.cdiv(rows, BLOCK_ROWS),)
    return pl.pallas_call(
        functools.partial(_codebook_encode_pack_kernel, s=s, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=None),       # n: full (1,) operand
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=None),       # levels: full operand
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, wc), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((rows, wc), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray([n], jnp.int32), g, rand, levels)


def _codebook_decode_kernel(codes_ref, levels_ref, out_ref, *, s: int):
    levels = levels_ref[...]
    codes = codes_ref[...].astype(jnp.float32)
    bm = codes.shape[0]
    flat = codes.reshape(bm * LANES)
    iota = jax.lax.broadcasted_iota(jnp.float32, (flat.shape[0], s + 1), 1)
    onehot = (iota == flat[:, None]).astype(jnp.float32)
    out_ref[...] = (onehot @ levels).reshape(bm, LANES)


def codebook_decode_2d(codes: jax.Array, levels: jax.Array, *, interpret: bool) -> jax.Array:
    rows = codes.shape[0]
    s = levels.shape[0] - 1
    grid = (pl.cdiv(rows, BLOCK_ROWS),)
    return pl.pallas_call(
        functools.partial(_codebook_decode_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=None),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(codes, levels)
