"""Pure-jnp oracles for the quantization kernels.

These mirror ``repro.core.quantizers`` but take an explicit uniform-random
array (the kernels consume pre-generated random bits so the Pallas and
reference paths can be compared bit-exactly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantizers import num_levels


def uniform_encode(g: jax.Array, alpha: jax.Array, bits: int, rand: jax.Array) -> jax.Array:
    """Fused truncate + uniform stochastic quantize.  codes in [0, s], uint8.

    code = floor(u) + 1[rand < frac(u)]  with  u = (clip(g, ±α) + α) · s/(2α).
    """
    s = num_levels(bits)
    scale = s / (2.0 * alpha)
    u = (jnp.clip(g, -alpha, alpha) + alpha) * scale
    k = jnp.clip(jnp.floor(u), 0, s - 1)
    frac = u - k
    code = k + (rand < frac).astype(u.dtype)
    return jnp.clip(code, 0, s).astype(jnp.uint8)


def uniform_decode(codes: jax.Array, alpha: jax.Array, bits: int) -> jax.Array:
    s = num_levels(bits)
    step = 2.0 * alpha / s
    return codes.astype(jnp.float32) * step - alpha


def codebook_encode(g: jax.Array, levels: jax.Array, rand: jax.Array) -> jax.Array:
    """Fused truncate + non-uniform stochastic quantize onto ``levels``.

    k = #{j in 1..s : g >= l_j} clipped to s-1;  pr = (g - l_k)/(l_{k+1}-l_k);
    code = k + 1[rand < pr].  Matches quantizers.stochastic_encode given the
    same uniforms.
    """
    s = levels.shape[0] - 1
    alpha = levels[-1]
    gt = jnp.clip(g, -alpha, alpha)
    k = jnp.sum(gt[..., None] >= levels[1:][None, :], axis=-1)
    k = jnp.clip(k, 0, s - 1)
    lo = levels[k]
    hi = levels[k + 1]
    pr = (gt - lo) / jnp.maximum(hi - lo, 1e-12)
    return (k + (rand < pr).astype(k.dtype)).astype(jnp.uint8)


def codebook_decode(codes: jax.Array, levels: jax.Array) -> jax.Array:
    return jnp.take(levels, codes.astype(jnp.int32))
