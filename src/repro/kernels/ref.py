"""Pure-jnp oracles for the quantization kernels.

These mirror ``repro.core.quantizers`` but take an explicit uniform-random
array (the kernels consume pre-generated random bits so the Pallas and
reference paths can be compared bit-exactly).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.quantizers import num_levels


def uniform_encode(g: jax.Array, alpha: jax.Array, bits: int, rand: jax.Array) -> jax.Array:
    """Fused truncate + uniform stochastic quantize.  codes in [0, s], uint8.

    code = floor(u) + 1[rand < frac(u)]  with  u = (clip(g, ±α) + α) · s/(2α).
    """
    s = num_levels(bits)
    scale = s / (2.0 * alpha)
    u = (jnp.clip(g, -alpha, alpha) + alpha) * scale
    k = jnp.clip(jnp.floor(u), 0, s - 1)
    frac = u - k
    code = k + (rand < frac).astype(u.dtype)
    return jnp.clip(code, 0, s).astype(jnp.uint8)


def uniform_decode(codes: jax.Array, alpha: jax.Array, bits: int) -> jax.Array:
    s = num_levels(bits)
    step = 2.0 * alpha / s
    return codes.astype(jnp.float32) * step - alpha


def codebook_encode(g: jax.Array, levels: jax.Array, rand: jax.Array) -> jax.Array:
    """Fused truncate + non-uniform stochastic quantize onto ``levels``.

    k = #{j in 1..s : g >= l_j} clipped to s-1;  pr = (g - l_k)/(l_{k+1}-l_k);
    code = k + 1[rand < pr].  Matches quantizers.stochastic_encode given the
    same uniforms.
    """
    s = levels.shape[0] - 1
    alpha = levels[-1]
    gt = jnp.clip(g, -alpha, alpha)
    k = jnp.sum(gt[..., None] >= levels[1:][None, :], axis=-1)
    k = jnp.clip(k, 0, s - 1)
    lo = levels[k]
    hi = levels[k + 1]
    pr = (gt - lo) / jnp.maximum(hi - lo, 1e-12)
    return (k + (rand < pr).astype(k.dtype)).astype(jnp.uint8)


def codebook_decode(codes: jax.Array, levels: jax.Array) -> jax.Array:
    return jnp.take(levels, codes.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Fused decode oracles (``kernels.decode``).
#
# The decode-reduce kernels fold peers into the output tile *sequentially*
# (grid peer axis innermost) and divide by n at the last peer; per element
# that is exactly ``(((v_0 + v_1) + ...) + v_{n-1}) / n`` regardless of the
# row blocking, so the oracle is a plain per-peer accumulation loop.  All
# remaining ops are element-wise (or exact one-hot lookups), hence kernel and
# oracle agree bit-for-bit in interpret mode — and these same functions
# double as the shard_map-safe jnp fallback of ``dist.sharded_codec``, which
# streams one peer at a time instead of materializing the (n_peers, m)
# unpacked code tensor.
# ---------------------------------------------------------------------------


def uniform_decode_reduce(words: jax.Array, alphas: jax.Array, n: int, bits: int) -> jax.Array:
    """(peers, packed_words) uint32 + (peers,) alphas -> (n,) fp32 peer mean."""
    from repro.core.quantizers import unpack_codes

    s = num_levels(bits)
    p = words.shape[0]
    acc = jnp.zeros((n,), jnp.float32)
    for j in range(p):
        codes = unpack_codes(words[j], n, bits).astype(jnp.float32)
        alpha = alphas[j].astype(jnp.float32)
        acc = acc + (codes * (2.0 * alpha / s) - alpha)
    return acc / p


def codebook_decode_reduce(words: jax.Array, levels: jax.Array, n: int, bits: int) -> jax.Array:
    """(peers, packed_words) + (peers, s+1) codebooks -> (n,) fp32 peer mean."""
    from repro.core.quantizers import unpack_codes

    p = words.shape[0]
    acc = jnp.zeros((n,), jnp.float32)
    for j in range(p):
        codes = unpack_codes(words[j], n, bits)
        acc = acc + jnp.take(levels[j].astype(jnp.float32), codes.astype(jnp.int32))
    return acc / p


def uniform_decode_rows(words: jax.Array, alphas: jax.Array, n: int, bits: int) -> jax.Array:
    """(peers, packed_words) uint32 + (peers,) alphas -> (peers, n) fp32."""
    from repro.core.quantizers import unpack_codes

    s = num_levels(bits)
    rows = []
    for j in range(words.shape[0]):
        codes = unpack_codes(words[j], n, bits).astype(jnp.float32)
        alpha = alphas[j].astype(jnp.float32)
        rows.append(codes * (2.0 * alpha / s) - alpha)
    return jnp.stack(rows)


def codebook_decode_rows(words: jax.Array, levels: jax.Array, n: int, bits: int) -> jax.Array:
    """(peers, packed_words) + (peers, s+1) codebooks -> (peers, n) fp32."""
    from repro.core.quantizers import unpack_codes

    rows = []
    for j in range(words.shape[0]):
        codes = unpack_codes(words[j], n, bits)
        rows.append(jnp.take(levels[j].astype(jnp.float32), codes.astype(jnp.int32)))
    return jnp.stack(rows)


def bucket_stats(g: jax.Array) -> jax.Array:
    """Blockwise jnp oracle for ``stats.bucket_stats_2d``.

    Walks the padded (rows, 128) layout in the kernel's BLOCK_ROWS grid
    order, builds each block's log2-spaced histogram / ln-sum / max / moment
    partials with the same one-hot-matmul reduction, and folds them with the
    same add-or-maximum merge — so kernel and oracle agree bit-for-bit
    (interpret mode executes identical ops in identical order).
    """
    from . import stats as S

    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.size
    rows = -(-n // S.LANES)
    blocks = -(-rows // S.BLOCK_ROWS)
    padded = jnp.pad(flat, (0, blocks * S.BLOCK_ROWS * S.LANES - n))
    w = (S.LOG2_HI - S.LOG2_LO) / S.NUM_BINS
    acc = jnp.zeros((S.STATS_ROWS, S.NUM_BINS), jnp.float32)
    for i in range(blocks):
        m = S.BLOCK_ROWS * S.LANES
        gb = padded[i * m:(i + 1) * m].reshape(S.BLOCK_ROWS, S.LANES)
        valid = (jnp.arange(i * m, (i + 1) * m) < n).reshape(S.BLOCK_ROWS, S.LANES)
        vmask = valid.astype(jnp.float32)
        gabs = jnp.abs(gb) * vmask
        lnab = jnp.log(jnp.maximum(gabs, 1e-30))
        b = jnp.clip(jnp.floor((lnab / math.log(2.0) - S.LOG2_LO) / w),
                     0.0, S.NUM_BINS - 1.0)
        b = jnp.where(valid, b, -1.0)
        iota = jax.lax.broadcasted_iota(jnp.float32, (m, S.NUM_BINS), 1)
        onehot = (iota == b.reshape(m)[:, None]).astype(jnp.float32)
        counts = jnp.ones((1, m), jnp.float32) @ onehot
        logsum = (lnab * vmask).reshape(1, m) @ onehot
        gv = gb * vmask
        part = jnp.concatenate(
            [
                counts,
                logsum,
                jnp.full((1, S.NUM_BINS), jnp.max(gabs), jnp.float32),
                jnp.full((1, S.NUM_BINS), jnp.sum(gv), jnp.float32),
                jnp.full((1, S.NUM_BINS), jnp.sum(gv * gv), jnp.float32),
                jnp.zeros((S.STATS_ROWS - 5, S.NUM_BINS), jnp.float32),
            ],
            axis=0,
        )
        row = jax.lax.broadcasted_iota(jnp.int32, (S.STATS_ROWS, S.NUM_BINS), 0)
        acc = jnp.where(row == 2, jnp.maximum(acc, part), acc + part)
    return acc
