"""Pure-jnp oracles for the quantization kernels.

These mirror ``repro.core.quantizers`` but take an explicit uniform-random
array (the kernels consume pre-generated random bits so the Pallas and
reference paths can be compared bit-exactly).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.quantizers import num_levels


def uniform_encode(g: jax.Array, alpha: jax.Array, bits: int, rand: jax.Array) -> jax.Array:
    """Fused truncate + uniform stochastic quantize.  codes in [0, s], uint8.

    code = floor(u) + 1[rand < frac(u)]  with  u = (clip(g, ±α) + α) · s/(2α).
    """
    s = num_levels(bits)
    scale = s / (2.0 * alpha)
    u = (jnp.clip(g, -alpha, alpha) + alpha) * scale
    k = jnp.clip(jnp.floor(u), 0, s - 1)
    frac = u - k
    code = k + (rand < frac).astype(u.dtype)
    return jnp.clip(code, 0, s).astype(jnp.uint8)


def uniform_decode(codes: jax.Array, alpha: jax.Array, bits: int) -> jax.Array:
    s = num_levels(bits)
    step = 2.0 * alpha / s
    return codes.astype(jnp.float32) * step - alpha


def codebook_encode(g: jax.Array, levels: jax.Array, rand: jax.Array) -> jax.Array:
    """Fused truncate + non-uniform stochastic quantize onto ``levels``.

    k = #{j in 1..s : g >= l_j} clipped to s-1;  pr = (g - l_k)/(l_{k+1}-l_k);
    code = k + 1[rand < pr].  Matches quantizers.stochastic_encode given the
    same uniforms.
    """
    s = levels.shape[0] - 1
    alpha = levels[-1]
    gt = jnp.clip(g, -alpha, alpha)
    k = jnp.sum(gt[..., None] >= levels[1:][None, :], axis=-1)
    k = jnp.clip(k, 0, s - 1)
    lo = levels[k]
    hi = levels[k + 1]
    pr = (gt - lo) / jnp.maximum(hi - lo, 1e-12)
    return (k + (rand < pr).astype(k.dtype)).astype(jnp.uint8)


def codebook_decode(codes: jax.Array, levels: jax.Array) -> jax.Array:
    return jnp.take(levels, codes.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Fused encode-side oracles / fallbacks (``kernels.encode_fused``).
#
# The key-based functions mirror the ``ops`` wrappers' signatures exactly —
# same (rows, 128) padding, same uniform draw — so ``dist.sharded_codec``
# dispatches between the Pallas module and this one by name, and kernel vs
# fallback produce bit-identical wire words for the codebook methods (the
# uniform dequant inside the residual keeps the usual ulp-level FMA slack).
# All ops here are plain jnp and safe under shard_map tracing on the pinned
# toolchain.
# ---------------------------------------------------------------------------


def _flat_rand(g: jax.Array, key: jax.Array) -> tuple[jax.Array, jax.Array, int]:
    """Match the ops wrappers' RNG layout: pad to (rows, 128), draw there."""
    from .ops import _to_2d

    g2, n = _to_2d(g.astype(jnp.float32))
    rand = jax.random.uniform(key, g2.shape, jnp.float32)
    return g2, rand, n


def uniform_encode_pack(g: jax.Array, alpha: jax.Array, bits: int, key: jax.Array) -> jax.Array:
    """Sequential oracle of ``ops.uniform_encode_pack``: encode then pack."""
    from repro.core.quantizers import pack_codes

    g2, rand, n = _flat_rand(g, key)
    codes = uniform_encode(g2, alpha, bits, rand).reshape(-1)[:n]
    return pack_codes(codes, bits)


def _codebook_codes(flat: jax.Array, levels: jax.Array, rand: jax.Array) -> jax.Array:
    """Codebook stochastic codes via searchsorted + take.

    Bit-identical to the kernel's compare-count + one-hot formulation
    (:func:`codebook_encode`): the interval index is the same exact integer
    either way, and ``take`` is the same exact lookup as the one-hot
    matmul — but a binary search beats the (n, s) compare matrix on CPU,
    which is what this fallback actually runs on.
    """
    levels = levels.astype(jnp.float32)
    s = levels.shape[0] - 1
    gt = jnp.clip(flat, -levels[s], levels[s])
    k = jnp.clip(jnp.searchsorted(levels, gt, side="right") - 1, 0, s - 1)
    lo = jnp.take(levels, k)
    hi = jnp.take(levels, k + 1)
    pr = (gt - lo) / jnp.maximum(hi - lo, 1e-12)
    return (k + (rand.reshape(-1)[: flat.size] < pr).astype(k.dtype)).astype(jnp.uint8)


def codebook_encode_pack(g: jax.Array, levels: jax.Array, bits: int, key: jax.Array) -> jax.Array:
    """Sequential oracle of ``ops.codebook_encode_pack``."""
    from repro.core.quantizers import pack_codes

    g2, rand, n = _flat_rand(g, key)
    codes = _codebook_codes(g2.reshape(-1)[:n], levels, rand)
    return pack_codes(codes, bits)


def uniform_encode_pack_residual(
    g: jax.Array, alpha: jax.Array, bits: int, key: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Sequential oracle of ``ops.uniform_encode_pack_residual``."""
    from repro.core.quantizers import pack_codes

    s = num_levels(bits)
    g2, rand, n = _flat_rand(g, key)
    codes = uniform_encode(g2, alpha, bits, rand).reshape(-1)[:n]
    flat = g2.reshape(-1)[:n]
    alpha = alpha.astype(jnp.float32)
    resid = flat - (codes.astype(jnp.float32) * (2.0 * alpha / s) - alpha)
    return pack_codes(codes, bits), resid


def codebook_encode_pack_residual(
    g: jax.Array, levels: jax.Array, bits: int, key: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Sequential oracle of ``ops.codebook_encode_pack_residual``; the
    residual is the exact ``g − levels[code]``."""
    from repro.core.quantizers import pack_codes

    g2, rand, n = _flat_rand(g, key)
    flat = g2.reshape(-1)[:n]
    codes = _codebook_codes(flat, levels, rand)
    resid = flat - jnp.take(levels.astype(jnp.float32), codes.astype(jnp.int32))
    return pack_codes(codes, bits), resid


def bucket_stats_scatter(g: jax.Array):
    """O(n) scatter-add bucket statistics — the shard_map-safe jnp fallback.

    Counts and max are identical to the fused kernel (integer adds / exact
    max); the float ln/moment sums may differ in the last bits (reduction
    order), which neither the EMA telemetry nor the histogram plan cares
    about — the bit-exact contract is pinned kernel ↔ :func:`bucket_stats`.
    Returns ``(counts, log_sums, g_max, g_sum, g_sumsq)``.
    """
    from . import stats as S

    flat = g.reshape(-1).astype(jnp.float32)
    gabs = jnp.abs(flat)
    lnab = jnp.log(jnp.maximum(gabs, 1e-30))
    w = (S.LOG2_HI - S.LOG2_LO) / S.NUM_BINS
    b = jnp.clip(jnp.floor((lnab / jnp.log(2.0) - S.LOG2_LO) / w),
                 0.0, S.NUM_BINS - 1.0).astype(jnp.int32)
    # repro: allow REPRO104 (counts sum exact 1.0s — order-free; see above)
    counts = jnp.zeros((S.NUM_BINS,), jnp.float32).at[b].add(1.0)
    # repro: allow REPRO104 (last-bit slack documented in the EMA contract)
    log_sums = jnp.zeros((S.NUM_BINS,), jnp.float32).at[b].add(lnab)
    return counts, log_sums, jnp.max(gabs), jnp.sum(flat), jnp.sum(flat * flat)


def ef_correct_stats(g: jax.Array, e: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Blockwise oracle of ``ops.ef_correct_stats``: ``c = g + e`` plus the
    (STATS_ROWS, NUM_BINS) stats tile of c, walking the same block/merge
    order as the fused kernel (bit-exact in interpret mode)."""
    c = g.reshape(-1).astype(jnp.float32) + e.reshape(-1).astype(jnp.float32)
    return c, bucket_stats(c)


# ---------------------------------------------------------------------------
# Fused decode oracles (``kernels.decode``).
#
# The decode-reduce kernels fold peers into the output tile *sequentially*
# (grid peer axis innermost) and divide by n at the last peer; per element
# that is exactly ``(((v_0 + v_1) + ...) + v_{n-1}) / n`` regardless of the
# row blocking, so the oracle is a plain per-peer accumulation loop.  All
# remaining ops are element-wise (or exact one-hot lookups), hence kernel and
# oracle agree bit-for-bit in interpret mode — and these same functions
# double as the shard_map-safe jnp fallback of ``dist.sharded_codec``, which
# streams one peer at a time instead of materializing the (n_peers, m)
# unpacked code tensor.
# ---------------------------------------------------------------------------


def uniform_decode_reduce(words: jax.Array, alphas: jax.Array, n: int, bits: int) -> jax.Array:
    """(peers, packed_words) uint32 + (peers,) alphas -> (n,) fp32 peer mean."""
    from repro.core.quantizers import unpack_codes

    s = num_levels(bits)
    p = words.shape[0]
    acc = jnp.zeros((n,), jnp.float32)
    for j in range(p):
        codes = unpack_codes(words[j], n, bits).astype(jnp.float32)
        alpha = alphas[j].astype(jnp.float32)
        acc = acc + (codes * (2.0 * alpha / s) - alpha)
    return acc / p


def codebook_decode_reduce(words: jax.Array, levels: jax.Array, n: int, bits: int) -> jax.Array:
    """(peers, packed_words) + (peers, s+1) codebooks -> (n,) fp32 peer mean."""
    from repro.core.quantizers import unpack_codes

    p = words.shape[0]
    acc = jnp.zeros((n,), jnp.float32)
    for j in range(p):
        codes = unpack_codes(words[j], n, bits)
        acc = acc + jnp.take(levels[j].astype(jnp.float32), codes.astype(jnp.int32))
    return acc / p


def uniform_decode_rows(words: jax.Array, alphas: jax.Array, n: int, bits: int) -> jax.Array:
    """(peers, packed_words) uint32 + (peers,) alphas -> (peers, n) fp32."""
    from repro.core.quantizers import unpack_codes

    s = num_levels(bits)
    rows = []
    for j in range(words.shape[0]):
        codes = unpack_codes(words[j], n, bits).astype(jnp.float32)
        alpha = alphas[j].astype(jnp.float32)
        rows.append(codes * (2.0 * alpha / s) - alpha)
    return jnp.stack(rows)


def codebook_decode_rows(words: jax.Array, levels: jax.Array, n: int, bits: int) -> jax.Array:
    """(peers, packed_words) + (peers, s+1) codebooks -> (peers, n) fp32."""
    from repro.core.quantizers import unpack_codes

    rows = []
    for j in range(words.shape[0]):
        codes = unpack_codes(words[j], n, bits)
        rows.append(jnp.take(levels[j].astype(jnp.float32), codes.astype(jnp.int32)))
    return jnp.stack(rows)


def bucket_stats(g: jax.Array) -> jax.Array:
    """Blockwise jnp oracle for ``stats.bucket_stats_2d``.

    Walks the padded (rows, 128) layout in the kernel's BLOCK_ROWS grid
    order, builds each block's log2-spaced histogram / ln-sum / max / moment
    partials with the same one-hot-matmul reduction, and folds them with the
    same add-or-maximum merge — so kernel and oracle agree bit-for-bit
    (interpret mode executes identical ops in identical order).
    """
    from . import stats as S

    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.size
    rows = -(-n // S.LANES)
    blocks = -(-rows // S.BLOCK_ROWS)
    padded = jnp.pad(flat, (0, blocks * S.BLOCK_ROWS * S.LANES - n))
    w = (S.LOG2_HI - S.LOG2_LO) / S.NUM_BINS
    acc = jnp.zeros((S.STATS_ROWS, S.NUM_BINS), jnp.float32)
    for i in range(blocks):
        m = S.BLOCK_ROWS * S.LANES
        gb = padded[i * m:(i + 1) * m].reshape(S.BLOCK_ROWS, S.LANES)
        valid = (jnp.arange(i * m, (i + 1) * m) < n).reshape(S.BLOCK_ROWS, S.LANES)
        vmask = valid.astype(jnp.float32)
        gabs = jnp.abs(gb) * vmask
        lnab = jnp.log(jnp.maximum(gabs, 1e-30))
        b = jnp.clip(jnp.floor((lnab / math.log(2.0) - S.LOG2_LO) / w),
                     0.0, S.NUM_BINS - 1.0)
        b = jnp.where(valid, b, -1.0)
        iota = jax.lax.broadcasted_iota(jnp.float32, (m, S.NUM_BINS), 1)
        onehot = (iota == b.reshape(m)[:, None]).astype(jnp.float32)
        counts = jnp.ones((1, m), jnp.float32) @ onehot
        logsum = (lnab * vmask).reshape(1, m) @ onehot
        gv = gb * vmask
        part = jnp.concatenate(
            [
                counts,
                logsum,
                jnp.full((1, S.NUM_BINS), jnp.max(gabs), jnp.float32),
                jnp.full((1, S.NUM_BINS), jnp.sum(gv), jnp.float32),
                jnp.full((1, S.NUM_BINS), jnp.sum(gv * gv), jnp.float32),
                jnp.zeros((S.STATS_ROWS - 5, S.NUM_BINS), jnp.float32),
            ],
            axis=0,
        )
        row = jax.lax.broadcasted_iota(jnp.int32, (S.STATS_ROWS, S.NUM_BINS), 0)
        acc = jnp.where(row == 2, jnp.maximum(acc, part), acc + part)
    return acc


def orthogonalize(p: jax.Array) -> jax.Array:
    """Oracle for ``kernels.orthogonalize``: the identical padded-tile
    Gram–Schmidt loop (shared ``_gs_padded`` body ⇒ bit-exact vs the kernel
    in interpret mode), shard_map-safe jnp."""
    from .orthogonalize import LANES as _GS_LANES, SUBLANES as _GS_SUB, _gs_padded

    rows, r = p.shape
    rows_p = -(-rows // _GS_SUB) * _GS_SUB
    pp = jnp.pad(p.astype(jnp.float32), ((0, rows_p - rows), (0, _GS_LANES - r)))
    return _gs_padded(pp, r)[:rows, :r]
