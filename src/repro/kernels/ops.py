"""jit'd public wrappers around the Pallas quantization kernels.

Handles flattening/padding to the (rows, 128) kernel layout, dtype
narrowing, and the CPU fallback (interpret=True) so the same API runs in
tests and on TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from typing import NamedTuple

from . import quantize as _k
from . import stats as _s

LANES = _k.LANES


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_2d(x: jax.Array) -> tuple[jax.Array, int]:
    n = x.size
    rows = -(-n // LANES)
    pad = rows * LANES - n
    x2 = jnp.pad(x.reshape(-1), (0, pad)).reshape(rows, LANES)
    return x2, n


@partial(jax.jit, static_argnames=("bits", "interpret"))
def uniform_encode(
    g: jax.Array, alpha: jax.Array, bits: int, key: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    """Fused truncate + uniform stochastic encode.  Returns flat uint8 codes."""
    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    rand = jax.random.uniform(key, g2.shape, jnp.float32)
    codes = _k.uniform_encode_2d(g2, rand, alpha.astype(jnp.float32), bits=bits, interpret=interpret)
    return codes.reshape(-1)[:n].astype(jnp.uint8)


@partial(jax.jit, static_argnames=("bits", "interpret"))
def uniform_decode(
    codes: jax.Array, alpha: jax.Array, bits: int, *, interpret: bool | None = None
) -> jax.Array:
    interpret = _use_interpret() if interpret is None else interpret
    c2, n = _to_2d(codes.astype(jnp.int32))
    vals = _k.uniform_decode_2d(c2, alpha.astype(jnp.float32), bits=bits, interpret=interpret)
    return vals.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("bits", "interpret"))
def uniform_encode_packed(
    g: jax.Array, alpha: jax.Array, bits: int, key: jax.Array, *, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """Fused truncate + uniform stochastic encode + bit-pack.

    Returns ``(words, codes)``: uint32 wire words (``packed_size(n, bits)``,
    bit-identical to ``pack_codes`` of the same codes) plus the flat uint8
    codes for local dequantization (error feedback).
    """
    from repro.core.quantizers import packed_size

    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    rand = jax.random.uniform(key, g2.shape, jnp.float32)
    codes, words = _k.uniform_encode_pack_2d(
        g2, rand, alpha.astype(jnp.float32), n, bits=bits, interpret=interpret)
    words = jax.lax.bitcast_convert_type(words.reshape(-1), jnp.uint32)[: packed_size(n, bits)]
    return words, codes.reshape(-1)[:n].astype(jnp.uint8)


@partial(jax.jit, static_argnames=("bits", "interpret"))
def codebook_encode_packed(
    g: jax.Array, levels: jax.Array, bits: int, key: jax.Array, *, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """Fused non-uniform encode + bit-pack onto ``levels``; see
    :func:`uniform_encode_packed` for the return contract."""
    from repro.core.quantizers import packed_size

    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    rand = jax.random.uniform(key, g2.shape, jnp.float32)
    codes, words = _k.codebook_encode_pack_2d(
        g2, rand, levels.astype(jnp.float32), n, bits=bits, interpret=interpret)
    words = jax.lax.bitcast_convert_type(words.reshape(-1), jnp.uint32)[: packed_size(n, bits)]
    return words, codes.reshape(-1)[:n].astype(jnp.uint8)


@partial(jax.jit, static_argnames=("interpret",))
def codebook_encode(
    g: jax.Array, levels: jax.Array, key: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    """Fused truncate + non-uniform stochastic encode onto ``levels``."""
    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    rand = jax.random.uniform(key, g2.shape, jnp.float32)
    codes = _k.codebook_encode_2d(g2, rand, levels.astype(jnp.float32), interpret=interpret)
    return codes.reshape(-1)[:n].astype(jnp.uint8)


@partial(jax.jit, static_argnames=("interpret",))
def codebook_decode(
    codes: jax.Array, levels: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    interpret = _use_interpret() if interpret is None else interpret
    c2, n = _to_2d(codes.astype(jnp.int32))
    vals = _k.codebook_decode_2d(c2, levels.astype(jnp.float32), interpret=interpret)
    return vals.reshape(-1)[:n]


class BucketStats(NamedTuple):
    """One-pass telemetry statistics of a flat gradient bucket."""

    counts: jax.Array    # (NUM_BINS,) log2-spaced |g| histogram counts
    log_sums: jax.Array  # (NUM_BINS,) per-bin sums of ln|g|
    g_max: jax.Array     # scalar max |g|
    g_sum: jax.Array     # scalar sum g
    g_sumsq: jax.Array   # scalar sum g²


@partial(jax.jit, static_argnames=("interpret",))
def bucket_stats(g: jax.Array, *, interpret: bool | None = None) -> BucketStats:
    """Fused histogram + Hill-sum + max/moments pass (``kernels.stats``).

    Replaces the full-sort quantile in the telemetry hot loop: everything
    the online power-law tail estimator needs comes out of one VMEM pass.
    """
    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    out = _s.bucket_stats_2d(g2, n, interpret=interpret)
    return BucketStats(counts=out[0], log_sums=out[1], g_max=out[2, 0],
                       g_sum=out[3, 0], g_sumsq=out[4, 0])
