"""jit'd public wrappers around the Pallas quantization kernels.

Handles flattening/padding to the (rows, 128) kernel layout, dtype
narrowing, and the CPU fallback (interpret=True) so the same API runs in
tests and on TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from typing import NamedTuple

from . import decode as _d
from . import encode_fused as _e
from . import quantize as _k
from . import stats as _s

LANES = _k.LANES


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_2d(x: jax.Array) -> tuple[jax.Array, int]:
    n = x.size
    rows = -(-n // LANES)
    pad = rows * LANES - n
    x2 = jnp.pad(x.reshape(-1), (0, pad)).reshape(rows, LANES)
    return x2, n


@partial(jax.jit, static_argnames=("bits", "interpret"))
def uniform_encode(
    g: jax.Array, alpha: jax.Array, bits: int, key: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    """Fused truncate + uniform stochastic encode.  Returns flat uint8 codes."""
    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    rand = jax.random.uniform(key, g2.shape, jnp.float32)
    codes = _k.uniform_encode_2d(g2, rand, alpha.astype(jnp.float32), bits=bits, interpret=interpret)
    return codes.reshape(-1)[:n].astype(jnp.uint8)


@partial(jax.jit, static_argnames=("bits", "interpret"))
def uniform_decode(
    codes: jax.Array, alpha: jax.Array, bits: int, *, interpret: bool | None = None
) -> jax.Array:
    interpret = _use_interpret() if interpret is None else interpret
    c2, n = _to_2d(codes.astype(jnp.int32))
    vals = _k.uniform_decode_2d(c2, alpha.astype(jnp.float32), bits=bits, interpret=interpret)
    return vals.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("bits", "interpret"))
def uniform_encode_packed(
    g: jax.Array, alpha: jax.Array, bits: int, key: jax.Array, *, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """Fused truncate + uniform stochastic encode + bit-pack.

    Returns ``(words, codes)``: uint32 wire words (``packed_size(n, bits)``,
    bit-identical to ``pack_codes`` of the same codes) plus the flat uint8
    codes for local dequantization (error feedback).
    """
    from repro.core.quantizers import packed_size

    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    rand = jax.random.uniform(key, g2.shape, jnp.float32)
    codes, words = _k.uniform_encode_pack_2d(
        g2, rand, alpha.astype(jnp.float32), n, bits=bits, interpret=interpret)
    words = jax.lax.bitcast_convert_type(words.reshape(-1), jnp.uint32)[: packed_size(n, bits)]
    return words, codes.reshape(-1)[:n].astype(jnp.uint8)


@partial(jax.jit, static_argnames=("bits", "interpret"))
def codebook_encode_packed(
    g: jax.Array, levels: jax.Array, bits: int, key: jax.Array, *, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """Fused non-uniform encode + bit-pack onto ``levels``; see
    :func:`uniform_encode_packed` for the return contract."""
    from repro.core.quantizers import packed_size

    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    rand = jax.random.uniform(key, g2.shape, jnp.float32)
    codes, words = _k.codebook_encode_pack_2d(
        g2, rand, levels.astype(jnp.float32), n, bits=bits, interpret=interpret)
    words = jax.lax.bitcast_convert_type(words.reshape(-1), jnp.uint32)[: packed_size(n, bits)]
    return words, codes.reshape(-1)[:n].astype(jnp.uint8)


@partial(jax.jit, static_argnames=("interpret",))
def codebook_encode(
    g: jax.Array, levels: jax.Array, key: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    """Fused truncate + non-uniform stochastic encode onto ``levels``."""
    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    rand = jax.random.uniform(key, g2.shape, jnp.float32)
    codes = _k.codebook_encode_2d(g2, rand, levels.astype(jnp.float32), interpret=interpret)
    return codes.reshape(-1)[:n].astype(jnp.uint8)


@partial(jax.jit, static_argnames=("interpret",))
def codebook_decode(
    codes: jax.Array, levels: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    interpret = _use_interpret() if interpret is None else interpret
    c2, n = _to_2d(codes.astype(jnp.int32))
    vals = _k.codebook_decode_2d(c2, levels.astype(jnp.float32), interpret=interpret)
    return vals.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# Fused decode: packed wire rows + per-peer codebooks -> peer mean (or per-
# peer rows) without materializing the (n_peers, m) unpacked code tensor.
# ---------------------------------------------------------------------------


def _to_words3(words: jax.Array, n: int, bits: int, block_rows: int) -> jax.Array:
    """(peers, packed_size(n, bits)) uint32 -> (peers, rows_p, 4·bits) int32.

    Pads each peer's word row out to whole (block_rows, 128)-element tiles;
    the pad words decode to garbage values that land past element ``n`` and
    are sliced off by the callers (packing is independent per 32-element
    group, so padding never perturbs valid elements).
    """
    from repro.core.quantizers import packed_size

    p, w = words.shape
    if w != packed_size(n, bits):
        raise ValueError(
            f"wire has {w} words per peer; {n} elements at {bits} bits need "
            f"{packed_size(n, bits)}")
    wc = _d.words_per_row(bits)
    rows = -(-n // LANES)
    rows_p = -(-rows // block_rows) * block_rows
    padded = jnp.pad(words, ((0, 0), (0, rows_p * wc - w)))
    return jax.lax.bitcast_convert_type(padded, jnp.int32).reshape(p, rows_p, wc)


@partial(jax.jit, static_argnames=("n", "bits", "interpret"))
def uniform_decode_reduce(
    words: jax.Array, alphas: jax.Array, n: int, bits: int, *, interpret: bool | None = None
) -> jax.Array:
    """Fused unpack + uniform dequant + peer mean.

    ``words``: (n_peers, packed_size(n, bits)) uint32 wire rows (one
    independently packed code row per peer, the ``pack_codes`` layout);
    ``alphas``: (n_peers,) truncation thresholds.  Returns the (n,) fp32
    mean over peers of ``code · 2α_p/s − α_p``.
    """
    interpret = _use_interpret() if interpret is None else interpret
    words3 = _to_words3(words, n, bits, _d.BLOCK_ROWS)
    a2 = alphas.astype(jnp.float32).reshape(-1, 1)
    out = _d.uniform_decode_reduce_3d(words3, a2, bits=bits, interpret=interpret)
    return out.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("n", "bits", "interpret"))
def codebook_decode_reduce(
    words: jax.Array, levels: jax.Array, n: int, bits: int, *, interpret: bool | None = None
) -> jax.Array:
    """Fused unpack + codebook dequant + peer mean; ``levels``: (n_peers, s+1).

    Returns the (n,) fp32 mean over peers of ``levels_p[code]``.
    """
    interpret = _use_interpret() if interpret is None else interpret
    words3 = _to_words3(words, n, bits, _d.BLOCK_ROWS_CODEBOOK)
    out = _d.codebook_decode_reduce_3d(
        words3, levels.astype(jnp.float32), bits=bits, interpret=interpret)
    return out.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("n", "bits", "interpret"))
def uniform_decode_rows(
    words: jax.Array, alphas: jax.Array, n: int, bits: int, *, interpret: bool | None = None
) -> jax.Array:
    """Fused unpack + uniform dequant, one (n,) row per peer (no reduce) —
    the all-gather phase-2 sites, where peer j's decode is output chunk j."""
    interpret = _use_interpret() if interpret is None else interpret
    words3 = _to_words3(words, n, bits, _d.BLOCK_ROWS)
    a2 = alphas.astype(jnp.float32).reshape(-1, 1)
    out = _d.uniform_decode_rows_3d(words3, a2, bits=bits, interpret=interpret)
    return out.reshape(words.shape[0], -1)[:, :n]


@partial(jax.jit, static_argnames=("n", "bits", "interpret"))
def codebook_decode_rows(
    words: jax.Array, levels: jax.Array, n: int, bits: int, *, interpret: bool | None = None
) -> jax.Array:
    """Fused unpack + codebook dequant, one (n,) row per peer (no reduce)."""
    interpret = _use_interpret() if interpret is None else interpret
    words3 = _to_words3(words, n, bits, _d.BLOCK_ROWS_CODEBOOK)
    out = _d.codebook_decode_rows_3d(
        words3, levels.astype(jnp.float32), bits=bits, interpret=interpret)
    return out.reshape(words.shape[0], -1)[:, :n]


class BucketStats(NamedTuple):
    """One-pass telemetry statistics of a flat gradient bucket."""

    counts: jax.Array    # (NUM_BINS,) log2-spaced |g| histogram counts
    log_sums: jax.Array  # (NUM_BINS,) per-bin sums of ln|g|
    g_max: jax.Array     # scalar max |g|
    g_sum: jax.Array     # scalar sum g
    g_sumsq: jax.Array   # scalar sum g²


@partial(jax.jit, static_argnames=("interpret",))
def bucket_stats(g: jax.Array, *, interpret: bool | None = None) -> BucketStats:
    """Fused histogram + Hill-sum + max/moments pass (``kernels.stats``).

    Replaces the full-sort quantile in the telemetry hot loop: everything
    the online power-law tail estimator needs comes out of one VMEM pass.
    """
    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    out = _s.bucket_stats_2d(g2, n, interpret=interpret)
    return BucketStats(counts=out[0], log_sums=out[1], g_max=out[2, 0],
                       g_sum=out[3, 0], g_sumsq=out[4, 0])


# ---------------------------------------------------------------------------
# Fused encode side (``kernels.encode_fused``): one-pass EF-correct→stats,
# and quantize→pack[→residual] without staging codes or owns in HBM.
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("interpret",))
def ef_correct_stats(
    g: jax.Array, e: jax.Array, *, interpret: bool | None = None
) -> tuple[jax.Array, BucketStats]:
    """One pass: ``c = g + e`` plus the full plan/telemetry statistics of c.

    Returns ``(corrected (n,) fp32, BucketStats)``.  Everything the plan
    consumes (counts, log-sums, max) is bit-identical to
    ``bucket_stats(g + e)`` — same block statistics and merge as
    ``kernels.stats`` — without the extra HBM sweep; the EMA moment rows
    are plain reductions with ulp-level fusion discretion.
    """
    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    e2, _ = _to_2d(e.astype(jnp.float32))
    c2, out = _e.ef_correct_stats_2d(g2, e2, n, interpret=interpret)
    return c2.reshape(-1)[:n], BucketStats(
        counts=out[0], log_sums=out[1], g_max=out[2, 0],
        g_sum=out[3, 0], g_sumsq=out[4, 0])


def _packed_words(words2: jax.Array, n: int, bits: int) -> jax.Array:
    from repro.core.quantizers import packed_size

    return jax.lax.bitcast_convert_type(words2.reshape(-1), jnp.uint32)[: packed_size(n, bits)]


@partial(jax.jit, static_argnames=("bits", "interpret"))
def uniform_encode_pack(
    g: jax.Array, alpha: jax.Array, bits: int, key: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    """Fused truncate + uniform stochastic encode + bit-pack, words only.

    Returns the uint32 wire words (``packed_size(n, bits)``, bit-identical
    to ``pack_codes`` of the same codes); unlike ``uniform_encode_packed``
    the codes never reach HBM.
    """
    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    rand = jax.random.uniform(key, g2.shape, jnp.float32)
    words = _e.uniform_encode_pack_2d(g2, rand, alpha.astype(jnp.float32), n,
                                      bits=bits, interpret=interpret)
    return _packed_words(words, n, bits)


@partial(jax.jit, static_argnames=("bits", "interpret"))
def codebook_encode_pack(
    g: jax.Array, levels: jax.Array, bits: int, key: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    """Codebook variant of :func:`uniform_encode_pack` (words only)."""
    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    rand = jax.random.uniform(key, g2.shape, jnp.float32)
    words = _e.codebook_encode_pack_2d(g2, rand, levels.astype(jnp.float32), n,
                                       bits=bits, interpret=interpret)
    return _packed_words(words, n, bits)


@partial(jax.jit, static_argnames=("bits", "interpret"))
def uniform_encode_pack_residual(
    g: jax.Array, alpha: jax.Array, bits: int, key: jax.Array, *, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """Fused truncate + uniform encode + bit-pack + EF residual.

    Returns ``(words, residual)``: the uint32 wire words plus
    ``g − dequant(code)`` — the next error-feedback residual — computed in
    the same tile, so neither the codes nor the dequantized ``own`` tensor
    ever reach HBM.
    """
    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    rand = jax.random.uniform(key, g2.shape, jnp.float32)
    words, resid = _e.uniform_encode_pack_resid_2d(
        g2, rand, alpha.astype(jnp.float32), n, bits=bits, interpret=interpret)
    return _packed_words(words, n, bits), resid.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("bits", "interpret"))
def codebook_encode_pack_residual(
    g: jax.Array, levels: jax.Array, bits: int, key: jax.Array, *, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """Codebook variant of :func:`uniform_encode_pack_residual`; the
    residual is an exact ``g − levels[code]`` (the dequant reuses the
    interval endpoint the stochastic rounding selected)."""
    interpret = _use_interpret() if interpret is None else interpret
    g2, n = _to_2d(g.astype(jnp.float32))
    rand = jax.random.uniform(key, g2.shape, jnp.float32)
    words, resid = _e.codebook_encode_pack_resid_2d(
        g2, rand, levels.astype(jnp.float32), n, bits=bits, interpret=interpret)
    return _packed_words(words, n, bits), resid.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("interpret",))
def orthogonalize(p: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Gram–Schmidt orthonormalization of a tall-skinny (rows, r) factor.

    The PowerSGD power-iteration step: pads to the (8k, 128) fp32 tile,
    runs the single-block ``kernels.orthogonalize`` kernel, slices the
    (rows, r) corner back out.  r must be ≤ 128 lanes.
    """
    from . import orthogonalize as _o

    interpret = _use_interpret() if interpret is None else interpret
    rows, r = p.shape
    rows_p = -(-rows // _o.SUBLANES) * _o.SUBLANES
    pp = jnp.pad(p.astype(jnp.float32), ((0, rows_p - rows), (0, _o.LANES - r)))
    out = _o.orthogonalize_2d(pp, r=r, interpret=interpret)
    return out[:rows, :r]
