"""Deterministic synthetic data pipelines.

Two generators:
- ``lm_batch``: token streams for the LM zoo (Zipf-ish marginals so the loss
  has structure), plus the modality extras each family needs (patch
  embeddings + M-RoPE positions for VLM, frame embeddings for audio).
- ``classification dataset``: 28x28 10-class "shapes" images for the paper's
  MNIST-style experiments — fixed random class templates + pixel noise +
  occasional outlier samples (keeps gradients heavy-tailed like Fig. 1).

Everything is pure-functional on a seed: step t of any pipeline is
reproducible from (seed, t), which is what checkpoint-resume tests rely on.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.transformer import Batch


def _zipf_tokens(key, shape, vocab: int) -> jax.Array:
    """Zipf-like marginal over the vocab (heavier head, long tail)."""
    u = jax.random.uniform(key, shape, minval=1e-6, maxval=1.0)
    r = jnp.power(u, 3.0)  # skew toward 0
    return jnp.clip((r * vocab).astype(jnp.int32), 0, vocab - 1)


def make_mrope_positions(batch: int, seq: int, n_patches: int, grid: int = 16) -> jax.Array:
    """(3, B, S) positions: image patches get (t=0, h, w); text continues."""
    hh = jnp.arange(n_patches) // grid
    ww = jnp.arange(n_patches) % grid
    t_img = jnp.zeros((n_patches,), jnp.int32)
    text_start = (jnp.maximum(hh[-1], ww[-1]) + 1).astype(jnp.int32)
    t_text = text_start + jnp.arange(seq - n_patches, dtype=jnp.int32)
    tpos = jnp.concatenate([t_img, t_text])
    hpos = jnp.concatenate([hh.astype(jnp.int32), t_text])
    wpos = jnp.concatenate([ww.astype(jnp.int32), t_text])
    pos = jnp.stack([tpos, hpos, wpos])                       # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))


@partial(jax.jit, static_argnames=("cfg", "batch", "seq"))
def lm_batch(cfg, seed: jax.Array, batch: int, seq: int) -> Batch:
    """One training batch for any zoo config."""
    # repro: allow REPRO204 (the dataset IS the fixed stream; seed selects the batch)
    key = jax.random.fold_in(jax.random.key(0), seed)
    k_tok, k_extra = jax.random.split(key)
    tokens = _zipf_tokens(k_tok, (batch, seq), cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(-1)
    positions = None
    patches = None
    frames = None
    if cfg.vlm_patches:
        patches = jax.random.normal(k_extra, (batch, cfg.vlm_patches, cfg.vlm_vision_dim), jnp.float32)
        positions = make_mrope_positions(batch, seq, cfg.vlm_patches)
        labels = labels.at[:, : cfg.vlm_patches].set(-1)
    if cfg.enc_dec:
        frames = jax.random.normal(k_extra, (batch, cfg.enc_seq, cfg.d_model), jnp.float32)
    return Batch(tokens=tokens, labels=labels, positions=positions, patches=patches, frames=frames)


# ---------------------------------------------------------------------------
# Classification dataset for the paper's experiments (MNIST stand-in).
# ---------------------------------------------------------------------------


def make_templates(key, num_classes: int = 10, hw: int = 28) -> jax.Array:
    """Fixed smooth class templates (num_classes, hw, hw)."""
    base = jax.random.normal(key, (num_classes, hw, hw))
    # Smooth with a small box filter to create structure.
    kernel = jnp.ones((5, 5)) / 25.0
    sm = jax.vmap(lambda img: jax.scipy.signal.convolve2d(img, kernel, mode="same"))(base)
    return sm / jnp.maximum(jnp.std(sm, axis=(1, 2), keepdims=True), 1e-6)


@partial(jax.jit, static_argnames=("batch", "hw", "outlier_frac"))
def shapes_batch(
    templates: jax.Array,
    seed: jax.Array,
    batch: int,
    hw: int = 28,
    noise: float = 0.6,
    outlier_frac: float = 0.02,
):
    """Returns (images (B, hw, hw, 1), labels (B,)).  A small fraction of
    samples get 10x amplified noise — the outliers that make gradients
    heavy-tailed (paper Fig. 1's regime)."""
    nc = templates.shape[0]
    # repro: allow REPRO204 (the dataset IS the fixed stream; seed selects the batch)
    key = jax.random.fold_in(jax.random.key(1), seed)
    k_lab, k_noise, k_out = jax.random.split(key, 3)
    labels = jax.random.randint(k_lab, (batch,), 0, nc)
    imgs = templates[labels]
    eps = jax.random.normal(k_noise, imgs.shape) * noise
    is_out = jax.random.uniform(k_out, (batch, 1, 1)) < outlier_frac
    imgs = imgs + jnp.where(is_out, 10.0 * eps, eps)
    return imgs[..., None].astype(jnp.float32), labels


def client_batches(templates, seed: jax.Array, n_clients: int, batch: int):
    """Per-client batches for the N-client DSGD experiments."""
    imgs, labels = shapes_batch(templates, seed, n_clients * batch)
    return (
        imgs.reshape(n_clients, batch, *imgs.shape[1:]),
        labels.reshape(n_clients, batch),
    )
