"""Fault-injection harness: scripted dropout traces for elastic sync.

A :class:`ChaosTrace` is a static ``(T, n_peers)`` 0/1 table — row ``t %
T`` is step ``t``'s live mask — fed to the sync stack through
:class:`repro.elastic.schedule.ElasticConfig(trace=...)`.  Scenario
constructors cover the failure shapes the mesh-invariance and recovery
tests replay:

- :func:`flap` — one peer flip-flops with a fixed period (the compiled-
  step-cache-thrash scenario the adaptive hysteresis defends against);
- :func:`partition` — a contiguous block of peers goes dark for a window,
  then rejoins (stale-EF recovery);
- :func:`solo_survivor` — every peer but one is down (the k=1 degenerate
  case of every sync mode).

The JSON file format (``--chaos-trace`` launch flag)::

    {"version": 1, "name": "...", "n_peers": 4, "rows": [[1,1,0,1], ...]}
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

from .schedule import ElasticConfig

TRACE_FORMAT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class ChaosTrace:
    """A scripted dropout table; ``rows[t % T][p]`` is peer p's liveness."""

    rows: tuple[tuple[int, ...], ...]
    name: str = "chaos"

    def __post_init__(self):
        rows = tuple(tuple(int(v) for v in row) for row in self.rows)
        if not rows or not rows[0]:
            raise ValueError("chaos trace needs at least one row and one peer")
        width = len(rows[0])
        for r, row in enumerate(rows):
            if len(row) != width:
                raise ValueError(f"row {r} has {len(row)} peers, row 0 has {width}")
            if any(v not in (0, 1) for v in row):
                raise ValueError(f"row {r} must contain only 0/1 entries")
        object.__setattr__(self, "rows", rows)

    @property
    def n_peers(self) -> int:
        return len(self.rows[0])

    @property
    def n_steps(self) -> int:
        return len(self.rows)

    def elastic(self, min_live: int = 1) -> ElasticConfig:
        """The :class:`ElasticConfig` replaying this trace."""
        return ElasticConfig(trace=self.rows, min_live=min_live)


def flap(n: int, peer: int = 0, period: int = 2, steps: int | None = None) -> ChaosTrace:
    """``peer`` alternates down/up every ``period`` steps, everyone else live."""
    if not (0 <= peer < n):
        raise ValueError(f"peer {peer} out of range for {n} peers")
    if period < 1:
        raise ValueError("period must be >= 1")
    T = steps if steps is not None else 2 * period
    rows = []
    for t in range(T):
        row = [1] * n
        if (t // period) % 2 == 0:
            row[peer] = 0
        rows.append(tuple(row))
    return ChaosTrace(tuple(rows), name=f"flap_p{peer}_per{period}")


def partition(n: int, down: tuple[int, ...] | int, down_steps: int,
              up_steps: int = 1) -> ChaosTrace:
    """Peers in ``down`` (a tuple, or the first ``down`` peers) go dark for
    ``down_steps`` steps, then the whole fleet runs for ``up_steps`` — the
    rejoin window stale-EF recovery is measured over."""
    dead = tuple(range(down)) if isinstance(down, int) else tuple(down)
    if not dead or any(not (0 <= p < n) for p in dead):
        raise ValueError(f"partition peers {dead} out of range for {n} peers")
    if len(dead) >= n:
        raise ValueError("partition cannot take down every peer")
    if down_steps < 1 or up_steps < 1:
        raise ValueError("down_steps and up_steps must be >= 1")
    dark = tuple(0 if p in dead else 1 for p in range(n))
    full = (1,) * n
    return ChaosTrace((dark,) * down_steps + (full,) * up_steps,
                      name=f"partition_{len(dead)}of{n}")


def solo_survivor(n: int, survivor: int = 0, steps: int = 1) -> ChaosTrace:
    """Every peer but ``survivor`` is down: the k=1 degenerate live set."""
    if not (0 <= survivor < n):
        raise ValueError(f"survivor {survivor} out of range for {n} peers")
    row = tuple(1 if p == survivor else 0 for p in range(n))
    return ChaosTrace((row,) * max(steps, 1), name=f"solo_{survivor}of{n}")


def save_trace(trace: ChaosTrace, path) -> None:
    """Write ``trace`` as the versioned JSON the launcher loads."""
    doc = {"version": TRACE_FORMAT_VERSION, "name": trace.name,
           "n_peers": trace.n_peers, "rows": [list(r) for r in trace.rows]}
    pathlib.Path(path).write_text(json.dumps(doc, indent=1) + "\n")


def load_trace(path) -> ChaosTrace:
    """Load a ``--chaos-trace`` JSON file (validates shape and version)."""
    doc = json.loads(pathlib.Path(path).read_text())
    if doc.get("version") != TRACE_FORMAT_VERSION:
        raise ValueError(
            f"chaos trace {path}: version {doc.get('version')!r}, "
            f"expected {TRACE_FORMAT_VERSION}")
    rows = tuple(tuple(int(v) for v in row) for row in doc["rows"])
    trace = ChaosTrace(rows, name=str(doc.get("name", "chaos")))
    if "n_peers" in doc and int(doc["n_peers"]) != trace.n_peers:
        raise ValueError(
            f"chaos trace {path}: n_peers={doc['n_peers']} does not match "
            f"row width {trace.n_peers}")
    return trace
