"""Elastic partial-participation sync: deterministic peer dropout.

``repro.elastic`` owns the *who-is-live* half of elastic sync; the sync
stack (``dist.train_step`` / ``dist.sharded_codec`` / ``dist.reference``)
owns what a live mask *means* (zeroed wire contribution, live-count
renormalization, stale-EF accumulation for dropped peers).

- :mod:`repro.elastic.schedule` — :class:`ElasticConfig` and the
  counter-hash :func:`live_mask`: a pure function of ``(seed, step,
  peer_id)`` that every peer (and the single-device reference replay)
  evaluates identically, traced or on host — no collective, no wall-clock.
- :mod:`repro.elastic.chaos` — the fault-injection harness: scripted
  :class:`ChaosTrace` dropout tables (flap / partition / solo-survivor
  scenarios) with a JSON file format for the ``--chaos-trace`` launch flag.
"""
from .chaos import ChaosTrace, flap, load_trace, partition, save_trace, solo_survivor
from .schedule import ElasticConfig, expected_live_fraction, live_mask

__all__ = [
    "ChaosTrace",
    "ElasticConfig",
    "expected_live_fraction",
    "flap",
    "live_mask",
    "load_trace",
    "partition",
    "save_trace",
    "solo_survivor",
]
