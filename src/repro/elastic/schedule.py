"""Deterministic peer-dropout schedule (the ``PeerSchedule`` of elastic sync).

The live mask for step ``t`` is a pure counter-based hash of ``(seed, t,
peer_id)`` — the Philox/murmur-finalizer idiom: no RNG state, no wall
clock, no collective.  Every peer of the mesh and the single-device
reference replay evaluate the same ``uint32`` arithmetic and therefore
agree on the mask bit-for-bit, which is what lets
``tests/test_mesh_invariance.py`` pin k-of-n subsets against
``dist.reference`` under the same mask.  The hash works identically on
traced step counters (inside the jitted train step) and on Python ints
(host-side replay, the adaptive controller's expected-participation
window).

Participation floor: a step whose hash (or trace row) leaves fewer than
``min_live`` peers is replaced by the canonical fallback mask — the first
``min_live`` peers live — so the sync never divides by an empty live set.
The rule is itself deterministic and replayed identically everywhere.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# murmur3 finalizer constants: full-avalanche uint32 mixing, wrap-around
# multiplies are the point (uint32 arithmetic is mod 2^32 in XLA and numpy).
_C1 = 0x9E3779B9
_C2 = 0x85EBCA6B
_C3 = 0xC2B2AE35


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Partial-participation schedule for the sync stack.

    ``rate`` is the per-peer per-step dropout probability realized by the
    counter hash (0 disables hashing entirely: everyone is live).
    ``trace`` replaces the hash with a scripted 0/1 table of shape
    ``(T, n_peers)`` indexed by ``step % T`` — the fault-injection harness
    (:mod:`repro.elastic.chaos`) builds these.  ``min_live`` is the
    participation floor (see module docstring).
    """

    rate: float = 0.0
    seed: int = 0x17E
    trace: tuple[tuple[int, ...], ...] | None = None
    min_live: int = 1

    def __post_init__(self):
        if not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"dropout rate must be in [0, 1], got {self.rate}")
        if self.min_live < 1:
            raise ValueError("min_live must be >= 1 (the sync needs a live peer)")
        if self.trace is not None:
            rows = tuple(tuple(int(v) for v in row) for row in self.trace)
            if not rows or not rows[0]:
                raise ValueError("chaos trace must be a non-empty (T, n) table")
            width = len(rows[0])
            for r, row in enumerate(rows):
                if len(row) != width:
                    raise ValueError(
                        f"chaos trace row {r} has {len(row)} peers, row 0 has {width}")
                if any(v not in (0, 1) for v in row):
                    raise ValueError(f"chaos trace row {r} must be 0/1 entries")
            object.__setattr__(self, "trace", rows)


def _hash_mask(seed: int, step, n: int, rate: float) -> jax.Array:
    """(n,) float32 0/1 mask from the counter hash; ``step`` int or traced."""
    threshold = min(int(round(rate * (1 << 32))), (1 << 32) - 1)
    if threshold == 0:
        return jnp.ones((n,), jnp.float32)
    step = jnp.asarray(step).astype(jnp.uint32)
    peer = jnp.arange(n, dtype=jnp.uint32)
    h = (jnp.uint32(seed & 0xFFFFFFFF) * jnp.uint32(_C1)) \
        ^ (step * jnp.uint32(_C2)) ^ (peer * jnp.uint32(_C3))
    h ^= h >> 16
    h *= jnp.uint32(_C2)
    h ^= h >> 13
    h *= jnp.uint32(_C3)
    h ^= h >> 16
    return (h >= jnp.uint32(threshold)).astype(jnp.float32)


def live_mask(cfg: ElasticConfig, step, n: int) -> jax.Array:
    """The (n,) float32 live mask for ``step`` (1.0 = live, 0.0 = dropped).

    ``step`` may be a Python int (host replay) or a traced integer scalar
    (inside the jitted train step) — the arithmetic is identical.  The
    participation floor replaces under-populated masks with the first
    ``min_live`` peers (see module docstring).
    """
    if cfg.trace is not None:
        table = jnp.asarray(cfg.trace, jnp.float32)
        if table.shape[1] != n:
            raise ValueError(
                f"chaos trace is for {table.shape[1]} peers, mesh has {n}")
        mask = table[jnp.asarray(step).astype(jnp.uint32) % table.shape[0]]
    else:
        mask = _hash_mask(cfg.seed, step, n, cfg.rate)
    floor = min(cfg.min_live, n)
    fallback = (jnp.arange(n) < floor).astype(jnp.float32)
    return jnp.where(jnp.sum(mask) >= floor, mask, fallback)


def expected_live_fraction(cfg: ElasticConfig | None, n: int,
                           start_step: int, window: int) -> float:
    """Mean live fraction over ``[start_step, start_step + window)``.

    Host-side replay of the exact in-graph schedule — the adaptive
    controller budgets the *upcoming* replan window against this, not the
    static mesh size.  ``cfg=None`` (elastic off) is full participation.
    """
    if cfg is None or n <= 0:
        return 1.0
    window = max(int(window), 1)
    total = 0.0
    for s in range(int(start_step), int(start_step) + window):
        total += float(jnp.mean(live_mask(cfg, s, n)))
    return total / window
