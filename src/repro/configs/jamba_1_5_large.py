"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7, MoE [arXiv:2403.19887].

72L, d_model 8192, 64H (GQA kv=8) on the attention layers (1 per 8-layer
block, offset 4), d_ff 24576, vocab 65536, MoE 16e top-2 on every other
layer.  Deviation: SSM layers use our Mamba2/SSD block (Jamba-1.5 ships
Mamba-1); chunked SSD is the TPU-friendly form.
"""
from .base import ArchConfig, MoESpec, SSMSpec

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    act="silu",
    rope="rope",
    tie_embeddings=False,
    attn_period=8,
    attn_offset=4,
    moe=MoESpec(num_experts=16, top_k=2, capacity_factor=1.25, every=2, d_ff=24576),
    ssm=SSMSpec(d_state=128, head_dim=64, expand=2, conv_width=4, n_groups=1, chunk=256),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    fsdp=True,
    source="arXiv:2403.19887",
)
