"""gemma-7b [dense] — GeGLU, head_dim 256 [arXiv:2403.08295].

28L, d_model 3072, 16H (kv=16: full MHA on 7b; MQA is the 2b variant),
d_ff 24576, vocab 256000.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256_000,
    act="gelu",
    rope="rope",
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    fsdp=True,
    source="arXiv:2403.08295",
)
