"""Architecture registry: ``get_config(name)`` / ``--arch <id>``.

Every assigned architecture is a module in this package exporting CONFIG
with the exact published dimensions (source cited in the module docstring).
"""
from __future__ import annotations

from .base import INPUT_SHAPES, ArchConfig, InputShape, MoESpec, SSMSpec, reduced

from . import (
    gemma_7b,
    granite_20b,
    jamba_1_5_large,
    llama3_2_1b,
    mamba2_2_7b,
    minitron_8b,
    phi3_5_moe,
    qwen2_vl_2b,
    qwen3_moe_235b,
    whisper_base,
)

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        granite_20b,
        qwen2_vl_2b,
        llama3_2_1b,
        qwen3_moe_235b,
        gemma_7b,
        minitron_8b,
        whisper_base,
        phi3_5_moe,
        mamba2_2_7b,
        jamba_1_5_large,
    )
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return list(ARCHS)


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


def variant_for_shape(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Arch variant actually lowered for a given input shape.

    ``long_500k`` on full-attention families switches to the sliding-window
    variant (window 4096, rolling KV cache) — full attention at 524k is out
    of scope per the assignment; SSM/hybrid run natively."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid") and cfg.sliding_window is None:
        return cfg.replace(sliding_window=4096)
    return cfg


__all__ = [
    "ARCHS",
    "ArchConfig",
    "INPUT_SHAPES",
    "InputShape",
    "MoESpec",
    "SSMSpec",
    "get_config",
    "get_shape",
    "list_archs",
    "reduced",
    "variant_for_shape",
]
