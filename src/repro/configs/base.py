"""Architecture configuration system.

One frozen dataclass describes every architecture in the zoo; families:
``dense`` | ``moe`` | ``ssm`` | ``hybrid`` | ``vlm`` | ``audio``.
Each assigned architecture file in this package instantiates the exact
published config and a ``reduced()`` smoke-test variant (2 layers,
d_model <= 512, <= 4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    every: int = 1          # layer i has an MoE FFN iff i % every == every - 1
    d_ff: int | None = None  # per-expert hidden dim (defaults to ArchConfig.d_ff)


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk: int = 256        # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    act: str = "silu"               # silu (SwiGLU) | gelu (GeGLU) | gelu_mlp (plain MLP)
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    rope: str = "rope"              # rope | mrope | sincos | learned | none
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    moe: MoESpec | None = None
    ssm: SSMSpec | None = None
    # hybrid: layer i is attention iff i % attn_period == attn_offset, else SSM.
    attn_period: int = 1
    attn_offset: int = 0
    # encoder-decoder (audio): encoder consumes precomputed frame embeddings.
    enc_dec: bool = False
    num_enc_layers: int = 0
    enc_seq: int = 1500
    # vlm: number of image-patch positions at the start of the sequence.
    vlm_patches: int = 0
    vlm_vision_dim: int = 0
    # long-context variant: sliding-window attention (rolling KV cache).
    sliding_window: int | None = None
    # numerics / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False
    fsdp: bool = False              # shard params over the data axis too
    # provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def attn_layer(self, i: int) -> bool:
        """Is layer ``i`` an attention layer (vs SSM)?"""
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return i % self.attn_period == self.attn_offset
        return True

    def moe_layer(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every == self.moe.every - 1)

    def supports_long_decode(self) -> bool:
        """long_500k runs for SSM/hybrid natively, others need sliding window."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ArchConfig, *, layers: int = 2, d_model: int = 256, vocab: int = 512) -> ArchConfig:
    """Reduced same-family variant for CPU smoke tests."""
    heads = max(2, min(4, cfg.num_heads))
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    kw = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads if cfg.head_dim == 0 else max(16, min(64, cfg.head_dim)),
        d_ff=d_model * 2,
        vocab=vocab,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
        fsdp=False,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(2, cfg.moe.top_k), d_ff=d_model
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk=32
        )
    if cfg.family == "hybrid":
        kw["num_layers"] = max(layers, cfg.attn_period)  # keep >=1 attention layer
        kw["attn_period"] = max(2, min(cfg.attn_period, kw["num_layers"]))
        kw["attn_offset"] = min(cfg.attn_offset, kw["attn_period"] - 1)
    if cfg.enc_dec:
        kw["num_enc_layers"] = 2
        kw["enc_seq"] = 64
    if cfg.vlm_patches:
        kw["vlm_patches"] = 16
        kw["vlm_vision_dim"] = 128
    if cfg.sliding_window is not None:
        kw["sliding_window"] = 64
    return cfg.replace(**kw)
