"""mamba2-2.7b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

64L, d_model 2560, attention-free (d_inner 5120, 80 heads of P=64,
ssm_state 128), vocab 50280.  Mixer-only layers (no FFN), like Mamba.
"""
from .base import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab=50280,
    rope="none",
    tie_embeddings=True,
    ssm=SSMSpec(d_state=128, head_dim=64, expand=2, conv_width=4, n_groups=1, chunk=256),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    fsdp=True,
    source="arXiv:2405.21060",
)
