"""llama3.2-1b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B].

16L, d_model 2048, 32H (GQA kv=8), d_ff 8192, vocab 128256.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    act="silu",
    rope="rope",
    rope_theta=500_000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    fsdp=True,
    source="hf:meta-llama/Llama-3.2-1B",
)
