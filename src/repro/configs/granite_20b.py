"""granite-20b [dense] — llama-arch code model [arXiv:2405.04324].

52L, d_model 6144, 48 q-heads with single-KV-head GQA (MQA), d_ff 24576,
vocab 49152.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    act="silu",
    rope="rope",
    tie_embeddings=False,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    fsdp=True,
    source="arXiv:2405.04324",
)
