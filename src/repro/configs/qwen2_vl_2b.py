"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

28L, d_model 1536, 12H (GQA kv=2), d_ff 8960, vocab 151936.  The vision
encoder is a stub: input_specs supplies precomputed patch embeddings
(vision_dim 1536) consumed through a linear projector; M-RoPE uses
(t, h, w) position streams.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    act="silu",
    rope="mrope",
    rope_theta=1e6,
    tie_embeddings=True,
    vlm_patches=256,
    vlm_vision_dim=1536,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    fsdp=True,
    source="arXiv:2409.12191",
)
