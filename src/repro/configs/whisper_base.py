"""whisper-base [audio] — enc-dec transformer backbone [arXiv:2212.04356].

6 encoder + 6 decoder layers, d_model 512, 8H, d_ff 2048, vocab 51865.
The mel-spectrogram + conv frontend is a stub: input_specs provides the
(B, 1500, 512) frame embeddings directly.  Positions are sinusoidal in both
stacks (deviation: whisper's decoder uses learned positions; sincos keeps
params independent of sequence length for the 32k/500k mechanical shapes).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    act="gelu_mlp",
    norm="layernorm",
    rope="sincos",
    tie_embeddings=True,
    enc_dec=True,
    num_enc_layers=6,
    enc_seq=1500,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    source="arXiv:2212.04356",
)
