"""minitron-8b [dense] — pruned nemotron [arXiv:2407.14679].

32L, d_model 4096, 32H (GQA kv=8), d_ff 16384, vocab 256000.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16384,
    vocab=256_000,
    act="silu",
    rope="rope",
    tie_embeddings=False,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    fsdp=True,
    source="arXiv:2407.14679",
)
