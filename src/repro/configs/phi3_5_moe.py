"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model 4096, 32H (GQA kv=8), per-expert d_ff 6400, vocab 32064,
MoE 16e top-2 on every layer.
"""
from .base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab=32064,
    act="silu",
    rope="rope",
    tie_embeddings=False,
    moe=MoESpec(num_experts=16, top_k=2, capacity_factor=1.25, every=1, d_ff=6400),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    fsdp=True,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
