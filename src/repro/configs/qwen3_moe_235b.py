"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family].

94L, d_model 4096, 64H (GQA kv=4, head_dim 128), per-expert d_ff 1536,
vocab 151936, MoE 128e top-8 on every layer.
"""
from .base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    act="silu",
    rope="rope",
    rope_theta=1e6,
    tie_embeddings=False,
    moe=MoESpec(num_experts=128, top_k=8, capacity_factor=1.25, every=1, d_ff=1536),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    fsdp=True,
    source="hf:Qwen/Qwen3-30B-A3B",
)
