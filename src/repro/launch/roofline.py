"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (per device, per step):

    compute    = HLO_FLOPs            / PEAK_FLOPS_BF16
    memory     = HLO_bytes_accessed   / HBM_BW
    collective = collective_bytes     / ICI_BW_PER_LINK

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (per-partition SPMD
module ⇒ per-device numbers).  collective_bytes is parsed from
``compiled.as_text()``: for every collective op we take its output shape and
apply ring-model per-device byte costs using the replica-group size found on
the op.  Conventions documented in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import re

from .mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_KINDS = (
    "all-gather-start", "all-gather", "all-reduce-start", "all-reduce",
    "reduce-scatter", "all-to-all", "collective-permute-start",
    "collective-permute",
)

# ``%name = TYPE[dims]{layout} op-name(...)`` — possibly tuple-typed.
_OP_RE = re.compile(
    r"=\s*(?P<ty>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<op>all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\b"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collective_bytes(hlo_text: str, default_group: int) -> CollectiveStats:
    """Per-device collective bytes via ring cost models.

    all-gather:      out × (N-1)/N
    reduce-scatter:  out × (N-1)         (out is the scattered shard)
    all-reduce:      out × 2(N-1)/N
    all-to-all:      out × (N-1)/N
    collective-permute: out
    """
    bytes_by: dict[str, float] = {}
    count_by: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op").replace("-start", "")
        nbytes = _shape_bytes(m.group("ty"))
        n = max(_group_size(line, default_group), 1)
        if op == "all-gather":
            cost = nbytes * (n - 1) / n
        elif op == "reduce-scatter":
            cost = nbytes * (n - 1)
        elif op == "all-reduce":
            cost = nbytes * 2 * (n - 1) / n
        elif op == "all-to-all":
            cost = nbytes * (n - 1) / n
        else:  # collective-permute
            cost = nbytes
        bytes_by[op] = bytes_by.get(op, 0.0) + cost
        count_by[op] = count_by.get(op, 0) + 1
    return CollectiveStats(bytes_by_kind=bytes_by, count_by_kind=count_by)


def scale_loop_collectives(stats: CollectiveStats, hlo_text: str) -> None:
    """Collectives inside while-loop bodies execute per iteration; XLA's text
    shows them once.  We approximate by multiplying bytes by the dominant
    scan trip count if the collective appears inside a while body.  (The
    trip count heuristic: largest constant in a while-condition compare.)

    NOTE: our models put collectives outside scan bodies (grad sync is
    post-backward), so this is a no-op in practice; kept for safety audits.
    """
    return None


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    model_flops: float
    collectives: CollectiveStats
    raw_flops: float = 0.0   # XLA cost_analysis (loop bodies counted once)
    raw_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW_PER_LINK

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "raw_flops": self.raw_flops,
            "raw_bytes": self.raw_bytes,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "collective_bytes_by_kind": self.collectives.bytes_by_kind,
            "collective_count_by_kind": self.collectives.count_by_kind,
        }


def build_roofline(compiled, model_flops: float, default_group: int) -> Roofline:
    """Roofline terms from the compiled module.

    Primary source is the trip-count-aware HLO walk (hlo_analysis) — XLA's
    built-in cost_analysis counts while-loop (scan) bodies once, undercounting
    layer-scanned models by ~num_layers.  The raw cost_analysis numbers are
    kept in the record for comparison."""
    from . import hlo_analysis

    text = compiled.as_text()
    cost = hlo_analysis.analyze(text, default_group)
    try:
        raw = compiled.cost_analysis()
        if isinstance(raw, list):
            raw = raw[0]
        raw_flops = float(raw.get("flops", 0.0))
        raw_bytes = float(raw.get("bytes accessed", 0.0))
    except Exception:
        raw_flops = raw_bytes = 0.0
    stats = CollectiveStats(bytes_by_kind=dict(cost.coll), count_by_kind={})
    r = Roofline(
        flops=cost.flops,
        hbm_bytes=cost.bytes,
        collective_bytes=stats.total_bytes,
        model_flops=model_flops,
        collectives=stats,
    )
    r.raw_flops = raw_flops
    r.raw_bytes = raw_bytes
    return r


def model_flops_for(cfg, shape, n_params: int, n_active: int) -> float:
    """MODEL_FLOPS convention: 6·N_active·tokens (train), 2·N_active·tokens
    (prefill), 2·N_active·batch (decode, one token).  Per device: divided by
    chip count at the call site (we report per-device terms)."""
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch
