"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from runs/dryrun/*.json.

Usage:  PYTHONPATH=src python -m repro.launch.report [--dir runs/dryrun]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

RUNS = pathlib.Path(__file__).resolve().parents[3] / "runs" / "dryrun"
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath: pathlib.Path, canonical: bool = True):
    """Canonical records: <arch>__<shape>__<mesh>.json (one per pair);
    sync-variant files (…__16x16_<sync>.json) are excluded unless
    canonical=False."""
    recs = []
    for f in sorted(dirpath.glob("*.json")):
        parts = f.stem.split("__")
        is_canon = len(parts) == 3 and parts[2] in ("16x16", "2x16x16")
        if canonical != is_canon:
            continue
        try:
            recs.append(json.loads(f.read_text()))
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping unreadable record {f}: {e}", file=sys.stderr)
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def roofline_table(recs, mesh="16x16") -> str:
    rows = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | bottleneck | "
        "useful FLOPs | HBM GiB/dev | compile (s) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    def key(r):
        return (r["arch"], SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 9)
    for r in sorted([r for r in recs if r["mesh"] == mesh], key=key):
        rl = r["roofline"]
        mem = r["memory"].get("total_per_device_bytes", 0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']*1e3:.1f} | {rl['memory_s']*1e3:.1f} "
            f"| {rl['collective_s']*1e3:.1f} | {rl['bottleneck']} | {rl['useful_flops_ratio']:.2f} "
            f"| {fmt_bytes(mem)} | {r['compile_s']:.0f} |"
        )
    return "\n".join(rows)


def dryrun_table(recs) -> str:
    rows = [
        "| arch | shape | mesh | params | FLOPs/dev | HBM bytes/dev | collective bytes/dev | "
        "ag / ar / rs / a2a / cp (GiB) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    def key(r):
        return (r["arch"], SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 9, r["mesh"])
    for r in sorted(recs, key=key):
        rl = r["roofline"]
        kinds = rl.get("collective_bytes_by_kind", {})
        gk = lambda k: kinds.get(k, 0) / 2**30
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['n_params']/1e9:.1f}B "
            f"| {rl['flops']:.2e} | {rl['hbm_bytes']:.2e} | {rl['collective_bytes']:.2e} "
            f"| {gk('all-gather'):.1f} / {gk('all-reduce'):.1f} / {gk('reduce-scatter'):.1f} / "
            f"{gk('all-to-all'):.1f} / {gk('collective-permute'):.1f} |"
        )
    return "\n".join(rows)


def adaptive_table(sizes, bits, alphas=None, gammas=None, rhos=None) -> str:
    """Markdown table of one adaptive bit plan: per-bucket elements, wire
    bits, solver α and (when known) the telemetry-estimated tail (γ, ρ).
    Used by ``launch.train --adaptive`` and ``examples/train_8clients.py``."""
    n = len(sizes)
    alphas = list(alphas) if alphas else [None] * n
    gammas = list(gammas) if gammas is not None else [None] * n
    rhos = list(rhos) if rhos is not None else [None] * n
    rows = ["| bucket | elements | bits | alpha | gamma | rho |",
            "|---|---|---|---|---|---|"]
    fmt = lambda v, spec: format(float(v), spec) if v is not None else "-"
    for b in range(n):
        rows.append(
            f"| {b} | {sizes[b]} | {bits[b]} | {fmt(alphas[b], '.3e')} "
            f"| {fmt(gammas[b], '.2f')} | {fmt(rhos[b], '.3f')} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(RUNS))
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load(pathlib.Path(args.dir))
    print(f"## Roofline ({args.mesh}, {len(recs)} records)\n")
    print(roofline_table(recs, args.mesh))
    print("\n## Dry-run detail\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()
