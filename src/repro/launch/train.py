"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs on whatever devices exist (CPU: mesh 1x1 by default).  Use
``--fake-devices N`` to exercise the distributed path on a host mesh.
"""
import argparse
import os
import sys


def _early_args(argv):
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--fake-devices", type=int, default=0)
    ns, _ = ap.parse_known_args(argv)
    return ns


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    early = _early_args(argv)
    if early.fake_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={early.fake_devices}"
        ).strip()

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint.io import load_checkpoint, latest_step, save_checkpoint
    from repro.configs import get_config, list_archs, reduced as make_reduced
    from repro.core.codecs import known_methods
    from repro.core.compressors import CompressorConfig
    from repro.data.synthetic import lm_batch
    from repro.dist.train_step import SYNC_MODES, TrainStepConfig, make_train_step
    from repro.launch.mesh import make_mesh_from_spec
    from repro.models import init_lm
    from repro.optim.optimizers import get_optimizer

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true", help="reduced config (CPU-trainable)")
    ap.add_argument("--mesh", default=None, help="e.g. 4x2; default all devices data-parallel")
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--sync", default="two_phase", choices=SYNC_MODES)
    ap.add_argument("--method", default="tnqsgd", choices=known_methods())
    ap.add_argument("--bits", type=int, default=3)
    ap.add_argument("--rank", type=int, default=4,
                    help="factor rank for rank-based codecs (powersgd)")
    ap.add_argument("--bucket-mb", type=float, default=4.0,
                    help="bucketed-codec target bucket size; 0 = per-leaf codec")
    ap.add_argument("--ef", action="store_true",
                    help="error feedback on the worker-side compressor (not checkpointed)")
    ap.add_argument("--dropout-rate", type=float, default=0.0,
                    help="elastic: per-(step,peer) dropout probability under "
                         "the deterministic counter-hash schedule (0 = full "
                         "participation)")
    ap.add_argument("--chaos-trace", default=None,
                    help="elastic: scripted live-mask JSON trace "
                         "(repro.elastic.save_trace format); overrides "
                         "--dropout-rate")
    ap.add_argument("--fp16-threshold", type=int, default=0,
                    help="buckets of at most this many local elements ship "
                         "raw fp16 instead of the quantizer (0 = off)")
    ap.add_argument("--adaptive", action="store_true",
                    help="online tail telemetry + wire-budget bit allocation per bucket")
    ap.add_argument("--wire-budget-mb", type=float, default=0.0,
                    help="adaptive wire budget (bytes/step, MB); 0 = match the "
                         "fixed --bits allocation")
    ap.add_argument("--replan-every", type=int, default=10,
                    help="steps between adaptive bit replans")
    ap.add_argument("--optimizer", default="momentum_sgd")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--obs-dir", default=None,
                    help="write observability JSONL here; turns on in-graph "
                         "per-bucket compression metrics (see repro.obs)")
    ap.add_argument("--obs-every", type=int, default=1,
                    help="steps between compression-metric events")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    mesh = make_mesh_from_spec(args.mesh or str(len(jax.devices())))
    print(f"arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"sync={args.sync} method={args.method} bits={args.bits}")

    # repro: allow REPRO204 (CLI entry point: the reproducible demo seed)
    params, logical = init_lm(jax.random.key(0), cfg)
    opt = get_optimizer(args.optimizer, lr=args.lr) if args.optimizer == "momentum_sgd" else get_optimizer(args.optimizer)
    acfg = None
    if args.adaptive:
        from repro.adaptive.controller import AdaptiveConfig

        acfg = AdaptiveConfig(wire_budget_mb=args.wire_budget_mb,
                              replan_every=args.replan_every)
    ecfg = None
    if args.chaos_trace:
        from repro.elastic import load_trace

        ecfg = load_trace(args.chaos_trace).elastic()
        print(f"elastic: chaos trace {args.chaos_trace} "
              f"({len(ecfg.trace)} steps, wraps modulo length)")
    elif args.dropout_rate > 0.0:
        from repro.elastic import ElasticConfig

        ecfg = ElasticConfig(rate=args.dropout_rate)
        print(f"elastic: scheduled dropout rate {args.dropout_rate}")
    if ecfg is not None and args.bucket_mb <= 0:
        ap.error("--dropout-rate/--chaos-trace require the bucketed codec "
                 "(--bucket-mb > 0)")
    obs_sink = obs_rec = drift_mon = None
    if args.obs_dir:
        from repro.obs import DriftMonitor, JsonlSink, SpanRecorder

        if args.bucket_mb <= 0:
            ap.error("--obs-dir requires the bucketed codec (--bucket-mb > 0)")
        obs_sink = JsonlSink(os.path.join(args.obs_dir, "events.jsonl"))
        obs_rec = SpanRecorder(sink=obs_sink)
        drift_mon = DriftMonitor(sink=obs_sink)
    ts = TrainStepConfig(sync=args.sync,
                         compressor=CompressorConfig(method=args.method, bits=args.bits,
                                                     rank=args.rank,
                                                     approx_gmin=args.adaptive),
                         bucket_mb=args.bucket_mb, error_feedback=args.ef, adaptive=acfg,
                         elastic=ecfg, fp16_threshold=args.fp16_threshold,
                         metrics_compression=args.obs_dir is not None)
    batch0 = lm_batch(cfg, jnp.uint32(0), args.batch, args.seq)
    opt_state = opt.init(params)
    stepper = None
    if args.adaptive:
        from repro.adaptive.runtime import AdaptiveStepper

        stepper = AdaptiveStepper(cfg, mesh, logical, opt, ts, batch0,
                                  opt_state_like=jax.eval_shape(lambda: opt_state),
                                  params_like=params, obs=obs_rec, drift=drift_mon)
        pspecs = stepper.pspecs
        print(f"adaptive: {len(stepper.sizes)} buckets, wire budget "
              f"{stepper.budget/2**20:.2f} MB/step, replan every {acfg.replan_every}")
    else:
        step_fn, pspecs = make_train_step(cfg, mesh, logical, opt, ts, batch0,
                                          opt_state_like=jax.eval_shape(lambda: opt_state))

    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P))
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start = load_checkpoint(args.ckpt_dir, (params, opt_state))
        print(f"resumed from step {start}")
    params = jax.device_put(params, sh)
    # optimizer state mirrors the param tree -> same shardings per leaf
    from repro.dist.train_step import _opt_specs, init_ef_state
    from jax.sharding import PartitionSpec as _P
    o_specs = _opt_specs(jax.eval_shape(lambda: opt_state), params, pspecs)
    opt_state = jax.device_put(opt_state, jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                                                       is_leaf=lambda x: isinstance(x, _P)))
    ef_state = init_ef_state(params, mesh, pspecs, ts) if args.ef else None
    tstate = stepper.init_telemetry() if stepper is not None else None

    import contextlib

    for i in range(start, start + args.steps):
        b = lm_batch(cfg, jnp.uint32(i), args.batch, args.seq)
        span = obs_rec.span("train.step", step=i) if obs_rec is not None else contextlib.nullcontext()
        with span:
            if stepper is not None:
                prev_bits = stepper.bits
                params, opt_state, ef_state, tstate, m = stepper.step(
                    params, opt_state, ef_state, tstate, b, i)
                if stepper.bits != prev_bits:
                    from repro.launch.report import adaptive_table
                    plan, tails = stepper.plan, stepper.tails
                    print(f"step {i}: replanned bits -> {plan.bits} "
                          f"({plan.spend_bytes}/{plan.budget_bytes} B/step)")
                    print(adaptive_table(stepper.sizes, plan.bits, plan.alphas,
                                         gammas=None if tails is None else tails.gamma,
                                         rhos=None if tails is None else tails.rho))
            elif args.ef:
                params, opt_state, ef_state, m = step_fn(params, opt_state, ef_state, b, jnp.uint32(i))
            else:
                params, opt_state, m = step_fn(params, opt_state, b, jnp.uint32(i))
        if obs_sink is not None and "compression" in m and i % max(args.obs_every, 1) == 0:
            from repro.obs import metrics_event

            comp = jax.device_get(m["compression"])
            event = metrics_event(i, comp)
            obs_sink.write(event)
            if drift_mon is not None:
                drift_mon.check_ratio([row["realized_mse"] for row in event["buckets"]],
                                      [row["predicted_mse"] for row in event["buckets"]], step=i)
        lvs = ""
        if ecfg is not None and "live_count" in m:
            lv = jax.device_get(m["live"]).reshape(-1)
            n_peers, n_live = int(lv.shape[0]), int(round(float(m["live_count"][0])))
            lvs = f" live {n_live}/{n_peers}"
            if n_live < n_peers and obs_sink is not None:
                from repro.obs.sink import SCHEMA_VERSION

                obs_sink.write({"v": SCHEMA_VERSION, "kind": "dropout",
                                "step": i, "live": n_live, "n_peers": n_peers,
                                "dropped": [p for p in range(n_peers)
                                            if float(lv[p]) == 0.0]})
            if drift_mon is not None:
                drift_mon.check_participation(n_live / n_peers, step=i)
        if args.log_every and i % args.log_every == 0:
            gn = f" gnorm {float(m['gnorm'][0]):.3f}" if "gnorm" in m else ""
            print(f"step {i:5d} loss {float(m['loss'][0]):.4f}{gn}{lvs}", flush=True)
        if args.ckpt_every and args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            host_p = jax.tree.map(lambda x: jax.device_get(x), (params, opt_state))
            save_checkpoint(args.ckpt_dir, i + 1, host_p)
            print(f"checkpointed step {i+1}")
    if obs_sink is not None:
        obs_sink.close()
        print(f"obs: {obs_sink.n_written} events -> {obs_sink.path} "
              f"(render with `python -m repro.obs report --dir {args.obs_dir}`)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
