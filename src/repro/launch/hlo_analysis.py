"""Trip-count-aware cost analysis of compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body* once, but our
models scan over layers — FLOPs, HBM traffic and (crucially) the GSPMD
collectives inside the layer loop execute ``trip_count`` times per step.
This module walks the HLO computation graph, recursively costing called
computations and multiplying while bodies by their trip count.

Cost model per instruction:
- dot:           2 · prod(output dims) · prod(contracted lhs dims) FLOPs
- elementwise:   1 FLOP per output element (exp/tanh etc. kept at 1 — dots
                 dominate every model here)
- bytes:         output bytes + operand bytes at fusion/computation
                 boundaries (internal fusion temporaries excluded, matching
                 XLA's "bytes accessed" semantics)
- collectives:   ring-model per-device bytes (see roofline.py), attributed
                 per kind, scaled by enclosing loop trip counts

Trip counts are parsed from the while condition: the constant compared
against the induction variable.  Validated against analytic 6·N·D FLOPs in
tests (agreement within the attention/dispatch overhead margin).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
# Lazy type match: the type may be a tuple containing /*index=N*/ comments;
# the first ``word(`` token after '=' is always the opcode.
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*(?P<ty>.+?)\s+"
    r"(?P<op>[\w\-]+)\((?P<args>.*)$"
)
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BDIMS = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_GROUPS = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "floor", "ceil", "round-nearest-afz", "select", "compare", "and", "or",
    "xor", "not", "clamp", "convert", "cosine", "sine", "atan2",
    "exponential-minus-one", "log-plus-one", "logistic", "cbrt",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
}

_COLLECTIVES = {
    "all-gather", "all-gather-start", "all-reduce", "all-reduce-start",
    "reduce-scatter", "all-to-all", "collective-permute",
    "collective-permute-start",
}


def _shape_elems_bytes(ty: str) -> tuple[int, int]:
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE.findall(ty):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


def _shape_dims(ty: str) -> list[int]:
    m = _SHAPE.search(ty)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict[str, float] | None = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {}

    def add(self, other: "Cost", scale: float = 1.0):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * scale


class HloModule:
    def __init__(self, text: str, default_group: int):
        self.default_group = default_group
        self.computations: dict[str, list] = {}
        self.entry: str | None = None
        self._parse(text)
        self._memo: dict[str, Cost] = {}
        self._trip_memo: dict[str, int] = {}

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            stripped = line.strip()
            # Computation headers are the only lines ending in '{' (return
            # types may embed /*index=N*/ comments, so no '=' heuristics).
            m = _COMP_START.match(stripped) if stripped.endswith("{") else None
            if m:
                cur = m.group(2)
                self.computations[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is not None:
                self.computations[cur].append(line)

    # -- trip counts ---------------------------------------------------------

    def trip_count(self, cond_name: str) -> int:
        if cond_name in self._trip_memo:
            return self._trip_memo[cond_name]
        trip = 1
        for line in self.computations.get(cond_name, ()):
            for c in _CONST_INT.findall(line):
                trip = max(trip, int(c))
        self._trip_memo[cond_name] = trip
        return trip

    # -- instruction costing -------------------------------------------------

    @staticmethod
    def _operand_list(args: str) -> str:
        """Operand sublist of an instruction line (drop attrs/metadata)."""
        return args.split(")", 1)[0]

    def _lhs_dims(self, args: str, symbols: dict[str, str]) -> list:
        """Dims of the first (lhs) operand.

        Newer HLO text carries inline operand types ("f32[64,128]{1,0} %x");
        older text has bare names resolved through the symbol table.
        """
        operands = self._operand_list(args)
        if _SHAPE.match(operands.strip()):
            return _shape_dims(operands)
        lhs_name = operands.split(",")[0].strip().lstrip("%")
        return _shape_dims(symbols.get(lhs_name, ""))

    def _operand_bytes(self, args: str, symbols: dict[str, str]) -> int:
        operands = self._operand_list(args)
        if _SHAPE.search(operands):
            return _shape_elems_bytes(operands)[1]
        return sum(
            _shape_elems_bytes(symbols.get(a.strip().lstrip("%"), ""))[1]
            for a in operands.split(",")
        )

    def _dot_flops(self, line: str, ty: str, args: str, symbols: dict[str, str]) -> float:
        out_elems, _ = _shape_elems_bytes(ty)
        m = _LHS_CDIMS.search(line)
        contracted = 1
        if m:
            dims = self._lhs_dims(args, symbols)
            for idx in m.group(1).split(","):
                if idx and dims and int(idx) < len(dims):
                    contracted *= dims[int(idx)]
        return 2.0 * out_elems * contracted

    def _collective_bytes(self, op: str, line: str, ty: str) -> tuple[str, float]:
        _, nbytes = _shape_elems_bytes(ty)
        n = self.default_group
        m = _GROUPS_IOTA.search(line)
        if m:
            n = int(m.group(2))
        else:
            m = _GROUPS.search(line)
            if m:
                n = len(m.group(1).split(","))
        n = max(n, 1)
        kind = op.replace("-start", "")
        if kind == "all-gather":
            cost = nbytes * (n - 1) / n
        elif kind == "reduce-scatter":
            cost = nbytes * (n - 1)
        elif kind == "all-reduce":
            cost = nbytes * 2 * (n - 1) / n
        elif kind == "all-to-all":
            cost = nbytes * (n - 1) / n
        else:
            cost = nbytes
        return kind, cost

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = Cost()
        self._memo[comp_name] = total  # guard recursion
        symbols: dict[str, str] = {}
        for line in self.computations.get(comp_name, ()):
            m = _INSTR.match(line)
            if not m:
                continue
            name, ty, op, args = m.group("name"), m.group("ty"), m.group("op"), m.group("args")
            symbols[name] = ty
            out_elems, out_bytes = _shape_elems_bytes(ty)

            if op == "while":
                b = _BODY.search(line)
                c = _COND.search(line)
                if b:
                    trip = self.trip_count(c.group(1)) if c else 1
                    total.add(self.cost_of(b.group(1)), scale=trip)
                    if c:
                        total.add(self.cost_of(c.group(1)), scale=trip)
                continue
            if op in ("fusion", "call", "async-start"):
                cm = _CALLS.search(line)
                if cm:
                    total.add(self.cost_of(cm.group(1)))
                # boundary bytes: operands + output
                total.bytes += out_bytes + self._operand_bytes(args, symbols)
                continue
            if op == "conditional":
                for cm in re.findall(r"branch_computations=\{([^}]*)\}", line):
                    for b in cm.split(","):
                        total.add(self.cost_of(b.strip().lstrip("%")))
                continue
            if op in _COLLECTIVES:
                kind, cb = self._collective_bytes(op, line, ty)
                total.coll[kind] = total.coll.get(kind, 0.0) + cb
                total.bytes += out_bytes
                continue
            if op == "dot":
                total.flops += self._dot_flops(line, ty, args, symbols)
                total.bytes += out_bytes + self._operand_bytes(args, symbols)
                continue
            if op == "convolution":
                # depthwise/short convs only in this codebase; approximate
                total.flops += 2.0 * out_elems * 4
                total.bytes += out_bytes
                continue
            if op in _ELEMENTWISE:
                total.flops += out_elems
                # elementwise at top level (unfused) reads+writes
                total.bytes += out_bytes
                continue
            if op in ("reduce", "reduce-window"):
                cm = _CALLS.search(line)
                total.flops += out_elems * 2
                total.bytes += out_bytes
                continue
            # data movement ops: copy/transpose/reshape/broadcast/slice/...
            if op in ("copy", "transpose", "reshape", "broadcast", "slice",
                      "concatenate", "pad", "gather", "scatter", "dynamic-slice",
                      "dynamic-update-slice", "iota", "constant", "parameter",
                      "get-tuple-element", "tuple", "bitcast", "rng",
                      "rng-bit-generator", "sort", "partition-id", "replica-id",
                      "after-all", "copy-start", "copy-done", "all-gather-done",
                      "all-reduce-done", "custom-call", "optimization-barrier",
                      "select-and-scatter", "compare", "map", "domain",
                      "collective-permute-done", "async-done", "async-update"):
                if op in ("copy", "transpose", "sort", "gather", "scatter",
                          "concatenate", "dynamic-update-slice"):
                    total.bytes += 2 * out_bytes
                continue
        return total

    def entry_cost(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.cost_of(self.entry)


def analyze(hlo_text: str, default_group: int) -> Cost:
    return HloModule(hlo_text, default_group).entry_cost()
