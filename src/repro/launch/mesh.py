"""Production mesh construction.

Target hardware: TPU v5e pods — 256 chips per pod (16x16 'data' x 'model'),
two pods for the multi-pod dry-run (512 chips, leading 'pod' axis).
``make_production_mesh`` is a function (never a module constant) so importing
this module cannot touch JAX device state.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh_from_spec(spec: str) -> Mesh:
    """'16x16' -> ('data','model'); '2x16x16' -> ('pod','data','model');
    '4' -> pure data-parallel."""
    dims = tuple(int(x) for x in spec.lower().split("x"))
    if len(dims) == 1:
        axes = ("data",)
    elif len(dims) == 2:
        axes = ("data", "model")
    elif len(dims) == 3:
        axes = ("pod", "data", "model")
    else:
        raise ValueError(f"bad mesh spec {spec!r}")
    return jax.make_mesh(dims, axes, axis_types=(AxisType.Auto,) * len(dims))


# TPU v5e hardware constants (per chip) for the roofline model.
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW_PER_LINK = 50e9         # B/s (~ per link)
HBM_PER_CHIP = 16 * 2**30      # bytes
