"""Abstract (allocation-free) model/init/input specs for the dry-run.

Everything here returns ShapeDtypeStructs via jax.eval_shape — the full-size
configs (up to 398B params) are never materialized on the CPU host.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models.transformer import Batch, init_caches, init_lm


def abstract_init(cfg: ArchConfig) -> tuple[Any, Any]:
    """(params ShapeDtypeStruct tree, logical-axes tree) without allocation."""
    box = {}

    def f(key):
        p, la = init_lm(key, cfg)
        box["la"] = la
        return p

    # repro: allow REPRO204 (eval_shape aval-only trace; value never used)
    shapes = jax.eval_shape(f, jax.random.key(0))
    return shapes, box["la"]


def abstract_batch(cfg: ArchConfig, batch: int, seq: int) -> Batch:
    """Batch of ShapeDtypeStructs matching data.synthetic.lm_batch."""
    def f():
        tokens = jnp.zeros((batch, seq), jnp.int32)
        labels = jnp.zeros((batch, seq), jnp.int32)
        positions = None
        patches = None
        frames = None
        if cfg.vlm_patches:
            patches = jnp.zeros((batch, cfg.vlm_patches, cfg.vlm_vision_dim), jnp.float32)
            positions = jnp.zeros((3, batch, seq), jnp.int32)
        if cfg.enc_dec:
            frames = jnp.zeros((batch, cfg.enc_seq, cfg.d_model), jnp.float32)
        return Batch(tokens=tokens, labels=labels, positions=positions, patches=patches, frames=frames)

    return jax.eval_shape(f)


def abstract_caches(cfg: ArchConfig, batch: int, cache_len: int) -> Any:
    return jax.eval_shape(lambda: init_caches(cfg, batch, cache_len))


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """All model inputs for a given input shape, as ShapeDtypeStructs.

    train/prefill: {'batch': Batch}.  decode: {'token','caches','position'}."""
    if shape.kind in ("train", "prefill"):
        return {"batch": abstract_batch(cfg, shape.global_batch, shape.seq_len)}
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    caches = abstract_caches(cfg, shape.global_batch, shape.seq_len)
    return {
        "token": token,
        "caches": caches,
        "position": jax.ShapeDtypeStruct((), jnp.int32),
    }


def count_params(params_like: Any) -> int:
    total = 0
    for x in jax.tree.leaves(params_like):
        n = 1
        for d in x.shape:
            n *= int(d)
        total += n
    return total


def count_active_params(cfg: ArchConfig, params_like: Any, logical: Any) -> int:
    """Active params per token: expert tensors count top_k/E of their size."""
    total = 0
    leaves = jax.tree.leaves(params_like)
    llog = jax.tree.leaves(logical, is_leaf=lambda x: isinstance(x, tuple))
    for x, la in zip(leaves, llog):
        n = 1
        for d in x.shape:
            n *= d
        if cfg.moe is not None and "expert" in la:
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
    return int(total)
