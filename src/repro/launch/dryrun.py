import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_PREFIX", "")
    + " --xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
).strip()

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against the production mesh with 512 placeholder host devices,
then extract memory/cost/collective analyses for §Dry-run and §Roofline.

The two lines above MUST stay first: JAX locks the device count at first
initialization, and the dry-run (only) needs 512 fake devices.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
  python -m repro.launch.dryrun --all   # subprocess per pair (isolation)
"""

import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, get_shape, list_archs, variant_for_shape
from repro.core.compressors import CompressorConfig
from repro.dist.serve_step import make_serve_fns
from repro.dist.train_step import TrainStepConfig, batch_pspecs, make_train_step, _opt_specs
from repro.launch import roofline as rl
from repro.launch.mesh import make_mesh_from_spec, make_production_mesh
from repro.launch.specs import abstract_batch, abstract_init, count_active_params, count_params
from repro.optim.optimizers import get_optimizer

RUNS_DIR = pathlib.Path(__file__).resolve().parents[3] / "runs" / "dryrun"


def _with_sharding(tree_like, spec_tree, mesh):
    def one(x, s):
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=NamedSharding(mesh, s))

    return jax.tree.map(
        one, tree_like, spec_tree,
    )


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        if hasattr(ma, k):
            out[k] = int(getattr(ma, k))
    if out:
        out["total_per_device_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool, sync: str, mesh_spec: str | None,
            bits: int, method: str, seq_rules: bool) -> dict:
    shape = get_shape(shape_name)
    cfg = variant_for_shape(get_config(arch), shape)
    mesh = make_mesh_from_spec(mesh_spec) if mesh_spec else make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    params_like, logical = abstract_init(cfg)
    n_params = count_params(params_like)
    n_active = count_active_params(cfg, params_like, logical)

    if shape.kind == "train":
        if shape.name == "long_500k":
            raise ValueError("long_500k is decode-only")
        opt = get_optimizer("momentum_sgd")
        batch_like = abstract_batch(cfg, shape.global_batch, shape.seq_len)
        opt_like = jax.eval_shape(opt.init, params_like)
        ts = TrainStepConfig(sync=sync, compressor=CompressorConfig(method=method, bits=bits))
        step_fn, pspecs = make_train_step(cfg, mesh, logical, opt, ts, batch_like, opt_state_like=opt_like, params_like=params_like)
        p_avals = _with_sharding(params_like, pspecs, mesh)
        o_specs = _opt_specs(opt_like, params_like, pspecs)
        o_avals = _with_sharding(opt_like, o_specs, mesh)
        dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
        b_avals = _with_sharding(batch_like, batch_pspecs(batch_like, dp), mesh)
        lowered = step_fn.lower(p_avals, o_avals, b_avals,
                                jax.ShapeDtypeStruct((), jnp.uint32, sharding=NamedSharding(mesh, P())))
    elif shape.kind == "prefill":
        batch_like = abstract_batch(cfg, shape.global_batch, shape.seq_len)
        prefill_fn, _, pspecs, _ = make_serve_fns(cfg, mesh, logical, batch_like, shape.global_batch, shape.seq_len, params_like=params_like)
        p_avals = _with_sharding(params_like, pspecs, mesh)
        lowered = prefill_fn.lower(p_avals, batch_like)
    else:  # decode
        from repro.launch.specs import abstract_caches

        _, decode_fn, pspecs, cspecs = make_serve_fns(cfg, mesh, logical, None, shape.global_batch, shape.seq_len, params_like=params_like)
        p_avals = _with_sharding(params_like, pspecs, mesh)
        caches_like = abstract_caches(cfg, shape.global_batch, shape.seq_len)
        c_avals = _with_sharding(caches_like, cspecs, mesh)
        token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = decode_fn.lower(p_avals, token, c_avals, pos)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = _memory_analysis_dict(compiled)
    model_flops = rl.model_flops_for(cfg, shape, n_params, n_active) / n_chips
    roof = rl.build_roofline(compiled, model_flops, default_group=n_chips)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "axes": list(mesh.axis_names),
        "chips": n_chips,
        "sync": sync if shape.kind == "train" else None,
        "kind": shape.kind,
        "n_params": n_params,
        "n_active_params": n_active,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "roofline": roof.to_dict(),
        "fsdp": cfg.fsdp,
        "sliding_window": cfg.sliding_window,
    }
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default=None, help="override mesh spec, e.g. 4x2")
    ap.add_argument("--sync", default="faithful", help="train grad sync mode")
    ap.add_argument("--method", default="tnqsgd")
    ap.add_argument("--bits", type=int, default=3)
    ap.add_argument("--seq-rules", action="store_true", help="sequence-parallel activations")
    ap.add_argument("--all", action="store_true", help="run every (arch x shape) via subprocesses")
    ap.add_argument("--both-meshes", action="store_true", help="with --all: also run multi-pod")
    ap.add_argument("--mp-only", action="store_true", help="with --all: multi-pod mesh only")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    RUNS_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        failures = []
        meshes = [True] if args.mp_only else ([False, True] if args.both_meshes else [False])
        for arch in list_archs():
            for shape in INPUT_SHAPES:
                for mp in meshes:
                    tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                    out = RUNS_DIR / f"{tag}{args.tag}.json"
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--sync", args.sync,
                           "--method", args.method, "--bits", str(args.bits),
                           "--out", str(out)]
                    if mp:
                        cmd.append("--multi-pod")
                    t0 = time.time()
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    status = "OK" if r.returncode == 0 else "FAIL"
                    print(f"{status:4s} {tag} ({time.time()-t0:.0f}s)", flush=True)
                    if r.returncode != 0:
                        failures.append(tag)
                        print(r.stdout[-2000:], r.stderr[-2000:], flush=True)
        print(f"done: {len(failures)} failures: {failures}")
        return 1 if failures else 0

    try:
        rec = run_one(args.arch, args.shape, multi_pod=args.multi_pod, sync=args.sync,
                      mesh_spec=args.mesh, bits=args.bits, method=args.method,
                      seq_rules=args.seq_rules)
    except Exception:
        traceback.print_exc()
        return 1
    out = args.out or (RUNS_DIR / f"{args.arch}__{args.shape}__{'mp' if args.multi_pod else 'sp'}{args.tag}.json")
    pathlib.Path(out).write_text(json.dumps(rec, indent=2))
    r = rec["roofline"]
    mem = rec["memory"].get("total_per_device_bytes", 0)
    print(f"{args.arch} x {args.shape} [{rec['mesh']}]: "
          f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
          f"collective={r['collective_s']*1e3:.2f}ms bottleneck={r['bottleneck']} "
          f"useful={r['useful_flops_ratio']:.2f} mem/dev={mem/2**30:.2f}GiB "
          f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s)")
    print(json.dumps(rec["memory"], indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
