"""Optimizers as pure pytree transforms (no external deps).

The paper trains with momentum SGD (lr 0.01, momentum 0.9, weight decay
5e-4); AdamW is provided for the LM zoo.  State pytrees mirror the param
pytree, so FSDP-sharded params get FSDP-sharded optimizer state for free.
"""
from __future__ import annotations

from collections.abc import Callable
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(params, grads, state, step) -> (new_params, new_state)


def momentum_sgd(lr: float = 0.01, momentum: float = 0.9, weight_decay: float = 5e-4) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(params, grads, state, step):
        del step

        def one(p, g, m):
            g32 = g.astype(jnp.float32)
            if weight_decay and p.ndim > 1:
                g32 = g32 + weight_decay * p.astype(jnp.float32)
            m_new = momentum * m.astype(jnp.float32) + g32
            p_new = p.astype(jnp.float32) - lr * m_new
            return p_new.astype(p.dtype), m_new.astype(m.dtype)

        out = jax.tree.map(one, params, grads, state)
        return (
            jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple)),
            jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple)),
        )

    return Optimizer(init=init, update=update)


class AdamWState(NamedTuple):
    m: Any
    v: Any


def adamw(
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        return AdamWState(
            m=jax.tree.map(jnp.zeros_like, params),
            v=jax.tree.map(jnp.zeros_like, params),
        )

    def update(params, grads, state, step):
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t

        def one(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            upd = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
            p32 = p.astype(jnp.float32)
            if weight_decay and p.ndim > 1:
                upd = upd + weight_decay * p32
            p_new = p32 - lr * upd
            return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

        out = jax.tree.map(one, params, grads, state.m, state.v)
        pick = lambda i: jax.tree.map(lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), AdamWState(m=pick(1), v=pick(2))

    return Optimizer(init=init, update=update)


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "momentum_sgd":
        return momentum_sgd(**kw)
    if name == "adamw":
        return adamw(**kw)
    raise ValueError(f"unknown optimizer {name!r}")
