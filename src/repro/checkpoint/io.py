"""Minimal pytree checkpointing (npz-based, no external deps).

Layout: <dir>/step_<N>/arrays.npz + tree.json (treedef via flattened key
paths).  Multi-host is out of scope for the CPU container; sharded arrays
are gathered on save (callers checkpoint from unsharded copies in tests).
"""
from __future__ import annotations

import json
import pathlib
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save_checkpoint(directory: str | pathlib.Path, step: int, tree: Any) -> pathlib.Path:
    d = pathlib.Path(directory) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    items = _flatten_with_paths(tree)
    arrays = {}
    dtypes = {}
    for i, (k, v) in enumerate(items):
        a = np.asarray(v)
        dtypes[f"a{i}"] = str(a.dtype)
        if a.dtype == jax.numpy.bfloat16:
            a = a.view(np.uint16)  # numpy.savez cannot serialize bf16
        arrays[f"a{i}"] = a
    np.savez(d / "arrays.npz", **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    (d / "tree.json").write_text(json.dumps({
        "paths": [k for k, _ in items],
        "dtypes": dtypes,
        "treedef": str(treedef),
        "step": step,
    }))
    return d


def load_checkpoint(directory: str | pathlib.Path, tree_like: Any, step: int | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``; returns (tree, step)."""
    root = pathlib.Path(directory)
    if step is None:
        steps = sorted(p.name for p in root.glob("step_*"))
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {root}")
        d = root / steps[-1]
        step = int(steps[-1].split("_")[1])
    else:
        d = root / f"step_{step:08d}"
    data = np.load(d / "arrays.npz")
    meta = json.loads((d / "tree.json").read_text())
    dtypes = meta.get("dtypes", {})
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    if len(data.files) != len(leaves):
        raise ValueError(f"checkpoint has {len(data.files)} leaves, expected {len(leaves)}")
    restored = []
    for i in range(len(leaves)):
        a = data[f"a{i}"]
        if dtypes.get(f"a{i}") == "bfloat16":
            a = a.view(jax.numpy.bfloat16)
        restored.append(jax.numpy.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, restored), step


def latest_step(directory: str | pathlib.Path) -> int | None:
    root = pathlib.Path(directory)
    steps = sorted(p.name for p in root.glob("step_*"))
    return int(steps[-1].split("_")[1]) if steps else None
