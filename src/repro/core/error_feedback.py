"""Error feedback (EF) on top of the truncated quantizers — beyond-paper.

Truncation makes the paper's compressor *biased* (E[C(g)] = T_α(g) ≠ g); the
bias term in Lemma 2 decays as α^(3-γ) but never vanishes. Error feedback
(Seide et al. 2014; EF21) re-injects the residual into the next round:

    c_t = C(g_t + e_t);   e_{t+1} = g_t + e_t - c_t

which turns the truncation bias into a compensated term — asymptotically the
truncated scheme converges like its unbiased counterpart while keeping the
same wire format.  This composes with every method in the registry and is
exposed as `TrainStepConfig`-independent state (one fp32 pytree per client).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .compressors import CompressorConfig, compress_decompress


def init_error(params_or_grads: Any) -> Any:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params_or_grads)


def compress_with_feedback(
    cfg: CompressorConfig, grads: Any, error: Any, key: jax.Array
) -> tuple[Any, Any]:
    """Returns (compressed grads to transmit, new error state)."""
    leaves, treedef = jax.tree.flatten(grads)
    errs = treedef.flatten_up_to(error)
    keys = jax.random.split(key, len(leaves))
    outs, new_errs = [], []
    for g, e, k in zip(leaves, errs, keys):
        corrected = g.astype(jnp.float32) + e
        c = compress_decompress(cfg, corrected, k)
        outs.append(c.astype(g.dtype))
        new_errs.append(corrected - c.astype(jnp.float32))
    return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(treedef, new_errs)
