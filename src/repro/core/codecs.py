"""Codec-plugin registry: the compressor interface the bucketed collectives
drive.

Every compressor family registers one :class:`Codec` per method name.  A
codec owns the *local* halves of the sync — planning, the fused
encode→pack(→residual) pass, the fused decode(→reduce) pass, and the static
wire/state geometry — while ``dist.sharded_codec`` owns only the collective
wiring (all-gather / all-to-all, key folding, fused-tensor offsets).  The
collective bodies never inspect ``cfg.method``; they branch exclusively on
the interface (``chunkable``, ``state_extra``), which is what lets a new
family (fp8, sparsification, …) plug in without touching collective code.

Wire contract
    A codec's transmission for an ``n``-element flat bucket is a single 1-D
    uint32 vector of exactly ``wire_words(cfg, n)`` words (trace-time
    static): everything a *peer* needs to decode — packed codes and the
    bitcast per-bucket codebook for the quantizers, the bitcast P/Q factors
    for ``powersgd``.  ``decode_reduce`` consumes the (peers, wire_words)
    gathered rows and returns the (n,) fp32 peer mean; ``decode_rows``
    returns one decoded row per peer (the all-gather phase-2 shape).

Chunking contract (``chunkable``)
    Chunkable codecs additionally split a bucket into ``n_chunks`` peer
    chunks for the two-phase reduce-scatter: ``encode_chunks`` returns
    (n_chunks, chunk_wire_words) rows such that row ``j`` decodes to peer
    ``j``'s chunk of ``chunk_elems`` elements.  Non-chunkable codecs (the
    low-rank family — factor matrices do not slice element-wise) are carried
    through the same all-to-all by tiling their full wire into every row
    (an embedded all-gather), decoded fully in phase 1, with a zero-width
    phase-2 contribution.

State contract (``state_extra``)
    The bucket-resident EF/state row for an ``n``-element bucket is
    ``concat(resid, aux)`` of length ``n + state_extra(cfg, n)``.  The
    ``aux`` tail is opaque codec memory carried step-to-step (``powersgd``:
    the warm-started Q factor); quantizers have none, keeping the PR-5
    layout — and all existing graphs — byte-identical.

The quantizer family (``core.compressors.METHODS``) is registered at import;
``powersgd`` registers lazily from ``core.lowrank`` on first registry miss.
"""
from __future__ import annotations

from collections.abc import Sequence

import jax
import jax.numpy as jnp

from .compressors import METHODS, CompressorConfig, plan_from_stats, wire_bytes
from .quantizers import QuantMeta, packed_size


# ---------------------------------------------------------------------------
# Local encode/decode dispatch (kernel vs jnp-oracle), shared by all sites
# ---------------------------------------------------------------------------

# Methods whose codebook is the uniform linspace: the fused kernels encode/
# dequantize them straight from α (code · 2α/s − α) instead of a table walk.
_UNIFORM_DECODE = ("qsgd", "tqsgd", "dsgd")


def _encode_dispatch(cfg: CompressorConfig, op: str, flat: jax.Array, meta: QuantMeta,
                     key: jax.Array, use_pallas: bool):
    """Kernel/jnp dispatch for the fused encode ops (mirror of
    ``_decode_dispatch``): ``use_pallas`` selects ``kernels.encode_fused``
    via the ``kernels.ops`` wrappers, else the key-compatible sequential
    oracles in ``kernels.ref`` (shard_map-safe, bit-identical words)."""
    if use_pallas:
        from repro.kernels import ops as mod
    else:
        from repro.kernels import ref as mod
    if cfg.method in _UNIFORM_DECODE:
        return getattr(mod, f"uniform_{op}")(flat, meta.alpha, cfg.bits, key)
    return getattr(mod, f"codebook_{op}")(flat, meta.levels, cfg.bits, key)


def encode_pack(cfg: CompressorConfig, flat: jax.Array, meta: QuantMeta, key: jax.Array,
                use_pallas: bool) -> jax.Array:
    """Flat fp32 -> packed uint32 wire words in one fused pass (no codes,
    no residual reach HBM)."""
    return _encode_dispatch(cfg, "encode_pack", flat, meta, key, use_pallas)


def encode_pack_residual(cfg: CompressorConfig, flat: jax.Array, meta: QuantMeta,
                         key: jax.Array, use_pallas: bool) -> tuple[jax.Array, jax.Array]:
    """Flat fp32 -> (uint32 wire words, ``flat − dequant(code)`` residual).

    The fused EF encode: the residual is written in the same pass as the
    pack, so the unpacked codes and the dequantized ``own`` tensor never
    leave VMEM on the kernel path.  Exact for codebook methods
    (``levels[code]`` is the interval endpoint the rounding chose); the
    uniform dequant keeps ulp-level FMA slack.
    """
    return _encode_dispatch(cfg, "encode_pack_residual", flat, meta, key, use_pallas)


def decode_reduce(cfg: CompressorConfig, words: jax.Array, levels: jax.Array, n: int,
                  use_pallas: bool) -> jax.Array:
    """Fused unpack → dequant → peer mean of gathered codec rows.

    ``words``: (peers, packed_words) uint32 wire rows; ``levels``: (peers,
    s+1) codebooks; returns the (n,) fp32 mean over peers, never
    materializing the (peers, n) unpacked tensor.  ``use_pallas`` selects the
    ``kernels.decode`` Pallas kernels (interpret-mode off-TPU); the fallback
    is the sequential-peer jnp oracle from ``kernels.ref``, which runs the
    same op sequence (bit-exact for codebook methods, ulp-level FMA slack
    for the uniform dequant — see ``tests/test_decode_kernels.py``) and is
    safe under shard_map tracing on the pinned toolchain.  Every peer of a
    collective runs one compiled program over identical gathered bytes, so
    peers agree bit-for-bit on the result regardless of path (the
    peer-agreement contract).
    """
    return _decode_dispatch(cfg, "decode_reduce", words, levels, n, use_pallas)


def decode_rows(cfg: CompressorConfig, words: jax.Array, levels: jax.Array, n: int,
                use_pallas: bool) -> jax.Array:
    """Fused unpack → dequant of gathered rows, one (n,) row per peer.

    The all-gather phase-2 shape: peer j's decode is output chunk j, so the
    (peers, n) result *is* the payload (no reduction) — the fusion removes
    the (peers, n) int32 code intermediate.  Same dispatch contract as
    :func:`decode_reduce`.
    """
    return _decode_dispatch(cfg, "decode_rows", words, levels, n, use_pallas)


def _decode_dispatch(cfg: CompressorConfig, op: str, words: jax.Array, levels: jax.Array,
                     n: int, use_pallas: bool) -> jax.Array:
    """Select kernel vs fallback module and uniform vs codebook variant.

    Uniform-codebook methods dequantize from α alone (``levels[:, -1]``);
    everything else walks the shipped codebook.
    """
    if use_pallas:
        from repro.kernels import ops as mod
    else:
        from repro.kernels import ref as mod
    if cfg.method in _UNIFORM_DECODE:
        return getattr(mod, f"uniform_{op}")(words, levels[:, -1], n, cfg.bits)
    return getattr(mod, f"codebook_{op}")(words, levels, n, cfg.bits)


def _bucket_stats(flat: jax.Array, use_pallas: bool):
    """One-pass (counts, log_sums, g_max, …) statistics dispatch for the
    secondary plan sites (phase-2 chunks, pod means) that have no
    precomputed stats from the train step's fused EF-correct pass."""
    from repro.adaptive.telemetry import bucket_statistics

    return bucket_statistics(flat, use_pallas=use_pallas)


def _plan_bucket(cfg: CompressorConfig, flat: jax.Array, stat, use_pallas: bool) -> QuantMeta:
    """Histogram-driven plan from precomputed or inline one-pass stats."""
    if stat is None:
        stat = _bucket_stats(flat, use_pallas)
    return plan_from_stats(cfg, stat[0], stat[1], stat[2])


def _levels_to_wire(levels: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(levels.astype(jnp.float32), jnp.uint32)


def _levels_from_wire(words: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(words, jnp.float32)


# ---------------------------------------------------------------------------
# The codec interface
# ---------------------------------------------------------------------------

#: Collective ops one *bucketed* sync of a compressed codec may issue, by
#: mode — the whole value proposition of the fused wire tensor (the count is
#: bounded by the mode, never by the bucket or leaf count).  Declared here,
#: on the registry, so ``repro.analysis.jaxpr_lint`` (REPRO101) and the
#: benchmarks check every registered codec against the same numbers.
COLLECTIVE_BUDGETS = {
    "faithful": 1,       # one fused all-gather of every peer's wire
    "two_phase": 2,      # all-to-all reduce-scatter + all-gather of chunk wires
    "hierarchical": 3,   # intra-pod two-phase + the cross-pod faithful exchange
}


class Codec:
    """One registered compressor method (see the module docstring contracts).

    All geometry methods (``wire_words``, ``chunk_*``, ``state_extra``)
    return trace-time-static Python ints — fused-tensor offsets and EF-state
    shapes are resolved while tracing, never at run time.
    """

    name: str = ""
    #: supports the two-phase peer-chunk split (reduce-scatter layout)
    chunkable: bool = True
    #: the method's fidelity knob is ``cfg.rank`` (else ``cfg.bits``)
    rank_based: bool = False
    #: fixed wire bits/element for plan-less passthrough codecs (``fp16``:
    #: 16); ``None`` means the width is ``cfg.bits`` (the quantizers)
    fixed_wire_bits: int | None = None

    # -- planning ----------------------------------------------------------
    def plan(self, cfg: CompressorConfig, flat: jax.Array, stat, use_pallas: bool):
        """Data-dependent per-bucket plan (codebook fit); opaque to callers."""
        return None

    # -- trace-time contracts ----------------------------------------------
    def collective_budget(self, mode: str, n_buckets: int = 1) -> int:
        """Max collective eqns one bucketed sync of this codec traces under
        ``mode``.  Uncompressed paths (``dsgd``) fall back to one ``pmean``
        per bucket; every compressed codec shares the fused-wire budgets in
        :data:`COLLECTIVE_BUDGETS`.
        """
        if mode == "dsgd" or self.name == "dsgd":
            return int(n_buckets)
        try:
            return COLLECTIVE_BUDGETS[mode]
        except KeyError:
            raise ValueError(
                f"unknown sync mode {mode!r}; expected one of "
                f"{tuple(COLLECTIVE_BUDGETS) + ('dsgd',)}") from None

    # -- static geometry ---------------------------------------------------
    def wire_words(self, cfg: CompressorConfig, n: int) -> int:
        """uint32 words of one peer's full-bucket transmission."""
        raise NotImplementedError

    def wire_bytes(self, cfg: CompressorConfig, n: int) -> int:
        """Accounted wire bytes (may exceed 4·wire_words by out-of-band
        metadata, e.g. the quantizers' α word)."""
        return 4 * self.wire_words(cfg, n)

    def state_extra(self, cfg: CompressorConfig, n: int) -> int:
        """Opaque aux words appended to the bucket's EF residual row."""
        return 0

    # -- encode ------------------------------------------------------------
    def encode(self, cfg: CompressorConfig, flat: jax.Array, pln, key: jax.Array,
               use_pallas: bool) -> jax.Array:
        """Flat (n,) fp32 -> (wire_words,) uint32 wire."""
        raise NotImplementedError

    def encode_residual(self, cfg: CompressorConfig, flat: jax.Array, pln,
                        key: jax.Array, use_pallas: bool, aux=None):
        """-> (wire, EF residual ``flat − own``, new aux or None)."""
        raise NotImplementedError

    # -- decode ------------------------------------------------------------
    def decode_reduce(self, cfg: CompressorConfig, rows: jax.Array, n: int,
                      use_pallas: bool) -> jax.Array:
        """(peers, wire_words) gathered rows -> (n,) fp32 peer mean."""
        raise NotImplementedError

    def decode_rows(self, cfg: CompressorConfig, rows: jax.Array, n: int,
                    use_pallas: bool) -> jax.Array:
        """(peers, wire_words) rows -> (peers, n) fp32, one row per peer."""
        raise NotImplementedError

    # -- two-phase chunking (chunkable codecs only) ------------------------
    def chunk_elems(self, cfg: CompressorConfig, n: int, n_chunks: int) -> int:
        raise NotImplementedError

    def chunk_wire_words(self, cfg: CompressorConfig, n: int, n_chunks: int) -> int:
        raise NotImplementedError

    def encode_chunks(self, cfg: CompressorConfig, flat: jax.Array, pln,
                      key: jax.Array, n_chunks: int, use_pallas: bool):
        """-> ((n_chunks, chunk_wire_words) rows, (n,) EF residual)."""
        raise NotImplementedError


class QuantizerCodec(Codec):
    """The paper's scalar quantizers as the first registered family.

    Wire layout per bucket (unchanged from the pre-registry codec, pinned
    bit-exact by ``tests/test_mesh_invariance.py``):
    ``[packed_size(n, bits) code words][s+1 bitcast codebook words]``.
    """

    chunkable = True

    def __init__(self, name: str):
        self.name = name

    def plan(self, cfg, flat, stat, use_pallas):
        return _plan_bucket(cfg, flat, stat, use_pallas)

    def wire_words(self, cfg, n):
        return packed_size(n, cfg.bits) + cfg.s + 1

    def wire_bytes(self, cfg, n):
        return wire_bytes(cfg, n)

    def encode(self, cfg, flat, pln, key, use_pallas):
        words = encode_pack(cfg, flat, pln, key, use_pallas)
        return jnp.concatenate([words, _levels_to_wire(pln.levels)])

    def encode_residual(self, cfg, flat, pln, key, use_pallas, aux=None):
        words, resid = encode_pack_residual(cfg, flat, pln, key, use_pallas)
        return jnp.concatenate([words, _levels_to_wire(pln.levels)]), resid, None

    def _split(self, cfg, rows, n):
        w = packed_size(n, cfg.bits)
        return rows[:, :w], _levels_from_wire(rows[:, w:w + cfg.s + 1])

    def decode_reduce(self, cfg, rows, n, use_pallas):
        words, levels = self._split(cfg, rows, n)
        return decode_reduce(cfg, words, levels, n, use_pallas)

    def decode_rows(self, cfg, rows, n, use_pallas):
        words, levels = self._split(cfg, rows, n)
        return decode_rows(cfg, words, levels, n, use_pallas)

    def chunk_elems(self, cfg, n, n_chunks):
        # chunks pad to 32 elements so packed chunk words slice cleanly
        return (n + (-n) % (n_chunks * 32)) // n_chunks

    def chunk_wire_words(self, cfg, n, n_chunks):
        return packed_size(self.chunk_elems(cfg, n, n_chunks), cfg.bits) + cfg.s + 1

    def encode_chunks(self, cfg, flat, pln, key, n_chunks, use_pallas):
        padded = jnp.pad(flat, (0, (-flat.size) % (n_chunks * 32)))
        words, resid = encode_pack_residual(cfg, padded, pln, key, use_pallas)
        wc = packed_size(padded.size // n_chunks, cfg.bits)
        lv = jnp.tile(_levels_to_wire(pln.levels)[None], (n_chunks, 1))
        return jnp.concatenate([words.reshape(n_chunks, wc), lv], axis=1), resid[: flat.size]


class Fp16Codec(Codec):
    """Raw half-precision passthrough: the size-adaptive small-bucket tier.

    Small buckets (below ``TrainStepConfig.fp16_threshold`` elements) skip
    quantization entirely and ship bitcast fp16 — the Hivemind
    ``SizeAdaptiveCompression`` pattern: for tiny tensors the codebook
    overhead (s+1 words) rivals the payload and full half-precision is both
    cheaper to compute and lower-error.  The wire is two fp16 values packed
    per uint32 word (low half = even element), so it rides the same fused
    uint32 tensor as every other codec.  ``plan`` is ``None`` (nothing to
    fit), the encode draws no RNG (rounding is deterministic
    nearest-even), and the EF residual is the roundoff ``flat − f32(f16(
    flat))``.  ``cfg.bits`` is ignored (see ``fixed_wire_bits``).
    """

    name = "fp16"
    chunkable = True
    rank_based = False
    fixed_wire_bits = 16

    def plan(self, cfg, flat, stat, use_pallas):
        return None

    def wire_words(self, cfg, n):
        return (n + 1) // 2

    def wire_bytes(self, cfg, n):
        return 2 * n

    @staticmethod
    def _pack(flat: jax.Array) -> jax.Array:
        h = flat.astype(jnp.float16)
        if h.size % 2:
            h = jnp.pad(h, (0, 1))
        u = jax.lax.bitcast_convert_type(h, jnp.uint16).astype(jnp.uint32)
        return u[0::2] | (u[1::2] << 16)

    @staticmethod
    def _unpack(rows: jax.Array, n: int) -> jax.Array:
        lo = (rows & jnp.uint32(0xFFFF)).astype(jnp.uint16)
        hi = (rows >> 16).astype(jnp.uint16)
        u = jnp.stack([lo, hi], axis=-1).reshape(rows.shape[:-1] + (-1,))
        h = jax.lax.bitcast_convert_type(u, jnp.float16)
        return h[..., :n].astype(jnp.float32)

    def encode(self, cfg, flat, pln, key, use_pallas):
        return self._pack(flat)

    def encode_residual(self, cfg, flat, pln, key, use_pallas, aux=None):
        wire = self._pack(flat)
        resid = flat - flat.astype(jnp.float16).astype(jnp.float32)
        return wire, resid, None

    def decode_reduce(self, cfg, rows, n, use_pallas):
        return jnp.mean(self._unpack(rows, n), axis=0)

    def decode_rows(self, cfg, rows, n, use_pallas):
        return self._unpack(rows, n)

    def chunk_elems(self, cfg, n, n_chunks):
        # chunks pad to 2 elements so packed chunk words slice cleanly
        return (n + (-n) % (n_chunks * 2)) // n_chunks

    def chunk_wire_words(self, cfg, n, n_chunks):
        return self.chunk_elems(cfg, n, n_chunks) // 2

    def encode_chunks(self, cfg, flat, pln, key, n_chunks, use_pallas):
        padded = jnp.pad(flat, (0, (-flat.size) % (n_chunks * 2)))
        words = self._pack(padded)
        resid = flat - flat.astype(jnp.float16).astype(jnp.float32)
        return words.reshape(n_chunks, -1), resid


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Codec] = {}


def register_codec(codec: Codec, *, override: bool = False) -> Codec:
    """Register ``codec`` under ``codec.name``.

    A second registration of the same name raises — two plugins silently
    shadowing each other is exactly the dispatch ambiguity the registry
    exists to rule out.  Pass ``override=True`` to replace a registered
    codec deliberately (tests, method shims).
    """
    if not codec.name:
        raise ValueError("codec must set a non-empty name")
    if not override and codec.name in _REGISTRY:
        raise ValueError(
            f"codec {codec.name!r} is already registered "
            f"({type(_REGISTRY[codec.name]).__name__}); pass override=True "
            "to replace it deliberately")
    _REGISTRY[codec.name] = codec
    return codec


def _ensure_builtin() -> None:
    # The low-rank family registers on import; deferred so that core.codecs
    # stays importable before core.lowrank (and kernels) exist in a trace.
    if "powersgd" not in _REGISTRY:
        from . import lowrank  # noqa: F401  (registers powersgd)


def get_codec(method: str) -> Codec:
    """The registered :class:`Codec` for ``method``."""
    _ensure_builtin()
    try:
        return _REGISTRY[method]
    except KeyError:
        raise KeyError(
            f"no codec registered for method {method!r}; known: {known_methods()}"
        ) from None


def known_methods() -> tuple[str, ...]:
    """All registered method names (sorted)."""
    _ensure_builtin()
    return tuple(sorted(_REGISTRY))


for _m in METHODS:
    register_codec(QuantizerCodec(_m))
del _m
register_codec(Fp16Codec())


# ---------------------------------------------------------------------------
# Per-bucket config plans (the ``bits_plan`` entries, now method-aware)
# ---------------------------------------------------------------------------


def bucket_cfg_entry(cfg: CompressorConfig, entry) -> CompressorConfig:
    """Resolve one per-bucket plan entry to a :class:`CompressorConfig`.

    ``entry`` is an int (bit width under ``cfg.method``), a
    ``("method", value)`` pair (value = rank for rank-based codecs, bits
    otherwise), or a full :class:`CompressorConfig`.  Malformed entries
    raise ``ValueError`` naming the entry and the accepted forms.
    """
    import dataclasses

    if isinstance(entry, CompressorConfig):
        return entry
    if isinstance(entry, tuple | list):
        if len(entry) != 2 or not isinstance(entry[0], str):
            raise ValueError(
                f"malformed bits_plan entry {entry!r}: a sequence entry must "
                "be a ('method', value) pair — e.g. ('tqsgd', 3) or "
                "('powersgd', 2)")
        method, value = entry
        try:
            value = int(value)
        except (TypeError, ValueError):
            raise ValueError(
                f"malformed bits_plan entry {entry!r}: value must be an int "
                f"(rank for rank-based codecs, bits otherwise), got "
                f"{type(entry[1]).__name__}") from None
        if get_codec(method).rank_based:
            if method == cfg.method and value == cfg.rank:
                return cfg
            return dataclasses.replace(cfg, method=method, rank=value)
        if method == cfg.method and value == cfg.bits:
            return cfg
        return dataclasses.replace(cfg, method=method, bits=value)
    try:
        entry = int(entry)
    except (TypeError, ValueError):
        raise ValueError(
            f"malformed bits_plan entry {entry!r}: expected an int bit "
            "width, a ('method', value) pair, or a CompressorConfig") from None
    return cfg if entry == cfg.bits else dataclasses.replace(cfg, bits=entry)


def bucket_cfgs(
    cfg: CompressorConfig, n_buckets: int, plan: Sequence | None
) -> list[CompressorConfig]:
    """Per-bucket compressor configs for a (possibly heterogeneous) plan.

    ``plan=None`` keeps ``cfg`` everywhere; otherwise one config per bucket
    from :func:`bucket_cfg_entry`.  The plan is trace-time Python, so bucket
    offsets in the fused wire tensor stay static.
    """
    if plan is None:
        return [cfg] * n_buckets
    if len(plan) != n_buckets:
        raise ValueError(f"bit plan has {len(plan)} entries for {n_buckets} buckets")
    return [bucket_cfg_entry(cfg, e) for e in plan]


def size_adaptive_plan(
    cfg: CompressorConfig, plan: Sequence | None, sizes: Sequence[int],
    threshold: int,
) -> Sequence | None:
    """Apply the fp16 small-bucket tier to a per-bucket plan.

    Buckets of at most ``threshold`` elements are overridden to the
    ``fp16`` passthrough codec (the Hivemind ``SizeAdaptiveCompression``
    pattern — see :class:`Fp16Codec`); larger buckets keep their ``plan``
    entry (or ``cfg`` itself when ``plan`` is None).  ``threshold <= 0``
    disables the tier and returns ``plan`` unchanged, so tier-off graphs
    stay byte-identical.  Trace-time Python: the tier decision is static
    per compiled step, like every other plan entry.
    """
    if threshold <= 0 or not any(int(m) <= threshold for m in sizes):
        return plan
    base = list(plan) if plan is not None else [cfg] * len(sizes)
    if len(base) != len(sizes):
        raise ValueError(f"bit plan has {len(base)} entries for {len(sizes)} buckets")
    return tuple(("fp16", cfg.bits) if int(m) <= threshold else e
                 for e, m in zip(base, sizes))


def bucket_state_sizes(
    cfg: CompressorConfig, sizes: Sequence[int], plan: Sequence | None = None
) -> list[int]:
    """EF/state row length per bucket: ``m + state_extra`` under the plan."""
    cfgs = bucket_cfgs(cfg, len(sizes), plan)
    return [int(m) + get_codec(c.method).state_extra(c, int(m))
            for m, c in zip(sizes, cfgs)]
