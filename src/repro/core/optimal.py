"""Optimal quantizer-parameter design (paper §IV + Appendix D).

Solves for the truncation threshold α and the quantization density λ_s for
the three truncated schemes:

- TQSGD  (uniform):     α fixed-point of Eq. 12 with Q_U(α);
- TNQSGD (non-uniform): λ ∝ p^(1/3) (Eq. 18), α fixed-point of Eq. 19 with Q_N;
- TBQSGD (bi-scaled):   piecewise-uniform λ (Eq. 25/34), split s_α/s_β
  (Eq. 29/30), k* by grid search, α fixed-point of Eq. 33 with Q_B.

All solvers run a fixed number of fixed-point iterations (jit-friendly) and
clamp α into [g_min, g_max]: thresholds above the observed max are pointless,
below g_min the power-law model does not apply.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .distributions import (
    EmpiricalDensity,
    PowerLawTail,
    cum_p,
    cum_p_third,
    interp_cum,
    q_u,
)
from .quantizers import levels_from_density, num_levels, uniform_levels

_EPS = 1e-12


def _alpha_fixed_point(tail: PowerLawTail, s: int, q_fn, iters: int) -> jax.Array:
    """Generic alternating iteration  α ← g_min · [2ρs²/((γ-2)·Q(α))]^{1/(γ-1)}.

    ``q_fn(alpha) -> Q(alpha)`` is Q_U / Q_N / Q_B.  Starts from Q = 1 (the
    paper's approximation α'); a handful of iterations suffices because Q is
    monotone in α and bounded in (0, 1].
    """
    gamma, g_min, rho = tail.gamma, tail.g_min, tail.rho
    expo = 1.0 / (gamma - 1.0)

    def base(q):
        return g_min * jnp.power(2.0 * rho * s * s / ((gamma - 2.0) * jnp.maximum(q, _EPS)), expo)

    def body(_, alpha):
        alpha = jnp.clip(alpha, tail.g_min, tail.g_max)
        return base(q_fn(alpha))

    alpha0 = base(jnp.asarray(1.0, jnp.float32))
    alpha = jax.lax.fori_loop(0, iters, body, alpha0)
    return jnp.clip(alpha, tail.g_min, tail.g_max)


# ---------------------------------------------------------------------------
# TQSGD: truncated uniform
# ---------------------------------------------------------------------------


def solve_alpha_uniform(tail: PowerLawTail, bits: int, *, iters: int = 10) -> jax.Array:
    """Optimal α for the truncated *uniform* quantizer (Eq. 12)."""
    s = num_levels(bits)
    return _alpha_fixed_point(tail, s, lambda a: q_u(tail, a), iters)


def uniform_codebook(alpha: jax.Array, bits: int) -> jax.Array:
    return uniform_levels(alpha, bits).astype(jnp.float32)


# ---------------------------------------------------------------------------
# TNQSGD: truncated non-uniform, λ ∝ p^(1/3)
# ---------------------------------------------------------------------------


def q_n(dens: EmpiricalDensity, alpha: jax.Array) -> jax.Array:
    """Q_N(α) = [ ∫_{-α}^{α} p^(1/3) (1/2α)^(2/3) dg ]^3  (Eq. 19), from the
    empirical density (the power law only covers the tail; the integral runs
    over the whole truncation range)."""
    c13 = cum_p_third(dens)
    one_sided = interp_cum(c13, dens, alpha)          # ∫_0^α p^(1/3)
    full = 2.0 * one_sided                            # symmetric
    return jnp.power(full, 3.0) / jnp.maximum((2.0 * alpha) ** 2, _EPS)


def solve_alpha_nonuniform(
    tail: PowerLawTail, dens: EmpiricalDensity, bits: int, *, iters: int = 10
) -> jax.Array:
    """Optimal α for the non-uniform quantizer (Eq. 19 fixed point)."""
    s = num_levels(bits)
    return _alpha_fixed_point(tail, s, lambda a: jnp.clip(q_n(dens, a), _EPS, 1.0), iters)


def nonuniform_codebook(dens: EmpiricalDensity, alpha: jax.Array, bits: int) -> jax.Array:
    """Codebook with λ ∝ p^(1/3) on [-α, α] (Eq. 18), from the empirical density.

    A fresh |g| grid over [0, α] is built (jit-friendly: same bin count, α may
    be traced) and the density is *interpolated* onto it, so only the portion
    of the histogram inside the truncation range shapes the codebook.
    """
    k = dens.num_bins
    edges = jnp.linspace(0.0, 1.0, k + 1) * alpha
    centers = 0.5 * (edges[:-1] + edges[1:])
    centers_src = 0.5 * (dens.edges[:-1] + dens.edges[1:])
    p = jnp.interp(centers, centers_src, dens.density)
    lam = jnp.power(jnp.maximum(p, 0.0), 1.0 / 3.0)
    # Give empty bins a tiny floor so levels stay strictly increasing.
    lam = jnp.maximum(lam, 1e-6 * jnp.max(lam))
    return levels_from_density(edges, lam, bits)


# ---------------------------------------------------------------------------
# TBQSGD: truncated bi-scaled (Appendix D)
# ---------------------------------------------------------------------------


def q_b(dens: EmpiricalDensity, alpha: jax.Array, k: jax.Array) -> jax.Array:
    """Q_B(α, k) of Appendix D:
    [ (2∫_{kα}^{α} p)^{1/3} (1-k)^{2/3} + (2∫_0^{kα} p)^{1/3} k^{2/3} ]^3.
    """
    cp = cum_p(dens)
    inner = 2.0 * interp_cum(cp, dens, k * alpha)
    outer = 2.0 * (interp_cum(cp, dens, alpha) - interp_cum(cp, dens, k * alpha))
    inner = jnp.maximum(inner, 0.0)
    outer = jnp.maximum(outer, 0.0)
    term = jnp.power(outer, 1 / 3) * jnp.power(1.0 - k, 2 / 3) + jnp.power(inner, 1 / 3) * jnp.power(k, 2 / 3)
    return jnp.power(term, 3.0)


def solve_biscaled(
    tail: PowerLawTail,
    dens: EmpiricalDensity,
    bits: int,
    *,
    iters: int = 10,
    k_grid: int = 49,
) -> tuple[jax.Array, jax.Array]:
    """One-step alternating minimisation of Appendix D: k* = argmin_k Q_B(α, k)
    on a grid, then α from Eq. 33 (iterated).  Returns (alpha, k_star)."""
    ks = jnp.linspace(0.02, 0.98, k_grid)
    s = num_levels(bits)

    def q_best(alpha):
        qs = jax.vmap(lambda k: q_b(dens, alpha, k))(ks)
        return jnp.clip(jnp.min(qs), _EPS, 1.0)

    alpha = _alpha_fixed_point(tail, s, q_best, iters)
    qs = jax.vmap(lambda k: q_b(dens, alpha, k))(ks)
    k_star = ks[jnp.argmin(qs)]
    return alpha, k_star


def biscaled_codebook(
    dens: EmpiricalDensity, alpha: jax.Array, k: jax.Array, bits: int
) -> jax.Array:
    """Piecewise-uniform codebook per Eq. 34: density λ takes one value on
    |g| < kα and another on kα <= |g| <= α, with the split given by the
    cube-root rule (Eq. 29/30)."""
    cp = cum_p(dens)
    beta = k * alpha
    mass_in = 2.0 * interp_cum(cp, dens, beta)
    mass_out = 2.0 * (interp_cum(cp, dens, alpha) - interp_cum(cp, dens, beta))
    p1 = jnp.maximum(mass_in, _EPS) / jnp.maximum(2.0 * beta, _EPS)      # avg density inside
    p2 = jnp.maximum(mass_out, _EPS) / jnp.maximum(2.0 * (alpha - beta), _EPS)
    lam_in = jnp.power(p1, 1 / 3)
    lam_out = jnp.power(p2, 1 / 3)
    nbins = dens.num_bins
    edges = jnp.linspace(0.0, 1.0, nbins + 1) * alpha
    centers = 0.5 * (edges[:-1] + edges[1:])
    lam = jnp.where(centers < beta, lam_in, lam_out)
    lam = jnp.maximum(lam, 1e-6 * jnp.maximum(lam_in, lam_out))
    return levels_from_density(edges, lam, bits)
