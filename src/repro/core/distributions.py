"""Power-law tail modelling of gradient distributions (paper §IV, Eq. 9-10).

The paper models the *tail* of the per-element gradient distribution as a
symmetric power law

    p(g) = rho * (gamma - 1) * g_min^(gamma-1) * |g|^(-gamma),   |g| > g_min

with one-sided tail mass ``rho = P(g > g_min)`` and tail index
``3 < gamma <= 5``.  ``gamma`` is estimated with the Hill / MLE estimator
(paper §V):  gamma = 1 + n / sum_j ln(g_j / g_min)  over |g_j| > g_min.

Everything here is jit-able and operates on flattened gradient tensors.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Paper restricts gamma to (3, 5]: the bias integral needs gamma > 3 and
# empirical fits above 5 are indistinguishable from thin tails.
GAMMA_MIN = 3.05
GAMMA_MAX = 5.0
_EPS = 1e-12


class PowerLawTail(NamedTuple):
    """Fitted symmetric power-law tail.  All fields are scalar arrays."""

    gamma: jax.Array   # tail index, clipped to (GAMMA_MIN, GAMMA_MAX]
    g_min: jax.Array   # lower bound of power-law behaviour
    rho: jax.Array     # one-sided tail mass P(g > g_min)
    g_max: jax.Array   # max |g| observed (used to clamp alpha)


def approx_abs_quantile(gabs: jax.Array, q: float, *, num_bins: int = 512) -> jax.Array:
    """Histogram-based approximate ``q``-quantile of a non-negative array.

    One O(n) counting pass over ``num_bins`` log-spaced bins (8 decades below
    the max) with interpolation inside the crossing bin, instead of the
    O(n log n) full sort behind ``jnp.quantile`` — built for the per-step
    plan/telemetry hot loop where ~1% relative quantile error is irrelevant
    to the tail fit.  Heavy-tailed |g| piles up orders of magnitude below
    the max, so the bins must be log-spaced: linear bins would put the 0.9
    quantile deep inside the first bin.
    """
    g_max = jnp.maximum(jnp.max(gabs), _EPS)
    lo = g_max * 1e-8
    log_lo, log_hi = jnp.log(lo), jnp.log(g_max)
    x = jnp.clip(jnp.log(jnp.maximum(gabs, lo)), log_lo, log_hi)
    edges = jnp.linspace(log_lo, log_hi, num_bins + 1)
    counts, _ = jnp.histogram(x, bins=edges)
    cum = jnp.concatenate([jnp.zeros((1,), jnp.float32),
                           jnp.cumsum(counts).astype(jnp.float32)])
    return jnp.exp(jnp.interp(q * gabs.size, cum, edges))


def fit_power_law_tail(
    g: jax.Array,
    *,
    gmin_quantile: float = 0.9,
    gamma_clip: tuple[float, float] = (GAMMA_MIN, GAMMA_MAX),
    approx_quantile: bool = False,
    quantile_bins: int = 512,
) -> PowerLawTail:
    """Fit the symmetric power-law tail of ``g``'s element distribution.

    ``g_min`` is taken as the ``gmin_quantile`` quantile of |g| (the paper
    fixes the power-law region to the tail); gamma via the Hill estimator.
    ``approx_quantile=True`` swaps the exact (full-sort) quantile for the
    O(n) histogram approximation — the hot-loop setting; exact stays the
    default for offline fits (agreement pinned in ``tests/test_powerlaw.py``).
    """
    gabs = jnp.abs(g.reshape(-1)).astype(jnp.float32)
    g_max = jnp.max(gabs)
    g_min = approx_abs_quantile(gabs, gmin_quantile, num_bins=quantile_bins) \
        if approx_quantile else jnp.quantile(gabs, gmin_quantile)
    # Guard degenerate tensors (all zeros / constant): fall back to a tiny
    # positive g_min so downstream math stays finite.
    g_min = jnp.maximum(g_min, _EPS)

    in_tail = gabs > g_min
    n_tail = jnp.sum(in_tail)
    log_ratio = jnp.where(in_tail, jnp.log(jnp.maximum(gabs, _EPS) / g_min), 0.0)
    sum_log = jnp.sum(log_ratio)
    gamma_raw = 1.0 + n_tail / jnp.maximum(sum_log, _EPS)
    gamma = jnp.clip(gamma_raw, gamma_clip[0], gamma_clip[1])

    # One-sided tail mass: by symmetry, half of P(|g| > g_min).
    rho = 0.5 * n_tail / jnp.maximum(gabs.size, 1)
    rho = jnp.maximum(rho, _EPS)
    return PowerLawTail(gamma=gamma, g_min=g_min, rho=rho, g_max=jnp.maximum(g_max, _EPS))


def tail_from_histogram(
    counts: jax.Array,
    log_sums: jax.Array,
    g_max: jax.Array,
    edges: jax.Array,
    *,
    gmin_quantile: float = 0.9,
) -> PowerLawTail:
    """Power-law tail fit from a |g| histogram + per-bin ln|g| sums.

    The one-pass-statistics twin of :func:`fit_power_law_tail`: ``g_min``
    snaps to the upper edge of the bin where the |g| CDF crosses
    ``gmin_quantile``, and the Hill estimator runs over the *whole* bins
    above it — the suffix count / suffix ln-sum of the accumulators, so the
    tail sum is exact with respect to the histogram (``Σ ln g_j − n_tail ln
    g_min``) at the cost of ≤ one bin of quantile resolution.  ``counts``
    and ``log_sums`` are (K,) on the (K+1,) ``edges``; scaling both by a
    common factor (an EMA decay) cancels in every ratio, so EMA
    accumulators need no debiasing.  This is what the fused encode kernels
    feed ``compressors.plan_from_stats`` and what ``adaptive.telemetry``
    estimates replan tails with.
    """
    k = counts.shape[0]
    total = jnp.sum(counts)
    cum = jnp.cumsum(counts)
    idx = jnp.clip(jnp.searchsorted(cum, gmin_quantile * total), 0, k - 1)
    g_min = jnp.maximum(jnp.minimum(edges[idx + 1], g_max), _EPS)
    n_tail = total - cum[idx]
    cum_log = jnp.cumsum(log_sums)
    sum_log = (cum_log[k - 1] - cum_log[idx]) - n_tail * jnp.log(g_min)
    gamma = jnp.clip(1.0 + n_tail / jnp.maximum(sum_log, _EPS), GAMMA_MIN, GAMMA_MAX)
    rho = jnp.maximum(0.5 * n_tail / jnp.maximum(total, 1.0), _EPS)
    return PowerLawTail(gamma=gamma, g_min=g_min, rho=rho,
                        g_max=jnp.maximum(g_max, _EPS))


def tail_mass(tail: PowerLawTail, alpha: jax.Array) -> jax.Array:
    """One-sided mass beyond ``alpha``:  int_alpha^inf p(g) dg = rho (g_min/alpha)^(gamma-1)."""
    return tail.rho * jnp.power(tail.g_min / jnp.maximum(alpha, _EPS), tail.gamma - 1.0)


def q_u(tail: PowerLawTail, alpha: jax.Array) -> jax.Array:
    """Q_U(alpha) = int_{-alpha}^{alpha} p(g) dg = 1 - 2 * tail_mass(alpha)."""
    return jnp.clip(1.0 - 2.0 * tail_mass(tail, alpha), _EPS, 1.0)


def truncation_bias(tail: PowerLawTail, alpha: jax.Array) -> jax.Array:
    """Per-element truncation bias term  2 * int_alpha^inf (g-alpha)^2 p(g) dg.

    With the power-law tail this is  4 rho g_min^(gamma-1) alpha^(3-gamma)
    / ((gamma-2)(gamma-3))  (the bracketed factor of Eq. 11 without d/N).
    """
    gm, ga = tail.g_min, tail.gamma
    coeff = 4.0 * tail.rho * jnp.power(gm, ga - 1.0) / ((ga - 2.0) * (ga - 3.0))
    return coeff * jnp.power(jnp.maximum(alpha, _EPS), 3.0 - ga)


def sample_power_law(
    key: jax.Array,
    shape: tuple[int, ...],
    *,
    gamma: float,
    g_min: float,
    rho: float = 0.5,
    body_scale: float | None = None,
) -> jax.Array:
    """Draw synthetic heavy-tailed 'gradients' with an exact power-law tail.

    With probability 2*rho an element is a signed Pareto(gamma-1) sample above
    g_min; otherwise it is uniform 'body' mass in [-g_min, g_min] (the paper
    ignores the near-zero region; a uniform body keeps tests simple).  Used by
    tests and the quant-error benchmark as a distribution with known
    (gamma, g_min, rho).
    """
    k_sel, k_par, k_body, k_sign = jax.random.split(key, 4)
    u = jax.random.uniform(k_par, shape, minval=1e-6, maxval=1.0)
    pareto = g_min * jnp.power(u, -1.0 / (gamma - 1.0))  # inverse-CDF Pareto
    if body_scale is None:
        body_scale = g_min
    body = jax.random.uniform(k_body, shape, minval=0.0, maxval=body_scale)
    is_tail = jax.random.uniform(k_sel, shape) < 2.0 * rho
    mag = jnp.where(is_tail, pareto, body)
    sign = jnp.where(jax.random.bernoulli(k_sign, 0.5, shape), 1.0, -1.0)
    return (sign * mag).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class EmpiricalDensity:
    """Piecewise-constant symmetric density estimate of gradient elements.

    Histogram of |g| on ``[0, g_max]`` with K bins, converted to the two-sided
    density p_g(x) = counts / (2 * n * bin_width).  Used to build non-uniform
    codebooks (Eq. 18) and the Q_N / Q_B integrals, which need p(g) over the
    *whole* truncation range, not just the tail.
    """

    edges: jax.Array    # (K+1,) bin edges over |g|, edges[0] = 0
    density: jax.Array  # (K,) two-sided density value on each bin

    @property
    def num_bins(self) -> int:
        return self.density.shape[0]


def density_from_histogram(counts: jax.Array, edges: jax.Array) -> EmpiricalDensity:
    """Piecewise-constant two-sided density from a |g| histogram.

    The same contract :func:`fit_empirical_density` produces, but from
    precomputed (possibly EMA-scaled, possibly non-uniform-bin) counts —
    ``_cum_integral`` handles non-uniform widths, so the ``core.optimal``
    solvers and codebooks run straight off the one-pass statistics.
    """
    widths = jnp.maximum(jnp.diff(edges), _EPS)
    total = jnp.maximum(jnp.sum(counts), 1.0)
    return EmpiricalDensity(edges=edges, density=counts / (2.0 * total * widths))


def fit_empirical_density(g: jax.Array, *, num_bins: int = 128) -> EmpiricalDensity:
    gabs = jnp.abs(g.reshape(-1)).astype(jnp.float32)
    g_max = jnp.maximum(jnp.max(gabs), _EPS)
    edges = jnp.linspace(0.0, g_max, num_bins + 1)
    counts, _ = jnp.histogram(gabs, bins=edges)
    width = edges[1] - edges[0]
    dens = counts.astype(jnp.float32) / (2.0 * jnp.maximum(gabs.size, 1) * jnp.maximum(width, _EPS))
    return EmpiricalDensity(edges=edges, density=dens)


def _cum_integral(dens: EmpiricalDensity, values: jax.Array) -> jax.Array:
    """Cumulative integral helper: returns edges-aligned cumsum of ``values``.

    ``values`` is a per-bin integrand (e.g. p or p^(1/3)); the result C has
    C[0] = 0 and C[k] = int_0^{edges[k]} integrand.
    """
    widths = jnp.diff(dens.edges)
    return jnp.concatenate([jnp.zeros((1,), values.dtype), jnp.cumsum(values * widths)])


def cum_p(dens: EmpiricalDensity) -> jax.Array:
    """C_p aligned to edges: int_0^x p(g) dg (one-sided)."""
    return _cum_integral(dens, dens.density)


def cum_p_third(dens: EmpiricalDensity) -> jax.Array:
    """C_{p^(1/3)} aligned to edges: int_0^x p(g)^(1/3) dg (one-sided)."""
    return _cum_integral(dens, jnp.power(jnp.maximum(dens.density, 0.0), 1.0 / 3.0))


def interp_cum(cum: jax.Array, dens: EmpiricalDensity, x: jax.Array) -> jax.Array:
    """Evaluate an edges-aligned cumulative integral at arbitrary |g| = x."""
    return jnp.interp(x, dens.edges, cum)
