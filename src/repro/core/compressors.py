"""Gradient compressor registry — the paper's algorithms as composable ops.

A compressor turns a gradient tensor into (packed codes, QuantMeta) and back.
Methods:

- ``dsgd``   : identity (no compression, fp32 wire format);
- ``qsgd``   : uniform quantization, α = max|g| (Alistarh et al. baseline);
- ``nqsgd``  : non-uniform (λ ∝ p^(1/3)), no truncation (α = max|g|);
- ``tqsgd``  : truncated uniform, α from Eq. 12;
- ``tnqsgd`` : truncated non-uniform, λ from Eq. 18, α from Eq. 19;
- ``tbqsgd`` : truncated bi-scaled (Appendix D), α/k from Eq. 29-33.

Everything is jit-able and shape-static.  ``plan`` computes the per-tensor
codebook (the expensive statistics pass); ``encode``/``decode`` are the wire
ops.  The Pallas fast path (repro.kernels) is used automatically for encode/
decode of uniform codebooks when enabled.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import distributions as dist
from . import optimal
from .quantizers import (
    QuantMeta,
    decode as _decode,
    num_levels,
    pack_codes,
    stochastic_encode,
    unpack_codes,
    uniform_levels,
)

METHODS = ("dsgd", "qsgd", "nqsgd", "tqsgd", "tnqsgd", "tbqsgd")
TRUNCATED = ("tqsgd", "tnqsgd", "tbqsgd")


@dataclasses.dataclass(frozen=True)
class CompressorConfig:
    method: str = "tnqsgd"
    bits: int = 3
    gmin_quantile: float = 0.9     # |g| quantile used as g_min for the tail fit
    hist_bins: int = 128           # empirical-density resolution
    alpha_iters: int = 10          # fixed-point iterations for α
    use_pallas: bool = False       # fused encode kernel for uniform codebooks
    pack: bool = True              # bit-pack codes into uint32 words on the wire
    plan_sample: int = 65536       # max elements used for the statistics pass
    approx_gmin: bool = False      # histogram quantile for g_min (no full sort)
    rank: int = 4                  # factor rank for rank-based codecs (powersgd)

    def __post_init__(self):
        if self.method not in METHODS:
            # registered codec families beyond the built-in quantizers
            from .codecs import known_methods

            if self.method not in known_methods():
                raise ValueError(
                    f"unknown method {self.method!r}; expected one of {known_methods()}")
        if not (1 <= self.bits <= 8):
            raise ValueError("bits must be in [1, 8]")
        if not (1 <= self.rank <= 64):
            raise ValueError("rank must be in [1, 64]")

    @property
    def s(self) -> int:
        return num_levels(self.bits)


_PLAN_SAMPLE_CHUNKS = 64


def _plan_sample(g32: jax.Array, sample: int) -> jax.Array:
    """Contiguous-chunk statistics subsample of a flat fp32 tensor.

    The old ``g32[::stride]`` strided gather is a scatter/gather HBM access
    pattern on TPU (one element per cache line).  Instead, take
    ``_PLAN_SAMPLE_CHUNKS`` *contiguous* runs at evenly spaced offsets via
    a reshape + leading-slice — each run is a sequential DMA — which keeps
    the sample spread across the tensor (buckets concatenate leaves of
    different scales, so a single leading chunk would be biased).
    """
    n = g32.size
    if not sample or n <= sample:
        return g32
    # always spread the runs across the whole tensor (a single leading chunk
    # would bias toward the first leaves of a bucket), but never more chunks
    # than sampled elements: a tiny plan_sample must still yield >= 1
    # element per run (the strided path it replaces always did)
    chunks = max(min(_PLAN_SAMPLE_CHUNKS, sample), 1)
    span = n // chunks
    run = max(min(sample // chunks, span), 1)
    return g32[: chunks * span].reshape(chunks, span)[:, :run].reshape(-1)


def plan(cfg: CompressorConfig, g: jax.Array) -> QuantMeta:
    """Build the per-tensor quantization plan (codebook + α) for ``g``.

    This is the statistics pass of Alg. 1 line 6: fit the power-law tail,
    solve for α per the method, construct the codebook.  Tensors beyond
    ``plan_sample`` elements are subsampled with contiguous chunks (the
    tail fit is estimation; the encode itself always sees every element).
    This sort-based fit is the *fallback* statistics path — the bucketed
    codec feeds :func:`plan_from_stats` from the fused one-pass
    histogram/Hill-sum kernels instead and never sorts.
    """
    g32 = _plan_sample(g.reshape(-1).astype(jnp.float32), cfg.plan_sample)
    tail = dist.fit_power_law_tail(g32, gmin_quantile=cfg.gmin_quantile,
                                   approx_quantile=cfg.approx_gmin)
    if cfg.method == "qsgd":
        alpha = tail.g_max
        levels = uniform_levels(alpha, cfg.bits)
    elif cfg.method == "nqsgd":
        dens = dist.fit_empirical_density(g32, num_bins=cfg.hist_bins)
        alpha = tail.g_max
        levels = optimal.nonuniform_codebook(dens, alpha, cfg.bits)
    elif cfg.method == "tqsgd":
        alpha = optimal.solve_alpha_uniform(tail, cfg.bits, iters=cfg.alpha_iters)
        levels = uniform_levels(alpha, cfg.bits)
    elif cfg.method == "tnqsgd":
        dens = dist.fit_empirical_density(g32, num_bins=cfg.hist_bins)
        alpha = optimal.solve_alpha_nonuniform(tail, dens, cfg.bits, iters=cfg.alpha_iters)
        levels = optimal.nonuniform_codebook(dens, alpha, cfg.bits)
    elif cfg.method == "tbqsgd":
        dens = dist.fit_empirical_density(g32, num_bins=cfg.hist_bins)
        alpha, k = optimal.solve_biscaled(tail, dens, cfg.bits, iters=cfg.alpha_iters)
        levels = optimal.biscaled_codebook(dens, alpha, k, cfg.bits)
    else:  # dsgd
        alpha = tail.g_max
        levels = uniform_levels(alpha, cfg.bits)
    return QuantMeta(levels=levels.astype(jnp.float32), alpha=jnp.asarray(alpha, jnp.float32))


def plan_from_stats(
    cfg: CompressorConfig,
    counts: jax.Array,
    log_sums: jax.Array,
    g_max: jax.Array,
) -> QuantMeta:
    """Quantization plan from precomputed one-pass bucket statistics.

    ``counts``/``log_sums`` are the 128-bin log2-spaced |g| histogram and
    per-bin ln|g| Hill sums of ``kernels.stats`` (one fused VMEM pass —
    ``kernels.ops.bucket_stats`` / ``ef_correct_stats`` — or the
    scatter-add fallback), ``g_max`` the exact max |g|.  The tail comes
    from :func:`repro.core.distributions.tail_from_histogram`, the density
    for the non-uniform codebooks from :func:`density_from_histogram`, so
    no sort, quantile, or second statistics sweep over the gradient bytes
    is needed — :func:`plan` (sort-based ``fit_power_law_tail`` /
    ``fit_empirical_density``) stays as the raw-tensor fallback.
    """
    from repro.kernels.stats import bin_edges

    edges = bin_edges()
    tail = dist.tail_from_histogram(counts, log_sums, g_max, edges,
                                    gmin_quantile=cfg.gmin_quantile)
    if cfg.method in ("qsgd", "dsgd"):
        alpha = tail.g_max
        levels = uniform_levels(alpha, cfg.bits)
    elif cfg.method == "tqsgd":
        alpha = optimal.solve_alpha_uniform(tail, cfg.bits, iters=cfg.alpha_iters)
        levels = uniform_levels(alpha, cfg.bits)
    else:
        dens = dist.density_from_histogram(counts, edges)
        if cfg.method == "nqsgd":
            alpha = tail.g_max
            levels = optimal.nonuniform_codebook(dens, alpha, cfg.bits)
        elif cfg.method == "tnqsgd":
            alpha = optimal.solve_alpha_nonuniform(tail, dens, cfg.bits, iters=cfg.alpha_iters)
            levels = optimal.nonuniform_codebook(dens, alpha, cfg.bits)
        else:  # tbqsgd
            alpha, k = optimal.solve_biscaled(tail, dens, cfg.bits, iters=cfg.alpha_iters)
            levels = optimal.biscaled_codebook(dens, alpha, k, cfg.bits)
    return QuantMeta(levels=levels.astype(jnp.float32), alpha=jnp.asarray(alpha, jnp.float32))


def encode(cfg: CompressorConfig, g: jax.Array, meta: QuantMeta, key: jax.Array) -> jax.Array:
    """Encode ``g`` to the wire format (packed uint32 words, or uint8 codes)."""
    flat = g.reshape(-1).astype(jnp.float32)
    if cfg.use_pallas and cfg.method in ("qsgd", "tqsgd", "dsgd"):
        from repro.kernels import ops as kops

        codes = kops.uniform_encode(flat, meta.alpha, cfg.bits, key)
    elif cfg.use_pallas:
        from repro.kernels import ops as kops

        codes = kops.codebook_encode(flat, meta.levels, key)
    else:
        codes = stochastic_encode(flat, meta, key)
    if cfg.pack:
        return pack_codes(codes, cfg.bits)
    return codes


def decode(cfg: CompressorConfig, wire: jax.Array, meta: QuantMeta, shape: tuple[int, ...]) -> jax.Array:
    n = 1
    for d in shape:
        n *= d
    if cfg.pack:
        from .quantizers import packed_size

        expected = packed_size(n, cfg.bits)
        if wire.shape != (expected,):
            # unpack_codes would silently truncate (or read garbage from) a
            # wire whose packed length disagrees with shape/bits
            raise ValueError(
                f"wire has shape {tuple(wire.shape)}; {n} elements at "
                f"{cfg.bits} bits need ({expected},) packed uint32 words")
        codes = unpack_codes(wire, n, cfg.bits)
    else:
        if wire.shape != (n,):
            raise ValueError(
                f"unpacked wire has shape {tuple(wire.shape)}; expected ({n},) codes")
        codes = wire
    return _decode(codes, meta).reshape(shape)


def compress_decompress(cfg: CompressorConfig, g: jax.Array, key: jax.Array) -> jax.Array:
    """One-shot quantization surrogate  C_b[g]  (what the server receives)."""
    if cfg.method == "dsgd":
        return g
    meta = plan(cfg, g)
    wire = encode(cfg, g, meta, key)
    return decode(cfg, wire, meta, g.shape).astype(g.dtype)


def _is_plan_entry(entry) -> bool:
    """A per-bucket ``("method", value)`` plan pair (vs a bits list)."""
    return (isinstance(entry, list | tuple) and len(entry) == 2
            and isinstance(entry[0], str))


def wire_bytes(cfg: CompressorConfig, n_elements, bits=None) -> int:
    """Bytes on the wire for one tensor (payload + meta).

    This is the single source of truth for wire accounting (used by
    ``dist.collectives.wire_bytes_per_device`` and the benchmarks): packed
    payload of ``bits``/element rounded up to uint32 groups, plus the
    codebook metadata — ``s+1`` fp32 levels and the fp32 alpha, ``s+2``
    words total.

    Heterogeneous adaptive formats are first-class: ``n_elements`` may be a
    sequence of per-bucket sizes, optionally with a matching sequence of
    per-bucket ``bits`` (scalar ``bits`` overrides ``cfg.bits`` uniformly).
    A per-bucket entry may also be a ``("method", value)`` pair or a full
    :class:`CompressorConfig` (the method-aware adaptive plans), resolved
    through ``core.codecs.bucket_cfg_entry`` — rank-based codecs account
    their own factor wire.  The result is the total over buckets — the
    fused wire tensor pays one codebook per bucket, which is exactly this
    sum.
    """
    if isinstance(n_elements, list | tuple):
        if isinstance(bits, list | tuple) and not _is_plan_entry(bits):
            if len(bits) != len(n_elements):
                raise ValueError(f"{len(bits)} bit-widths vs {len(n_elements)} buckets")
            return sum(wire_bytes(cfg, n, b) for n, b in zip(n_elements, bits))
        return sum(wire_bytes(cfg, n, bits) for n in n_elements)
    if _is_plan_entry(bits) or isinstance(bits, CompressorConfig):
        from .codecs import bucket_cfg_entry

        return wire_bytes(bucket_cfg_entry(cfg, bits), n_elements)
    if isinstance(bits, list | tuple):
        raise ValueError("per-bucket bits need a matching list of bucket sizes")
    if cfg.method == "dsgd":
        return 4 * n_elements
    if cfg.method not in METHODS:
        from .codecs import get_codec

        return get_codec(cfg.method).wire_bytes(cfg, n_elements)
    from .quantizers import num_levels, packed_size

    b = cfg.bits if bits is None else int(bits)
    if not (1 <= b <= 8):
        raise ValueError("bits must be in [1, 8]")
    payload = 4 * packed_size(n_elements, b) if cfg.pack else n_elements
    meta = 4 * (num_levels(b) + 2)
    return payload + meta


def wire_bits_per_element(cfg: CompressorConfig, n_elements, bits=None) -> float:
    """Effective wire bits per element, metadata included (8·wire_bytes/n)."""
    total = sum(n_elements) if isinstance(n_elements, list | tuple) else n_elements
    return 8.0 * wire_bytes(cfg, n_elements, bits) / max(total, 1)


# ---------------------------------------------------------------------------
# Bucket planner: DDP-style coalescing of a gradient pytree into a few large
# flat fp32 buckets.  One codebook (``plan``) per bucket amortizes the
# statistics pass and lets the distributed codec issue one collective per
# bucket (or per bucket *list*) instead of one per tensor.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static coalescing plan over a flattened leaf list.

    Bucket ``b`` holds the consecutive leaves ``ranges[b][0]:ranges[b][1]``
    (traversal order — adjacent leaves usually share scale, which keeps the
    per-bucket codebook tight) and has ``sizes[b]`` total elements.
    """

    ranges: tuple[tuple[int, int], ...]
    sizes: tuple[int, ...]

    @property
    def n_buckets(self) -> int:
        return len(self.ranges)


def plan_buckets(leaf_sizes: list[int], target_elements: int) -> BucketPlan:
    """Greedy size-targeted coalescing: pack consecutive leaves until the
    next one would push the bucket past ``target_elements``.  A single leaf
    larger than the target gets its own bucket."""
    if not leaf_sizes:
        return BucketPlan((), ())
    target = max(int(target_elements), 1)
    ranges, sizes = [], []
    start, acc = 0, 0
    for i, sz in enumerate(leaf_sizes):
        if acc and acc + sz > target:
            ranges.append((start, i))
            sizes.append(acc)
            start, acc = i, 0
        acc += sz
    ranges.append((start, len(leaf_sizes)))
    sizes.append(acc)
    return BucketPlan(tuple(ranges), tuple(sizes))


def bucket_concat(leaves: list, bp: BucketPlan) -> list:
    """Flatten + concatenate leaves into the plan's fp32 buckets."""
    return [
        jnp.concatenate([leaves[i].reshape(-1).astype(jnp.float32) for i in range(a, b)])
        if b - a > 1 else leaves[a].reshape(-1).astype(jnp.float32)
        for (a, b) in bp.ranges
    ]


def bucket_split(buckets: list, bp: BucketPlan, shapes: list) -> list:
    """Inverse of :func:`bucket_concat`: slice buckets back into shaped leaves."""
    out = []
    for (a, b), flat in zip(bp.ranges, buckets):
        off = 0
        for i in range(a, b):
            n = 1
            for d in shapes[i]:
                n *= d
            out.append(flat[off:off + n].reshape(shapes[i]))
            off += n
    return out


# ---------------------------------------------------------------------------
# Pytree-level API: per-tensor plans over a gradient pytree (the paper
# quantizes conv and fc layers independently; we generalise to per-tensor).
# ---------------------------------------------------------------------------


def tree_compress_decompress(cfg: CompressorConfig, grads: Any, key: jax.Array) -> Any:
    """Apply the two-stage quantizer independently to every tensor in a pytree."""
    if cfg.method == "dsgd":
        return grads
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = [compress_decompress(cfg, leaf, k) for leaf, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)
