"""Closed-form error expressions from the paper, for validation benchmarks.

These mirror Eq. (11), (15), (27) and the theorem bounds so tests/benchmarks
can compare the *measured* quantization MSE of each scheme against the
analytical prediction under the power-law model.
All expressions are per-element (the paper's E_TQ carries a d/N factor which
is constant across schemes and dropped here unless requested).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .distributions import EmpiricalDensity, PowerLawTail, q_u, truncation_bias
from .optimal import q_b, q_n
from .quantizers import num_levels

_EPS = 1e-12


def quant_variance_uniform(tail: PowerLawTail, alpha: jax.Array, bits: int) -> jax.Array:
    """First term of Eq. 11 (per element):  Q_U(α) α² / s²  · (1/4·4 = 1)…

    Eq. 11 states  E_var = Q_U(α) α² / s²  after substituting λ = s/2α into
    (1/4)∫ p/λ²:  (1/4)(2α/s)² ∫ p = α² Q_U / s².
    """
    s = num_levels(bits)
    return q_u(tail, alpha) * alpha**2 / s**2


def quant_variance_density(
    dens: EmpiricalDensity, levels: jax.Array
) -> jax.Array:
    """(1/4) Σ_k P_k |Δ_k|²  (Lemma 1 bound) for an arbitrary codebook,
    evaluated under the empirical density."""
    from .distributions import cum_p, interp_cum

    cp = cum_p(dens)
    # Mass in [l_{k-1}, l_k] under the symmetric density: use |g| cumulative.
    def mass(lo, hi):
        def one_sided(x):
            return jnp.sign(x) * interp_cum(cp, dens, jnp.abs(x))
        return one_sided(hi) - one_sided(lo)

    lo = levels[:-1]
    hi = levels[1:]
    pk = jax.vmap(mass)(lo, hi)
    return 0.25 * jnp.sum(jnp.maximum(pk, 0.0) * (hi - lo) ** 2)


def e_tq_uniform(tail: PowerLawTail, alpha: jax.Array, bits: int) -> jax.Array:
    """Per-element E_TQ for the truncated uniform quantizer (Eq. 11 without d/N)."""
    return quant_variance_uniform(tail, alpha, bits) + truncation_bias(tail, alpha)


def e_tq_nonuniform(
    tail: PowerLawTail, dens: EmpiricalDensity, alpha: jax.Array, bits: int
) -> jax.Array:
    """Per-element E_TQ for the truncated *non-uniform* quantizer:
    Q_N(α) α²/s² quantization variance (Eq. 15 with λ ∝ p^(1/3)) plus the
    same power-law truncation bias as Eq. 11."""
    s = num_levels(bits)
    return q_n(dens, alpha) * alpha**2 / s**2 + truncation_bias(tail, alpha)


def e_tq_bound(tail: PowerLawTail, q_value: jax.Array, bits: int) -> jax.Array:
    """Theorem 1/2/3 master bound (per element, without d/N):

        (γ-1) Q^{(γ-3)/(γ-1)} · g_min² (2ρ)^{2/(γ-1)} s^{(6-2γ)/(γ-1)}
        / ((γ-3)(γ-2)^{2/(γ-1)})

    with Q = Q_U, Q_N or Q_B for TQSGD / TNQSGD / TBQSGD respectively.
    """
    s = num_levels(bits)
    ga, gm, rho = tail.gamma, tail.g_min, tail.rho
    num = (ga - 1.0) * jnp.power(q_value, (ga - 3.0) / (ga - 1.0))
    num = num * gm**2 * jnp.power(2.0 * rho, 2.0 / (ga - 1.0)) * jnp.power(jnp.asarray(s, jnp.float32), (6.0 - 2.0 * ga) / (ga - 1.0))
    den = (ga - 3.0) * jnp.power(ga - 2.0, 2.0 / (ga - 1.0))
    return num / jnp.maximum(den, _EPS)


def holder_chain(tail: PowerLawTail, dens: EmpiricalDensity, alpha: jax.Array, k: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (Q_N, Q_B, Q_U) at a common α — the paper's Hölder ordering
    Q_N ≤ Q_B ≤ Q_U (non-uniform at least as good as bi-scaled, which is at
    least as good as uniform)."""
    return q_n(dens, alpha), q_b(dens, alpha, k), q_u(tail, alpha)


def dsgd_error(f0_minus_fstar: float, eta: float, T: int, sigma2: float, n_clients: int, batch: int) -> float:
    """E_DSGD of Eq. 7: 2ΔF/(Tη) + σ²/(NB)."""
    return 2.0 * f0_minus_fstar / (T * eta) + sigma2 / (n_clients * batch)
