"""PowerSGD low-rank gradient compression (Vogels et al.), as a codec plugin.

The second codec family behind :mod:`core.codecs`: a bucket's flat ``m``
elements are viewed as a (rows, cols) matrix ``M`` (zero-padded,
``cols`` = largest power of two ≤ √m) and compressed to rank-``r`` factors
by one warm-started power-iteration step:

    P  = M @ Q_prev          (Q_prev from the bucket's EF-state aux tail,
    P̂  = orth(P)              deterministic init on the first step)
    Qn = Mᵀ @ P̂

The transmission is the bitcast fp32 pair ``(P̂, Qn)`` —
``(rows + cols) · r`` words, independent of the bit width — and the decode
is the rank-``r`` reconstruction ``P̂ @ Qnᵀ`` averaged over peers.  The
compressor is *biased*; the EF residual ``c − P̂ @ Qnᵀ`` (computed against
this peer's own factors) feeds the next step's error feedback, which is
what makes biased low-rank compression converge (Wu et al., 1806.08054;
Vogels et al.).  ``Qn`` is carried to the next step in the same EF row
(``state_extra`` = cols·r), warm-starting the power iteration — one
iteration per step then tracks the gradient's dominant subspace.

Peer symmetry: the cold-start ``Q₀`` is a fixed-key normal draw — a *trace
constant*, identical on every peer — and the orthogonalization runs through
``kernels.orthogonalize`` (Pallas kernel under ``use_pallas``, the shared-
body ``kernels.ref`` oracle otherwise), so the mesh and the single-device
reference execute the identical op sequence (pinned by
``tests/test_mesh_invariance.py``).

Not chunkable: factor matrices do not slice element-wise, so the two-phase
collective tiles the full wire into every all-to-all row (an embedded
all-gather) and decodes entirely in phase 1 (see ``core.codecs``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .codecs import Codec, register_codec
from .compressors import CompressorConfig

# Fixed cold-start key: a trace-time constant, so every peer (and the
# single-device reference) draws the same Q₀ without any communication.
_Q0_SEED = 0x51D


def matrix_shape(m: int) -> tuple[int, int]:
    """Static (rows, cols) factorization target for a flat m-element bucket.

    cols is the largest power of two ≤ √m (clamped to [1, m]) — near-square
    keeps the factor wire ``(rows + cols)·r`` minimal, and the power-of-two
    width keeps the padded tail small and lane-friendly.
    """
    if m <= 1:
        return max(m, 1), 1
    cols = 1 << (int(math.isqrt(m)).bit_length() - 1)
    cols = max(min(cols, m), 1)
    return -(-m // cols), cols


def effective_rank(cfg: CompressorConfig, m: int) -> int:
    """``cfg.rank`` clamped to the bucket's matrix: r ≤ min(rows, cols)."""
    rows, cols = matrix_shape(m)
    return max(1, min(cfg.rank, rows, cols))


def orthogonalize(p: jax.Array, use_pallas: bool) -> jax.Array:
    """Gram–Schmidt dispatch: Pallas kernel vs the shared-body jnp oracle."""
    if use_pallas:
        from repro.kernels import ops

        return ops.orthogonalize(p)
    from repro.kernels import ref

    return ref.orthogonalize(p)


def _q_init(cols: int, r: int) -> jax.Array:
    # Deliberately peer-identical: PowerSGD requires every worker to start
    # the power iteration in the same random subspace so the gathered P/Q
    # factors are averageable (and the cold-start wire is reproducible).
    # repro: allow REPRO102, REPRO204 (shared Q0 init is the PowerSGD contract)
    return jax.random.normal(jax.random.key(_Q0_SEED), (cols, r), jnp.float32)


def _factorize(cfg: CompressorConfig, flat: jax.Array, use_pallas: bool,
               q_prev=None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One warm-started power-iteration step -> (P̂, Qn, own reconstruction)."""
    m = flat.size
    rows, cols = matrix_shape(m)
    r = effective_rank(cfg, m)
    mat = jnp.pad(flat, (0, rows * cols - m)).reshape(rows, cols)
    q0 = _q_init(cols, r)
    if q_prev is None:
        q = q0
    else:
        qm = q_prev.reshape(cols, r)
        # zero aux (a freshly initialized EF row) means "no warm start yet"
        q = jnp.where(jnp.sum(qm * qm) > 0.0, qm, q0)
    p_hat = orthogonalize(mat @ q, use_pallas)
    q_new = mat.T @ p_hat
    own = (p_hat @ q_new.T).reshape(-1)[:m]
    return p_hat, q_new, own


def _wire(p_hat: jax.Array, q_new: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(
        jnp.concatenate([p_hat.reshape(-1), q_new.reshape(-1)]), jnp.uint32)


class PowerSGDCodec(Codec):
    """Rank-based low-rank codec; fidelity knob is ``cfg.rank``."""

    name = "powersgd"
    chunkable = False
    rank_based = True

    def wire_words(self, cfg, n):
        rows, cols = matrix_shape(n)
        return (rows + cols) * effective_rank(cfg, n)

    def state_extra(self, cfg, n):
        _, cols = matrix_shape(n)
        return cols * effective_rank(cfg, n)

    def encode(self, cfg, flat, pln, key, use_pallas):
        p_hat, q_new, _ = _factorize(cfg, flat, use_pallas)
        return _wire(p_hat, q_new)

    def encode_residual(self, cfg, flat, pln, key, use_pallas, aux=None):
        p_hat, q_new, own = _factorize(cfg, flat, use_pallas, q_prev=aux)
        return _wire(p_hat, q_new), flat - own, q_new.reshape(-1)

    def _peer_recons(self, cfg, rows, n):
        rws, cols = matrix_shape(n)
        r = effective_rank(cfg, n)
        pw = rws * r
        vals = jax.lax.bitcast_convert_type(rows, jnp.float32)
        out = []
        for j in range(rows.shape[0]):
            p_hat = vals[j, :pw].reshape(rws, r)
            q_new = vals[j, pw:pw + cols * r].reshape(cols, r)
            out.append((p_hat @ q_new.T).reshape(-1)[:n])
        return out

    def decode_reduce(self, cfg, rows, n, use_pallas):
        recons = self._peer_recons(cfg, rows, n)
        acc = recons[0]
        for v in recons[1:]:
            acc = acc + v
        return acc / float(len(recons))

    def decode_rows(self, cfg, rows, n, use_pallas):
        return jnp.stack(self._peer_recons(cfg, rows, n))


register_codec(PowerSGDCodec())
