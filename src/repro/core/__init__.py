"""Core of the paper's contribution: truncated quantization for DSGD.

Public API:
- distributions: power-law tail fitting (Hill/MLE), empirical densities
- quantizers:    truncation + stochastic codebook quantization + bit packing
- optimal:       α / λ_s solvers for TQSGD / TNQSGD / TBQSGD
- compressors:   method registry with plan/encode/decode over pytrees
- theory:        closed-form error expressions for validation
"""
from . import compressors, distributions, optimal, quantizers, theory
from .compressors import METHODS, CompressorConfig, compress_decompress, tree_compress_decompress
from .distributions import PowerLawTail, fit_power_law_tail, sample_power_law
from .quantizers import QuantMeta, decode, num_levels, stochastic_encode, truncate

__all__ = [
    "METHODS",
    "CompressorConfig",
    "PowerLawTail",
    "QuantMeta",
    "compress_decompress",
    "compressors",
    "decode",
    "distributions",
    "fit_power_law_tail",
    "num_levels",
    "optimal",
    "quantizers",
    "sample_power_law",
    "stochastic_encode",
    "theory",
    "tree_compress_decompress",
    "truncate",
]
