"""Two-stage quantizer primitives (paper §III).

The quantizer is `Q_λs[T_α(g)]`: truncation to [-α, α] (Eq. 3) followed by
stochastic quantization onto a codebook L = {l_0 < ... < l_s} (Eq. 4), where
the codebook is induced by a quantization-density function λ_s (uniform λ
recovers QSGD).  ``s = 2^b - 1`` intervals, codes in [0, s].

This module is the pure-jnp reference implementation; the Pallas kernels in
``repro.kernels`` implement the same contract for the TPU hot path and are
tested against these functions.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_EPS = 1e-12


def num_levels(bits: int) -> int:
    """s = 2^b - 1 intervals -> s+1 codebook points."""
    return 2**bits - 1


def truncate(g: jax.Array, alpha: jax.Array) -> jax.Array:
    """T_alpha[g] (Eq. 3): clamp magnitudes to alpha, keeping sign."""
    return jnp.clip(g, -alpha, alpha)


def uniform_levels(alpha: jax.Array, bits: int) -> jax.Array:
    """Evenly spaced codebook over [-alpha, alpha] (QSGD / TQSGD)."""
    s = num_levels(bits)
    return jnp.linspace(-alpha, alpha, s + 1)


def levels_from_density(
    edges: jax.Array,
    lam: jax.Array,
    bits: int,
) -> jax.Array:
    """Build a codebook from a piecewise-constant density λ on |g| bins.

    ``edges`` are |g| bin edges over [0, alpha]; ``lam`` >= 0 is the (relative)
    quantization density per bin.  The codebook places the s interval
    boundaries at equal increments of the cumulative density, mirrored to the
    symmetric range [-alpha, alpha] (Eq. 18: λ ∝ p^(1/3), normalised so that
    ∫ λ = s).  Returns (s+1,) strictly increasing levels with l_0 = -alpha,
    l_s = +alpha.
    """
    s = num_levels(bits)
    alpha = edges[-1]
    # Mirror to the full range: [-alpha, alpha].
    full_edges = jnp.concatenate([-edges[::-1], edges[1:]])
    full_lam = jnp.concatenate([lam[::-1], lam])
    widths = jnp.diff(full_edges)
    cum = jnp.concatenate([jnp.zeros((1,), lam.dtype), jnp.cumsum(full_lam * widths)])
    total = jnp.maximum(cum[-1], _EPS)
    targets = jnp.linspace(0.0, total, s + 1)
    levels = jnp.interp(targets, cum, full_edges)
    # Pin the endpoints exactly and enforce strict monotonicity so that
    # interval lengths are never zero (degenerate λ would otherwise collapse
    # neighbouring levels).
    levels = levels.at[0].set(-alpha).at[-1].set(alpha)
    min_step = 2.0 * alpha * 1e-6 / (s + 1)
    levels = jax.lax.cummax(levels + min_step * jnp.arange(s + 1), axis=0) - min_step * jnp.arange(s + 1)
    return levels.astype(jnp.float32)


class QuantMeta(NamedTuple):
    """Per-tensor quantization metadata shipped alongside the codes.

    ``levels`` has static shape (s+1,). For a uniform quantizer the levels are
    the linspace over [-alpha, alpha]; decode is a pure table lookup either
    way, so the wire format is identical for all methods.
    """

    levels: jax.Array  # (s+1,) float32 codebook
    alpha: jax.Array   # scalar float32 truncation threshold (levels[-1])


def stochastic_encode(g: jax.Array, meta: QuantMeta, key: jax.Array) -> jax.Array:
    """Truncate + stochastically quantize ``g`` onto ``meta.levels`` (Eq. 4).

    Returns uint8 codes with the same shape as ``g`` (code k means levels[k]).
    Unbiased:  E[levels[code]] = truncate(g, alpha).
    """
    levels = meta.levels
    s = levels.shape[0] - 1
    gt = truncate(g, meta.alpha)
    # Interval index: k such that levels[k] <= gt < levels[k+1].
    k = jnp.clip(jnp.searchsorted(levels, gt, side="right") - 1, 0, s - 1)
    lo = levels[k]
    hi = levels[k + 1]
    pr = (gt - lo) / jnp.maximum(hi - lo, _EPS)
    up = jax.random.uniform(key, g.shape) < pr
    return (k + up.astype(k.dtype)).astype(jnp.uint8)


def decode(codes: jax.Array, meta: QuantMeta) -> jax.Array:
    """Map codes back to codebook values."""
    return jnp.take(meta.levels, codes.astype(jnp.int32))


def quantize(g: jax.Array, meta: QuantMeta, key: jax.Array) -> jax.Array:
    """encode+decode in one step: the quantized surrogate of ``g``."""
    return decode(stochastic_encode(g, meta, key), meta)


# ---------------------------------------------------------------------------
# Bit packing: codes (<= 8 bits each) into uint32 lanes for the wire format.
# Packs groups of 32 codes into ``bits`` uint32 words by bit-slicing, so the
# on-wire size is exactly bits/32 words per element (plus padding to 32).
# ---------------------------------------------------------------------------


def packed_size(n: int, bits: int) -> int:
    """Number of uint32 words for n codes at ``bits`` bits each."""
    groups = (n + 31) // 32
    return groups * bits


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """Pack flat uint8 codes (values < 2^bits) into a uint32 array.

    Layout: group g, bit-plane j -> word[g, j] holds bit j of codes
    [32g .. 32g+31] in its 32 lanes.  Shape: (ceil(n/32) * bits,).
    """
    n = codes.shape[0]
    groups = (n + 31) // 32
    padded = jnp.zeros((groups * 32,), jnp.uint32).at[:n].set(codes.astype(jnp.uint32))
    padded = padded.reshape(groups, 32)
    lane = (jnp.arange(32, dtype=jnp.uint32))[None, :, None]          # (1, 32, 1)
    planes = (padded[:, :, None] >> jnp.arange(bits, dtype=jnp.uint32)[None, None, :]) & 1
    words = jnp.sum(planes << lane, axis=1, dtype=jnp.uint32)          # (groups, bits)
    return words.reshape(-1)


def unpack_codes(words: jax.Array, n: int, bits: int) -> jax.Array:
    """Inverse of :func:`pack_codes`; returns (n,) uint8 codes."""
    groups = words.shape[0] // bits
    w = words.reshape(groups, bits)                                    # (groups, bits)
    lane = jnp.arange(32, dtype=jnp.uint32)[None, :, None]             # (1, 32, 1)
    bitvals = (w[:, None, :] >> lane) & 1                              # (groups, 32, bits)
    codes = jnp.sum(bitvals << jnp.arange(bits, dtype=jnp.uint32)[None, None, :], axis=2)
    return codes.reshape(-1)[:n].astype(jnp.uint8)

# Wire accounting lives in ``compressors.wire_bytes`` /
# ``compressors.wire_bits_per_element`` — the single source of truth for
# payload + codebook metadata costs (a former duplicate here charged the
# metadata differently and had no callers).
