"""Phase span recorder: wall-clock + ``jax.profiler`` trace annotations.

Two complementary mechanisms cover the step's phases:

- **Host spans** (:class:`SpanRecorder.span`) wrap host-visible phases —
  the dispatched train step, the ``AdaptiveStepper`` replan, checkpoint and
  sink flushes — with ``time.perf_counter`` wall clock *and* a
  ``jax.profiler.TraceAnnotation``, so the same names line up in a captured
  profiler trace.  Each closed span is aggregated in-process and (when a
  sink is attached) written as a ``"span"`` JSONL event.
- **In-graph scopes** — the encode / collective / decode bodies in
  ``dist.sharded_codec`` and the optimizer update in ``dist.train_step``
  run under ``jax.named_scope("obs.encode" | "obs.collective" |
  "obs.decode" | "obs.optimizer")``.  A jitted step cannot be phase-timed
  from the host, so these appear as named regions inside the profiler
  trace / HLO rather than as wall-clock events.

Note for span consumers: the first occurrence of a span typically includes
compilation; :meth:`SpanRecorder.summary` reports ``max_s`` alongside the
mean so compile spikes stay visible.
"""
from __future__ import annotations

import contextlib
import time

import jax

from .sink import SCHEMA_VERSION


def span_event(name: str, t_start: float, dur_s: float, step=None,
               attrs: dict | None = None) -> dict:
    ev = {"v": SCHEMA_VERSION, "kind": "span", "name": str(name),
          "t_start": float(t_start), "dur_s": float(dur_s)}
    if step is not None:
        ev["step"] = int(step)
    if attrs:
        ev["attrs"] = dict(attrs)
    return ev


class SpanRecorder:
    """Records named wall-clock spans; optionally streams them to a sink."""

    def __init__(self, sink=None, clock=time.perf_counter):
        self._sink = sink
        self._clock = clock
        # name -> [count, total_s, max_s]
        self._agg: dict[str, list[float]] = {}

    @contextlib.contextmanager
    def span(self, name: str, step=None, **attrs):
        t0 = self._clock()
        with jax.profiler.TraceAnnotation(f"repro.obs/{name}"):
            yield
        dur = self._clock() - t0
        agg = self._agg.setdefault(name, [0, 0.0, 0.0])
        agg[0] += 1
        agg[1] += dur
        agg[2] = max(agg[2], dur)
        if self._sink is not None:
            self._sink.write(span_event(name, t0, dur, step, attrs))

    def summary(self) -> dict[str, dict[str, float]]:
        """Per-span aggregate: ``{name: {count, total_s, mean_s, max_s}}``."""
        return {
            name: {"count": int(c), "total_s": tot, "mean_s": tot / c, "max_s": mx}
            for name, (c, tot, mx) in sorted(self._agg.items())
        }
