"""``python -m repro.obs <command>`` — observability CLI.

Commands:
  report   render per-bucket metric tables + phase breakdown from JSONL
           event logs (see ``python -m repro.obs report --help``)
"""
from __future__ import annotations

import sys

from . import report


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__.strip())
        return 0 if argv else 1
    cmd, rest = argv[0], argv[1:]
    if cmd == "report":
        return report.main(rest)
    print(f"unknown command {cmd!r}; expected 'report'", file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())
