"""Compression observability: in-graph metrics, phase spans, sinks, drift.

The sync region of ``dist.train_step`` optionally self-reports a
:class:`~repro.obs.metrics.CompressionMetrics` pytree per bucket
(``TrainStepConfig.metrics_compression``); this package holds that metric
computation plus everything host-side: the JSONL event sinks
(:mod:`repro.obs.sink`), the wall-clock/profiler span recorder
(:mod:`repro.obs.trace`), the power-law drift monitor
(:mod:`repro.obs.drift`) and the ``python -m repro.obs report`` CLI
(:mod:`repro.obs.report`).

Import note: :mod:`repro.obs.metrics` is imported by ``dist.train_step``,
so nothing in this package may import from :mod:`repro.dist`.
"""
from .drift import DriftEvent, DriftMonitor, ObsDriftWarning
from .metrics import CompressionMetrics
from .sink import (
    METRIC_FIELDS,
    SCHEMA_VERSION,
    EmaAggregator,
    JsonlSink,
    export_csv,
    metrics_event,
    read_events,
)
from .trace import SpanRecorder, span_event

__all__ = [
    "METRIC_FIELDS",
    "SCHEMA_VERSION",
    "CompressionMetrics",
    "DriftEvent",
    "DriftMonitor",
    "EmaAggregator",
    "JsonlSink",
    "ObsDriftWarning",
    "SpanRecorder",
    "export_csv",
    "metrics_event",
    "read_events",
    "span_event",
]
