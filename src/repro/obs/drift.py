"""Drift monitor: structured warnings when the paper's error model breaks.

The controller's α/bit allocation assumes the per-bucket gradient magnitude
follows a power-law tail with index γ ∈ (3, 5] (paper §3; the Hill estimate
in ``core.distributions.tail_from_histogram`` is *clipped* to
``[GAMMA_MIN, GAMMA_MAX]``).  Two drift signals are watched:

- **tail regime** (:meth:`DriftMonitor.check_tails`): a bucket's estimated
  γ sitting on a clip rail means the raw Hill estimate left the power-law
  regime the controller assumed — the fit railed, it did not converge.
- **error ratio** (:meth:`DriftMonitor.check_ratio`): realized quantization
  MSE exceeding the predicted E_TQ by more than ``ratio_threshold`` — the
  fitted tail no longer describes the data the codec is quantizing.
- **participation** (:meth:`DriftMonitor.check_participation`): the elastic
  live fraction falling below ``participation_floor`` — the surviving
  peers' renormalized mean is being computed from too small a sample for
  the per-bucket statistics the controller fitted to stay representative.

Each violation produces a :class:`DriftEvent` (kept on the monitor,
optionally written to a JSONL sink as a ``"drift"`` event) and a Python
:class:`ObsDriftWarning` via ``warnings.warn`` so library users can route
or silence them with the stdlib machinery.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core.distributions import GAMMA_MAX, GAMMA_MIN

from .sink import SCHEMA_VERSION


class ObsDriftWarning(UserWarning):
    """Category for compression-drift warnings raised by :class:`DriftMonitor`."""


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    kind: str     # "tail_regime" | "error_ratio" | "participation"
    bucket: int   # -1 for mesh-wide events (participation)
    step: int
    value: float  # the offending γ, realized/predicted ratio, or live fraction
    lo: float
    hi: float

    def message(self) -> str:
        if self.kind == "tail_regime":
            return (f"bucket {self.bucket} step {self.step}: Hill tail index "
                    f"gamma={self.value:.3f} railed outside the power-law regime "
                    f"({self.lo:.2f}, {self.hi:.2f}) the controller assumes")
        if self.kind == "participation":
            return (f"step {self.step}: live fraction {self.value:.2f} fell "
                    f"below the participation floor {self.lo:.2f} — the "
                    f"renormalized mean is running on a thin live set")
        return (f"bucket {self.bucket} step {self.step}: realized/predicted "
                f"quantization MSE ratio {self.value:.2f} exceeds {self.hi:.2f} "
                f"— the heavy-tail fit no longer matches the gradients")

    def to_event(self) -> dict:
        return {"v": SCHEMA_VERSION, "kind": "drift", "drift": self.kind,
                "bucket": self.bucket, "step": self.step,
                "value": self.value, "lo": self.lo, "hi": self.hi,
                "message": self.message()}


class DriftMonitor:
    """Consumes telemetry tail estimates and metrics rows; raises on drift.

    ``gamma_margin`` is the rail-detection slack around the estimator's
    ``[GAMMA_MIN, GAMMA_MAX]`` clip range; ``ratio_threshold`` the
    realized/predicted MSE ratio above which a bucket is flagged;
    ``participation_floor`` the elastic live fraction below which a step
    is flagged.  ``warn=False`` suppresses ``warnings.warn`` (events are
    still recorded).
    """

    def __init__(self, sink=None, gamma_margin: float = 0.02,
                 ratio_threshold: float = 4.0,
                 participation_floor: float = 0.5, warn: bool = True):
        self.sink = sink
        self.gamma_lo = GAMMA_MIN + gamma_margin
        self.gamma_hi = GAMMA_MAX - gamma_margin
        self.ratio_threshold = float(ratio_threshold)
        self.participation_floor = float(participation_floor)
        self.warn = warn
        self.events: list[DriftEvent] = []

    def _emit(self, ev: DriftEvent) -> None:
        self.events.append(ev)
        if self.sink is not None:
            self.sink.write(ev.to_event())
        if self.warn:
            warnings.warn(ev.message(), ObsDriftWarning, stacklevel=3)

    def check_tails(self, tails, step: int = 0) -> list[DriftEvent]:
        """``tails``: a stacked :class:`~repro.core.distributions.PowerLawTail`
        (``adaptive.telemetry.estimate_tails`` output) or any array of per-
        bucket γ estimates.  Flags buckets whose γ sits on a clip rail."""
        gammas = np.asarray(getattr(tails, "gamma", tails), dtype=np.float64).reshape(-1)
        new = []
        for b, g in enumerate(gammas):
            if g <= self.gamma_lo or g >= self.gamma_hi:
                ev = DriftEvent("tail_regime", b, int(step), float(g),
                                float(GAMMA_MIN), float(GAMMA_MAX))
                self._emit(ev)
                new.append(ev)
        return new

    def check_ratio(self, realized, predicted, step: int = 0) -> list[DriftEvent]:
        """Flags buckets with ``realized > ratio_threshold * predicted``.
        Buckets without a prediction (``predicted <= 0``: rank-based or
        uncompressed) are skipped — there is no model to drift from."""
        realized = np.asarray(realized, dtype=np.float64).reshape(-1)
        predicted = np.asarray(predicted, dtype=np.float64).reshape(-1)
        new = []
        for b, (r, p) in enumerate(zip(realized, predicted)):
            if p <= 0.0 or not np.isfinite(p) or not np.isfinite(r):
                continue
            ratio = r / p
            if ratio > self.ratio_threshold:
                ev = DriftEvent("error_ratio", b, int(step), float(ratio),
                                0.0, self.ratio_threshold)
                self._emit(ev)
                new.append(ev)
        return new

    def check_participation(self, live_frac, step: int = 0) -> list[DriftEvent]:
        """Flags a step whose elastic live fraction sits below the floor.

        ``live_frac`` is ``live_count / n_peers`` for the step (any scalar
        convertible); ``bucket`` is reported as ``-1`` — participation is a
        mesh-wide property, not a per-bucket one.
        """
        frac = float(np.asarray(live_frac, dtype=np.float64).reshape(-1)[0])
        if not np.isfinite(frac) or frac >= self.participation_floor:
            return []
        ev = DriftEvent("participation", -1, int(step), frac,
                        self.participation_floor, 1.0)
        self._emit(ev)
        return [ev]
