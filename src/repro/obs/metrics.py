"""In-graph per-bucket compression metrics (the sync region's self-report).

The paper's contribution is an *error model*: pick α and the codebook to
minimize the predicted quantization error E_TQ.  This module computes, from
tensors the bucketed sync already holds, everything needed to verify that
model online:

- the **realized** per-element quantization MSE of this peer's own encode —
  the fused encode's EF residual is exactly ``corrected − C(corrected)``,
  so ``Σ resid² / m`` costs nothing extra;
- the **predicted** per-element E_TQ for the *same plan* —
  ``tail_from_histogram`` / ``density_from_histogram`` over the one-pass
  stats the codec already computed, fed to ``core.theory.e_tq_uniform`` /
  ``e_tq_nonuniform`` (all jnp-traceable, no host round trip);
- the solved α (the codec's own ``plan`` recomputed from the same stats —
  XLA CSEs it with the encode's plan), the truncation clip fraction
  ``mean(|g| > α)``, the incoming EF-residual norm, and the static wire
  geometry (bits / rank / wire bytes per peer transmission).

The split mirrors how the metrics cross the mesh: :func:`local_sums` emits
per-model-shard *sums* plus a static geometry record; the caller reduces the
``(B, N_REDUCED)`` sums with **one** ``psum`` over the model axes (fused
with the ``metrics_gnorm`` scalar, so the traced collective count does not
change) and :func:`finalize` normalizes them into a
:class:`CompressionMetrics` pytree of ``(B,)`` leaves.  On meshes without
model axes the psum is skipped and the whole pipeline is bitwise identical
to the single-device replay in ``dist.reference``.

Semantics notes (documented, not configurable):

- ``realized_mse`` tracks the **worker-side encode** (the transmission the
  EF state compensates).  The two-phase mode's phase-2 mean re-encode and
  the hierarchical cross-pod exchange are not included.
- On model-sharded meshes each shard plans and encodes its own local slice,
  so ``alpha``/``predicted_mse``/``clip_frac`` are shard *means* and
  ``realized_mse`` the global sum over the bucket's elements.
- Uncompressed syncs (``dsgd``) report ``bits=32``, fp32 wire bytes, and
  zeros elsewhere; rank-based codecs (``powersgd``) report their rank and
  realized/EF terms but no α/predicted (the scalar-quantizer error model
  does not apply).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import theory
from repro.core.codecs import get_codec
from repro.core.distributions import density_from_histogram, tail_from_histogram
from repro.kernels.stats import bin_edges

#: per-bucket reduced columns: resid_sq, clip_count, ef_sq, alpha, predicted
N_REDUCED = 5

#: methods whose predicted error uses the uniform-codebook E_TQ (Eq. 11);
#: every other scalar quantizer gets the non-uniform form (Eq. 15).
_UNIFORM_PRED = ("qsgd", "tqsgd", "dsgd")


class CompressionMetrics(NamedTuple):
    """Per-bucket compression metrics; every leaf is a ``(B,)`` array.

    Through ``make_train_step`` the leaves come back stacked per data peer
    as ``(n_dp, B)`` — row ``j`` is peer ``j``'s own encode (model-shard
    reduced).  ``bits``/``rank``/``wire_bytes`` are trace-time constants.
    """

    bits: jax.Array           # (B,) int32 — wire bits (32 = uncompressed, 0 = rank-based)
    rank: jax.Array           # (B,) int32 — factor rank (0 for scalar quantizers)
    alpha: jax.Array          # (B,) f32 — solved truncation threshold (shard mean)
    clip_frac: jax.Array      # (B,) f32 — fraction of elements with |g| > α
    ef_norm: jax.Array        # (B,) f32 — ‖incoming EF residual‖₂
    wire_bytes: jax.Array     # (B,) f32 — accounted bytes of one peer transmission
    realized_mse: jax.Array   # (B,) f32 — Σ(corrected − C(corrected))² / m
    predicted_mse: jax.Array  # (B,) f32 — per-element E_TQ for the same plan


class MetricStatic(NamedTuple):
    """Trace-time geometry carried around the psum (all Python int tuples)."""

    bits: tuple[int, ...]
    rank: tuple[int, ...]
    wire_bytes: tuple[int, ...]
    sizes: tuple[int, ...]  # local (per model shard) bucket element counts


def local_sums(ts, cfgs: list, buckets: list, stats: list | None,
               state_rows: list | None, ef: list | None,
               compressed: bool) -> tuple[jax.Array, MetricStatic]:
    """Per-bucket metric sums of *this peer's local shard*.

    ``buckets`` are the EF-corrected flat buckets the codec encoded,
    ``stats`` the matching one-pass statistics tuples, ``state_rows`` the
    per-bucket EF/state rows the collective returned (residual prefix +
    codec aux tail), ``ef`` the *incoming* residual rows.  Returns a
    ``(B, N_REDUCED)`` f32 array of sums — additive over model shards, so
    one psum recovers the bucket-global values — plus the static geometry.
    """
    use_pallas = ts.compressor.use_pallas
    edges = bin_edges()
    cols, bits_t, rank_t, wire_t, sizes_t = [], [], [], [], []
    for b, g in enumerate(buckets):
        flat = g.reshape(-1)
        m = flat.size
        cfg_b = cfgs[b]
        codec = get_codec(cfg_b.method)
        sizes_t.append(m)
        if not compressed:
            bits_t.append(32)
            rank_t.append(0)
            wire_t.append(4 * m)
        elif codec.rank_based:
            bits_t.append(0)
            rank_t.append(int(cfg_b.rank))
            wire_t.append(int(codec.wire_bytes(cfg_b, m)))
        else:
            bits_t.append(int(codec.fixed_wire_bits or cfg_b.bits))
            rank_t.append(0)
            wire_t.append(int(codec.wire_bytes(cfg_b, m)))
        zero = jnp.zeros((), jnp.float32)
        resid_sq = zero
        if compressed and state_rows is not None:
            resid_sq = jnp.sum(jnp.square(state_rows[b][:m].astype(jnp.float32)))
        ef_sq = zero
        if ef is not None and ef[b] is not None:
            ef_sq = jnp.sum(jnp.square(ef[b][:m].astype(jnp.float32)))
        alpha = clip = pred = zero
        # Same plan the encode used (deterministic from the same stats, so
        # XLA CSEs the recomputation — no second statistics sweep).  Plan-
        # less passthrough codecs (fp16) have no α/E_TQ, like rank-based.
        pln = None
        if compressed and not codec.rank_based and stats is not None:
            pln = codec.plan(cfg_b, flat, stats[b], use_pallas)
        if pln is not None:
            counts, log_sums, g_max = stats[b][0], stats[b][1], stats[b][2]
            alpha = pln.alpha.astype(jnp.float32)
            clip = jnp.sum((jnp.abs(flat) > alpha).astype(jnp.float32))
            tail = tail_from_histogram(counts, log_sums, g_max, edges,
                                       gmin_quantile=cfg_b.gmin_quantile)
            if cfg_b.method in _UNIFORM_PRED:
                pred = theory.e_tq_uniform(tail, alpha, cfg_b.bits)
            else:
                dens = density_from_histogram(counts, edges)
                pred = theory.e_tq_nonuniform(tail, dens, alpha, cfg_b.bits)
            pred = pred.astype(jnp.float32)
        cols.append(jnp.stack([resid_sq, clip, ef_sq, alpha, pred]))
    static = MetricStatic(bits=tuple(bits_t), rank=tuple(rank_t),
                          wire_bytes=tuple(wire_t), sizes=tuple(sizes_t))
    return jnp.stack(cols), static


def finalize(sums: jax.Array, static: MetricStatic, n_model: int) -> CompressionMetrics:
    """Normalize (possibly psum-reduced) ``(B, N_REDUCED)`` sums into metrics.

    ``n_model`` is the number of model shards the sums were reduced over
    (1 on a data-only mesh, where this is bitwise the local computation:
    the divisors below are exact-by-1 in that case except the genuine
    per-element normalizations, which the reference replay repeats
    identically).
    """
    resid_sq, clip, ef_sq, alpha, pred = (sums[:, i] for i in range(N_REDUCED))
    m_glob = jnp.asarray([m * n_model for m in static.sizes], jnp.float32)
    inv_shards = jnp.float32(1.0 / n_model)
    return CompressionMetrics(
        bits=jnp.asarray(static.bits, jnp.int32),
        rank=jnp.asarray(static.rank, jnp.int32),
        alpha=alpha * inv_shards,
        clip_frac=clip / m_glob,
        ef_norm=jnp.sqrt(ef_sq),
        wire_bytes=jnp.asarray(static.wire_bytes, jnp.float32),
        realized_mse=resid_sq / m_glob,
        predicted_mse=pred * inv_shards,
    )
