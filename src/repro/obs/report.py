"""Render observability reports from ``runs/obs/*.jsonl`` event logs.

``python -m repro.obs report --dir runs/obs`` prints

- a per-bucket table (bits/rank, EMA-smoothed α, clip %, wire bytes,
  predicted vs realized per-element MSE and their ratio), flagging buckets
  whose realized/predicted ratio exceeds ``--threshold`` — i.e. where the
  heavy-tail fit the controller relied on broke;
- a step-time phase breakdown from the wall-clock ``"span"`` events;
- any structured ``"drift"`` warnings the run recorded.

``--json OBS.json`` additionally writes the machine-readable summary that
``benchmarks/check_obs.py`` validates in CI; ``--csv FILE`` dumps the raw
per-step metric rows.
"""
from __future__ import annotations

import argparse
import json
import pathlib

from .sink import EmaAggregator, export_csv, read_events

DEFAULT_THRESHOLD = 2.0


def _ratio(row: dict) -> float | None:
    pred = row.get("predicted_mse", 0.0)
    if not pred or pred <= 0.0:
        return None
    return row.get("realized_mse", 0.0) / pred


def summarize(events: list[dict], threshold: float = DEFAULT_THRESHOLD,
              ema_decay: float = 0.9) -> dict:
    """Aggregate an event list into the OBS summary dict (see check_obs)."""
    ema = EmaAggregator(decay=ema_decay)
    steps = set()
    for ev in events:
        if ev.get("kind") == "metrics":
            steps.add(ev.get("step"))
            ema.update(ev)
    buckets = []
    for row in ema.summary():
        ratio = _ratio(row)
        buckets.append({**row, "ratio": ratio,
                        "flagged": bool(ratio is not None and ratio > threshold)})
    spans: dict[str, dict] = {}
    for ev in events:
        if ev.get("kind") != "span":
            continue
        agg = spans.setdefault(ev["name"], {"count": 0, "total_s": 0.0, "max_s": 0.0})
        agg["count"] += 1
        agg["total_s"] += ev.get("dur_s", 0.0)
        agg["max_s"] = max(agg["max_s"], ev.get("dur_s", 0.0))
    phases = [{"name": k, **v, "mean_s": v["total_s"] / v["count"]}
              for k, v in sorted(spans.items())]
    drift = [ev for ev in events if ev.get("kind") == "drift"]
    return {"version": 1, "n_events": len(events), "n_steps": len(steps),
            "threshold": threshold, "buckets": buckets, "phases": phases,
            "drift": drift,
            "flagged": [b["bucket"] for b in buckets if b["flagged"]]}


def bucket_table(summary: dict) -> str:
    rows = ["| bucket | bits | rank | alpha | clip % | wire B | predicted | "
            "realized | ratio | |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    fmt = lambda v: "-" if v is None else f"{v:.3e}"
    for b in summary["buckets"]:
        flag = "**DRIFT**" if b["flagged"] else ""
        ratio_s = "-" if b["ratio"] is None else f"{b['ratio']:.2f}"
        rows.append(
            f"| {b['bucket']} | {b.get('bits', 0):.0f} | {b.get('rank', 0):.0f} "
            f"| {fmt(b.get('alpha'))} | {100.0 * b.get('clip_frac', 0.0):.2f} "
            f"| {b.get('wire_bytes', 0):.0f} | {fmt(b.get('predicted_mse'))} "
            f"| {fmt(b.get('realized_mse'))} | {ratio_s} | {flag} |")
    return "\n".join(rows)


def phase_table(summary: dict) -> str:
    rows = ["| phase | count | total (s) | mean (ms) | max (ms) |",
            "|---|---|---|---|---|"]
    for p in summary["phases"]:
        rows.append(f"| {p['name']} | {p['count']} | {p['total_s']:.3f} "
                    f"| {1e3 * p['mean_s']:.1f} | {1e3 * p['max_s']:.1f} |")
    return "\n".join(rows)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs report")
    ap.add_argument("--dir", default="runs/obs",
                    help="directory of *.jsonl event files (or one file)")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="realized/predicted MSE ratio above which a bucket "
                         "is flagged as drifted")
    ap.add_argument("--ema", type=float, default=0.9, help="EMA decay")
    ap.add_argument("--json", default=None, dest="json_path",
                    help="write the machine-readable OBS summary here")
    ap.add_argument("--csv", default=None, dest="csv_path",
                    help="export raw per-step metric rows as CSV")
    args = ap.parse_args(argv)

    events = read_events(args.dir)
    if not events:
        print(f"no events under {args.dir}")
        return 1
    summary = summarize(events, threshold=args.threshold, ema_decay=args.ema)

    print(f"## Compression metrics ({summary['n_steps']} steps, "
          f"{len(summary['buckets'])} buckets, EMA decay {args.ema}, "
          f"drift threshold {args.threshold:g})\n")
    print(bucket_table(summary))
    if summary["flagged"]:
        print(f"\ndrifted buckets (realized/predicted > {args.threshold:g}): "
              f"{summary['flagged']}")
    if summary["phases"]:
        print("\n## Phase breakdown (host wall clock)\n")
        print(phase_table(summary))
    if summary["drift"]:
        print(f"\n## Drift warnings ({len(summary['drift'])})\n")
        for ev in summary["drift"]:
            print(f"- {ev.get('message', ev)}")

    if args.csv_path:
        n = export_csv(events, args.csv_path)
        print(f"\nwrote {n} rows to {args.csv_path}")
    if args.json_path:
        pathlib.Path(args.json_path).write_text(json.dumps(summary, indent=2) + "\n")
        print(f"wrote {args.json_path}")
    return 0
