"""Event sinks for the observability layer: JSONL writer, reader, EMA, CSV.

Every event is one JSON object per line with a mandatory ``"v"`` schema
version (:data:`SCHEMA_VERSION`) and a ``"kind"`` discriminator:

- ``{"v": 1, "kind": "metrics", "step": i, "buckets": [{"bucket": b,
  "bits": ..., "rank": ..., "alpha": ..., "clip_frac": ..., "ef_norm": ...,
  "wire_bytes": ..., "realized_mse": ..., "predicted_mse": ...}, ...]}`` —
  one per logged step, peer-averaged from the in-graph
  :class:`repro.obs.metrics.CompressionMetrics` pytree;
- ``{"v": 1, "kind": "span", "name": ..., "t_start": ..., "dur_s": ...,
  "step": ..., "attrs": {...}}`` — wall-clock phase spans
  (:mod:`repro.obs.trace`);
- ``{"v": 1, "kind": "drift", ...}`` — structured drift warnings
  (:mod:`repro.obs.drift`).

``python -m repro.obs report`` consumes a directory of these files.
"""
from __future__ import annotations

import csv
import json
import pathlib
import sys

import numpy as np

SCHEMA_VERSION = 1

#: flat per-bucket fields of a "metrics" event, in column order
METRIC_FIELDS = ("bits", "rank", "alpha", "clip_frac", "ef_norm",
                 "wire_bytes", "realized_mse", "predicted_mse")


class JsonlSink:
    """Append-only JSONL event writer with buffered flushing.

    ``flush_every`` bounds the number of buffered events before an fsync-free
    flush; the sink is also a context manager (flushes on exit).  The parent
    directory is created on first write, so ``runs/obs/<name>.jsonl`` works
    without setup.
    """

    def __init__(self, path, flush_every: int = 16):
        self.path = pathlib.Path(path)
        self.flush_every = max(1, int(flush_every))
        self._buf: list[str] = []
        self._fh = None
        self.n_written = 0

    def write(self, event: dict) -> None:
        event.setdefault("v", SCHEMA_VERSION)
        self._buf.append(json.dumps(event, sort_keys=True))
        self.n_written += 1
        if len(self._buf) >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        if not self._buf:
            return
        if self._fh is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = self.path.open("a")
        self._fh.write("\n".join(self._buf) + "\n")
        self._fh.flush()
        self._buf.clear()

    def close(self) -> None:
        self.flush()
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def read_events(path) -> list[dict]:
    """Load events from one ``.jsonl`` file or every ``*.jsonl`` in a
    directory.  Malformed lines and version-mismatched events are skipped
    with a one-line warning naming the offending path (never silently)."""
    p = pathlib.Path(path)
    if not p.exists():
        print(f"warning: no event log at {p}", file=sys.stderr)
        return []
    files = sorted(p.glob("*.jsonl")) if p.is_dir() else [p]
    events = []
    for f in files:
        for ln, line in enumerate(f.read_text().splitlines(), 1):
            if not line.strip():
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                print(f"warning: skipping malformed event {f}:{ln}: {e}",
                      file=sys.stderr)
                continue
            if not isinstance(ev, dict) or ev.get("v") != SCHEMA_VERSION:
                print(f"warning: skipping event with unknown schema {f}:{ln} "
                      f"(v={ev.get('v') if isinstance(ev, dict) else None!r})",
                      file=sys.stderr)
                continue
            events.append(ev)
    return events


def metrics_event(step: int, comp) -> dict:
    """Host-side conversion of a :class:`CompressionMetrics` pytree (leaves
    ``(n_dp, B)`` as returned by the train step, or ``(B,)``) into one
    peer-averaged ``"metrics"`` event."""
    arrs = {k: np.atleast_2d(np.asarray(v)) for k, v in zip(comp._fields, comp)}
    n_buckets = arrs["bits"].shape[-1]
    buckets = []
    for b in range(n_buckets):
        row = {"bucket": b}
        for k in METRIC_FIELDS:
            col = arrs[k][:, b]
            row[k] = int(col[0]) if k in ("bits", "rank") else float(np.mean(col))
        buckets.append(row)
    return {"v": SCHEMA_VERSION, "kind": "metrics", "step": int(step),
            "buckets": buckets}


class EmaAggregator:
    """Exponential moving average over the per-bucket metric fields.

    ``update`` folds one ``"metrics"`` event; ``summary()`` returns the
    smoothed per-bucket rows (same field names as the events).  The first
    observation seeds the EMA.
    """

    def __init__(self, decay: float = 0.9):
        if not 0.0 <= decay < 1.0:
            raise ValueError("decay must be in [0, 1)")
        self.decay = decay
        self.state: dict[int, dict[str, float]] = {}
        self.n_events = 0

    def update(self, event: dict) -> None:
        if event.get("kind") != "metrics":
            return
        self.n_events += 1
        for row in event.get("buckets", []):
            b = int(row["bucket"])
            cur = self.state.setdefault(b, {})
            for k in METRIC_FIELDS:
                if k not in row:
                    continue
                v = float(row[k])
                cur[k] = v if k not in cur else self.decay * cur[k] + (1.0 - self.decay) * v

    def summary(self) -> list[dict]:
        return [{"bucket": b, **vals} for b, vals in sorted(self.state.items())]


def export_csv(events: list[dict], path) -> int:
    """Write every ``"metrics"`` event as flat CSV rows
    (``step,bucket,<METRIC_FIELDS...>``); returns the row count."""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    n = 0
    with p.open("w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(("step", "bucket") + METRIC_FIELDS)
        for ev in events:
            if ev.get("kind") != "metrics":
                continue
            for row in ev.get("buckets", []):
                w.writerow([ev.get("step"), row.get("bucket")]
                           + [row.get(k) for k in METRIC_FIELDS])
                n += 1
    return n
