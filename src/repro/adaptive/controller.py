"""Wire-budget bit allocation across gradient buckets (DQ-SGD-style).

Every ``replan_every`` steps the runtime snapshots the telemetry, estimates
one :class:`~repro.core.distributions.PowerLawTail` per bucket, and
water-fills discrete bits-per-bucket under a global bytes/step budget:
starting from ``min_bits`` everywhere, the bucket with the best marginal
error-reduction per wire byte gets one more bit until nothing fits.  The
objective is the paper's closed-form error model — for bucket ``b`` at
``k`` bits, ``size_b · E_TQ(tail_b, α*(tail_b, k), k)`` with α* from the
``core.optimal`` fixed-point solver and ``E_TQ`` from ``core.theory``
(Eq. 11: quantization variance + truncation bias) — so heavy-tailed /
large-scale buckets win bits over thin-tailed ones instead of every bucket
getting the same static width.

The error model dispatches on the compressor method: the truncated
*non-uniform* codecs (tnqsgd/nqsgd/tbqsgd) are scored with
``theory.e_tq_nonuniform`` and α from ``optimal.solve_alpha_nonuniform``
over a per-bucket :class:`~repro.core.distributions.EmpiricalDensity`
(telemetry's histogram, via ``telemetry.estimate_densities``), so the
reported α is the one the running codec's plan actually solves for; the
uniform codecs use Eq. 11 / Eq. 12.  Without densities the uniform model is
the fallback.

Plans are host-side Python (tuples of ints): bits are shape-static in the
compiled step, so a replan that changes the plan swaps to a different
compiled step through the runtime's cache rather than retracing anything
dynamically.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Sequence
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import optimal, theory
from repro.core.compressors import CompressorConfig, wire_bytes
from repro.core.distributions import EmpiricalDensity, PowerLawTail


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Adaptive bucketed-sync configuration (``TrainStepConfig.adaptive``).

    ``wire_budget_mb <= 0`` pins the budget to what the *fixed* allocation
    at ``compressor.bits`` would spend — the controller then only
    redistributes the same bytes.  ``ema`` is the telemetry decay;
    ``warmup_steps`` replans are skipped until the EMA has seen that many
    updates.
    """

    wire_budget_mb: float = 0.0
    replan_every: int = 20
    min_bits: int = 2
    max_bits: int = 8
    ema: float = 0.9
    gmin_quantile: float = 0.9
    warmup_steps: int = 2
    # Hysteresis: adopt a new plan only when its predicted error beats the
    # current plan's (under the same fresh tails) by this relative margin —
    # telemetry-noisy tails otherwise oscillate between neighbouring plans,
    # each first visit stalling on a fresh XLA compile.
    switch_threshold: float = 0.02
    # Retained compiled steps in the runtime's cache (LRU beyond this).
    max_cached_steps: int = 8
    # Elastic: the expected live fraction must move by more than this
    # relative margin before the budget is re-based on it — a single peer
    # flap otherwise thrashes the compiled-step cache through spurious
    # budget (and hence bit-plan) changes.
    live_hysteresis: float = 0.25

    def __post_init__(self):
        if not (1 <= self.min_bits <= self.max_bits <= 8):
            raise ValueError("need 1 <= min_bits <= max_bits <= 8")
        if self.replan_every < 1:
            raise ValueError("replan_every must be >= 1")
        if not (0.0 < self.ema < 1.0):
            raise ValueError("ema must be in (0, 1)")
        if self.switch_threshold < 0.0:
            raise ValueError("switch_threshold must be >= 0")
        if self.max_cached_steps < 1:
            raise ValueError("max_cached_steps must be >= 1")
        if not (0.0 <= self.live_hysteresis < 1.0):
            raise ValueError("live_hysteresis must be in [0, 1)")


class BitPlan(NamedTuple):
    """One allocation round's result (host-side Python scalars)."""

    bits: tuple[int, ...]     # per-bucket wire bits
    alphas: tuple[float, ...]  # solver α at the chosen bits (for reports)
    spend_bytes: int          # wire bytes/step of this plan
    budget_bytes: int         # the budget it was solved under
    err: float = 0.0          # predicted size-weighted total error of the plan


def _tail_rows(tails: PowerLawTail | Sequence[PowerLawTail]) -> list[PowerLawTail]:
    """Accept a stacked PowerLawTail (vmap output) or a list of scalars."""
    if isinstance(tails, PowerLawTail) and getattr(tails.gamma, "ndim", 0) == 1:
        return [PowerLawTail(*(jnp.asarray(f[b]) for f in tails))
                for b in range(tails.gamma.shape[0])]
    return list(tails)


def budget_bytes(cfg: AdaptiveConfig, ccfg: CompressorConfig, sizes: Sequence[int],
                 live_frac: float = 1.0) -> int:
    """Global wire budget in bytes/step over the fused bucket list.

    ``live_frac`` (elastic) is the expected fraction of peers contributing
    per step: with fewer live peers the fleet puts proportionally fewer
    bytes on the interconnect, so each surviving peer's budget grows by
    ``1/live_frac`` — the controller re-spends the freed fleet bandwidth
    on wider codebooks instead of leaving it idle.
    """
    if not 0.0 < live_frac <= 1.0:
        raise ValueError(f"live_frac {live_frac} outside (0, 1]")
    base = int(cfg.wire_budget_mb * (1 << 20)) if cfg.wire_budget_mb > 0 \
        else int(wire_bytes(ccfg, list(sizes)))
    return int(base / live_frac)


def _solve_bucket(tail: PowerLawTail, dens: EmpiricalDensity | None, k: int,
                  ccfg: CompressorConfig, iters: int) -> tuple[float, float]:
    """(α, per-element E_TQ) for one bucket at ``k`` bits, dispatched on the
    compressor method so both track what the codec's ``plan`` actually does:
    untruncated codecs (qsgd/nqsgd) pin α = max|g|; tnqsgd gets Eq. 19's α
    and the Q_N model (tbqsgd approximates with the same — Q_N ≤ Q_B by
    Hölder); tqsgd gets Eq. 12 / Eq. 11.  Without a density the uniform
    model is the fallback for every non-uniform codec."""
    method = ccfg.method
    if method in ("qsgd", "nqsgd", "dsgd"):
        a = tail.g_max
        if dens is not None and method == "nqsgd":
            return float(a), float(theory.e_tq_nonuniform(tail, dens, a, k))
        return float(a), float(theory.e_tq_uniform(tail, a, k))
    if dens is not None and method in ("tnqsgd", "tbqsgd"):
        a = optimal.solve_alpha_nonuniform(tail, dens, k, iters=iters)
        return float(a), float(theory.e_tq_nonuniform(tail, dens, a, k))
    a = optimal.solve_alpha_uniform(tail, k, iters=iters)
    return float(a), float(theory.e_tq_uniform(tail, a, k))


def predicted_error(
    tails: PowerLawTail | Sequence[PowerLawTail],
    sizes: Sequence[int],
    bits: Sequence[int],
    ccfg: CompressorConfig,
    *,
    dens: Sequence[EmpiricalDensity] | None = None,
    alpha_iters: int = 10,
) -> float:
    """Size-weighted total model error of an arbitrary bit assignment —
    the hysteresis comparison the runtime runs before adopting a new plan."""
    rows = _tail_rows(tails)
    return sum(
        _solve_bucket(rows[b], dens[b] if dens is not None else None,
                      int(bits[b]), ccfg, alpha_iters)[1] * sizes[b]
        for b in range(len(sizes)))


def allocate_bits(
    tails: PowerLawTail | Sequence[PowerLawTail],
    sizes: Sequence[int],
    budget: int,
    ccfg: CompressorConfig,
    *,
    dens: Sequence[EmpiricalDensity] | None = None,
    min_bits: int = 2,
    max_bits: int = 8,
    alpha_iters: int = 10,
) -> BitPlan:
    """Greedy marginal-utility water-filling of discrete bits-per-bucket.

    Each +1-bit upgrade is scored by (predicted error reduction) / (extra
    wire bytes); upgrades are applied best-first while they fit ``budget``.
    ``min_bits`` everywhere is the floor even if it alone overshoots the
    budget (the codec cannot go below 1 bit).  ``dens`` (per-bucket
    empirical densities, e.g. ``telemetry.estimate_densities``) switches
    the non-uniform codecs to their own α solver and error model.
    """
    rows = _tail_rows(tails)
    if len(rows) != len(sizes):
        raise ValueError(f"{len(rows)} tails vs {len(sizes)} bucket sizes")
    if dens is not None and len(dens) != len(sizes):
        raise ValueError(f"{len(dens)} densities vs {len(sizes)} bucket sizes")
    nb = len(sizes)
    widths = range(min_bits, max_bits + 1)
    # err[b][k], alpha[b][k]: size-weighted model error + solver α per width.
    err: list[dict[int, float]] = []
    alph: list[dict[int, float]] = []
    for b in range(nb):
        e_row, a_row = {}, {}
        for k in widths:
            a, e = _solve_bucket(rows[b], dens[b] if dens is not None else None,
                                 k, ccfg, alpha_iters)
            e_row[k] = e * sizes[b]
            a_row[k] = a
        err.append(e_row)
        alph.append(a_row)

    def cost(b: int, k: int) -> int:
        return int(wire_bytes(ccfg, sizes[b], k))

    bits = [min_bits] * nb
    spend = sum(cost(b, min_bits) for b in range(nb))
    while True:
        best = None
        for b in range(nb):
            k = bits[b] + 1
            if k > max_bits:
                continue
            dcost = cost(b, k) - cost(b, bits[b])
            if spend + dcost > budget:
                continue
            gain = err[b][bits[b]] - err[b][k]
            score = gain / max(dcost, 1)
            if best is None or score > best[0]:
                best = (score, b, k, dcost)
        if best is None or best[0] <= 0.0:
            break
        _, b, k, dcost = best
        bits[b] = k
        spend += dcost
    return BitPlan(
        bits=tuple(bits),
        alphas=tuple(alph[b][bits[b]] for b in range(nb)),
        spend_bytes=spend,
        budget_bytes=int(budget),
        err=sum(err[b][bits[b]] for b in range(nb)),
    )


class MethodPlan(NamedTuple):
    """A heterogeneous codec plan: per-bucket ``bits_plan`` entries (an int
    quantizer width or a ``("powersgd", rank)`` tuple), plus accounting."""

    entries: tuple          # per-bucket int bits or ("method", rank)
    alphas: tuple[float, ...]  # solver α for quantized buckets (0 otherwise)
    spend_bytes: int
    budget_bytes: int
    err: float = 0.0


def _density_msq(dens: EmpiricalDensity) -> float:
    """Per-element mean-square gradient magnitude from the telemetry
    histogram: ``Σ p_k · Δ_k · mid_k²`` over the density's bins."""
    edges = jnp.asarray(dens.edges, jnp.float32)
    mids = 0.5 * (edges[:-1] + edges[1:])
    widths = edges[1:] - edges[:-1]
    return float(jnp.sum(jnp.asarray(dens.density, jnp.float32) * widths * mids**2))


def _lowrank_error(msq: float, m: int, rank: int, beta: float) -> float:
    """Per-element predicted powersgd error for an m-element bucket at the
    given rank, under a power-law singular-value decay ``σ_i² ∝ i^-beta``:
    the captured energy fraction is ``E_r = Σ_{i<=r} i^-beta / Σ_{i<=R}
    i^-beta`` over the bucket matrix's full spectrum R = min(rows, cols),
    and the model error is the uncaptured share of the mean square."""
    from repro.core import lowrank

    rows, cols = lowrank.matrix_shape(m)
    full = min(rows, cols)
    r = max(1, min(rank, full))
    weights = [i ** -beta for i in range(1, full + 1)]
    captured = sum(weights[:r]) / sum(weights)
    return msq * (1.0 - captured)


def allocate_plan(
    tails: PowerLawTail | Sequence[PowerLawTail],
    sizes: Sequence[int],
    budget: int,
    ccfg: CompressorConfig,
    *,
    dens: Sequence[EmpiricalDensity] | None = None,
    min_bits: int = 2,
    max_bits: int = 8,
    alpha_iters: int = 10,
    ranks: Sequence[int] = (1, 2, 4, 8),
    sv_decay: float = 2.0,
) -> MethodPlan:
    """Method×rank×bits allocation: :func:`allocate_bits` extended with
    per-bucket ``("powersgd", rank)`` candidates.

    The quantizer water-filling runs first; each bucket is then offered a
    swap to its best low-rank candidate, scored by (predicted error
    reduction) / (wire-byte delta, clamped at 1) under the powersgd model
    of :func:`_lowrank_error` — swaps apply best-first while they fit the
    budget.  Rank candidates need the telemetry densities (``dens``) for
    the mean-square term; without them the result degrades to the pure
    quantizer plan.  Freed bytes from a cheaper low-rank wire are re-spent
    on +1-bit upgrades of the remaining quantized buckets.
    """
    base = allocate_bits(tails, sizes, budget, ccfg, dens=dens,
                         min_bits=min_bits, max_bits=max_bits,
                         alpha_iters=alpha_iters)
    entries: list = list(base.bits)
    alphas = list(base.alphas)
    if dens is None:
        return MethodPlan(tuple(entries), tuple(alphas),
                          base.spend_bytes, base.budget_bytes, base.err)
    from repro.core.codecs import bucket_cfg_entry

    rows = _tail_rows(tails)
    nb = len(sizes)

    def q_err(b: int, k: int) -> float:
        return _solve_bucket(rows[b], dens[b], k, ccfg, alpha_iters)[1] * sizes[b]

    def cost(b: int, entry) -> int:
        return int(wire_bytes(ccfg, sizes[b], entry))

    errs = [q_err(b, entries[b]) for b in range(nb)]
    spend = sum(cost(b, entries[b]) for b in range(nb))
    # Best powersgd candidate per bucket under the budget's spare room.
    changed = True
    while changed:
        changed = False
        best = None
        for b in range(nb):
            if not isinstance(entries[b], int):
                continue
            msq = _density_msq(dens[b])
            for r in ranks:
                entry = ("powersgd", int(r))
                pcfg = bucket_cfg_entry(ccfg, entry)
                if pcfg.rank != r:
                    continue  # out-of-range rank for this config
                e = _lowrank_error(msq, sizes[b], r, sv_decay) * sizes[b]
                dcost = cost(b, entry) - cost(b, entries[b])
                if spend + dcost > budget or e >= errs[b]:
                    continue
                score = (errs[b] - e) / max(dcost, 1)
                if best is None or score > best[0]:
                    best = (score, b, entry, e, dcost)
        if best is not None:
            _, b, entry, e, dcost = best
            entries[b], errs[b], alphas[b] = entry, e, 0.0
            spend += dcost
            changed = True
    # Re-spend any freed bytes on the still-quantized buckets.
    while True:
        best = None
        for b in range(nb):
            if not isinstance(entries[b], int) or entries[b] + 1 > max_bits:
                continue
            k = entries[b] + 1
            dcost = cost(b, k) - cost(b, entries[b])
            if spend + dcost > budget:
                continue
            gain = errs[b] - q_err(b, k)
            score = gain / max(dcost, 1)
            if best is None or score > best[0]:
                best = (score, b, k, dcost)
        if best is None or best[0] <= 0.0:
            break
        _, b, k, dcost = best
        a, e = _solve_bucket(rows[b], dens[b], k, ccfg, alpha_iters)
        entries[b], errs[b], alphas[b] = k, e * sizes[b], a
        spend += dcost
    return MethodPlan(
        entries=tuple(entries),
        alphas=tuple(alphas),
        spend_bytes=spend,
        budget_bytes=int(budget),
        err=sum(errs),
    )
