"""Online tail telemetry + wire-budget bit allocation for bucketed sync.

- ``telemetry``:  streaming per-bucket gradient statistics (EMA histogram,
  Hill-estimator log sums, max/moments) threaded through ``make_train_step``
  as an explicit state pytree, fed by the fused ``kernels.stats`` pass;
- ``controller``: the wire-budget allocator — every ``replan_every`` steps
  it water-fills discrete bits-per-bucket to minimize the summed
  ``core.theory`` quantization-error model under a global bytes/step budget,
  with per-bucket α from the ``core.optimal`` fixed-point solvers;
- ``runtime``:    the replan loop driver with a compiled-step cache keyed on
  the bit tuple (import ``repro.adaptive.runtime`` directly; it is kept out
  of this namespace so ``dist.train_step`` can import the config types
  without a cycle).
"""
from . import controller, telemetry
from .controller import AdaptiveConfig, BitPlan, allocate_bits, predicted_error
from .telemetry import (
    TelemetryState,
    estimate_densities,
    estimate_tails,
    init_telemetry,
    update_telemetry,
)

__all__ = [
    "AdaptiveConfig",
    "BitPlan",
    "TelemetryState",
    "allocate_bits",
    "controller",
    "estimate_densities",
    "estimate_tails",
    "init_telemetry",
    "predicted_error",
    "telemetry",
    "update_telemetry",
]
