"""Streaming per-bucket gradient statistics for the adaptive controller.

``TelemetryState`` is an explicit state pytree threaded through
``make_train_step`` exactly like the EF residual: one stacked row per data
shard, updated inside the manual sync region from the same coalesced buckets
the codec quantizes (post error-feedback correction), with **zero extra
collectives** — peers accumulate their own statistics and the controller
merges the rows on the host at replan time (:func:`aggregate_peers`).

Per bucket the state carries an EMA of the fused one-pass statistics from
``kernels.stats`` (|g| histogram on fixed log2-spaced bins, per-bin sums of
ln|g|, a decayed max envelope, and first/second moments).  Because γ, ρ and
the quantile are ratios of co-scaled accumulators, the EMA debiasing factor
cancels and :func:`estimate_tails` needs no step correction.

The tail estimate snaps ``g_min`` to a histogram bin edge: the Hill sum over
the bins above that edge is then *exact* with respect to the histogram
(``Σ ln g_j − n_tail ln g_min``), trading ≤ one bin of quantile resolution
(0.25 octave at the default 128 bins) for an O(n) pass instead of the
full sort ``jnp.quantile`` runs in the offline fit.
"""
from __future__ import annotations

from collections.abc import Sequence
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.distributions import (
    EmpiricalDensity,
    PowerLawTail,
    density_from_histogram,
    tail_from_histogram,
)
from repro.kernels import stats as kstats

NUM_BINS = kstats.NUM_BINS


class TelemetryState(NamedTuple):
    """Per-bucket streaming statistics.  Leaves are stacked over buckets
    (leading axis B); the train step stacks one more leading axis per data
    shard, mirroring the EF residual layout."""

    counts: jax.Array    # (B, NUM_BINS) EMA histogram counts of |g|
    log_sums: jax.Array  # (B, NUM_BINS) EMA per-bin sums of ln|g|
    g_max: jax.Array     # (B,) decayed max-|g| envelope
    mean: jax.Array      # (B,) EMA of the bucket mean
    msq: jax.Array       # (B,) EMA of the bucket second moment
    steps: jax.Array     # () number of updates folded in


def init_telemetry(n_buckets: int) -> TelemetryState:
    return TelemetryState(
        counts=jnp.zeros((n_buckets, NUM_BINS), jnp.float32),
        log_sums=jnp.zeros((n_buckets, NUM_BINS), jnp.float32),
        g_max=jnp.zeros((n_buckets,), jnp.float32),
        mean=jnp.zeros((n_buckets,), jnp.float32),
        msq=jnp.zeros((n_buckets,), jnp.float32),
        steps=jnp.zeros((), jnp.float32),
    )


def _stats_jnp(g: jax.Array):
    """Vectorized single-pass jnp fallback for the fused kernel.

    Scatter-add histogram instead of the kernel's one-hot matmul
    (``kernels.ref.bucket_stats_scatter``): safe under shard_map on the
    pinned toolchain and O(n).  Counts/max are identical to the kernel;
    float sums may differ in the last bits (reduction order), which the EMA
    telemetry does not care about — the bit-exact contract is pinned
    between ``kernels.ops.bucket_stats`` and ``kernels.ref``.
    """
    from repro.kernels.ref import bucket_stats_scatter

    return bucket_stats_scatter(g)


def bucket_statistics(g: jax.Array, *, use_pallas: bool = False):
    """(counts, log_sums, g_max, g_sum, g_sumsq) for one flat bucket."""
    if use_pallas:
        from repro.kernels import ops as kops

        s = kops.bucket_stats(g)
        return s.counts, s.log_sums, s.g_max, s.g_sum, s.g_sumsq
    return _stats_jnp(g)


def correct_stats(g: jax.Array, e=None, *, use_pallas: bool = False):
    """One-pass EF correction + statistics of one flat gradient bucket.

    Returns ``(corrected, (counts, log_sums, g_max, g_sum, g_sumsq))`` with
    ``corrected = g + e`` (``g`` itself when ``e`` is None) and the stats of
    the *corrected* bucket — everything ``compressors.plan_from_stats`` and
    the telemetry EMA consume.  ``use_pallas`` selects the fused
    ``kernels.ops.ef_correct_stats`` VMEM pass; the fallback is the
    shard_map-safe scatter-add pass over ``g + e``.
    """
    if use_pallas:
        from repro.kernels import ops as kops

        if e is None:
            s = kops.bucket_stats(g)
            return g, (s.counts, s.log_sums, s.g_max, s.g_sum, s.g_sumsq)
        c, s = kops.ef_correct_stats(g, e)
        return c, (s.counts, s.log_sums, s.g_max, s.g_sum, s.g_sumsq)
    c = g if e is None else g + e
    return c, _stats_jnp(c)


def update_telemetry(
    state: TelemetryState,
    buckets: Sequence[jax.Array],
    *,
    decay: float = 0.9,
    use_pallas: bool = False,
    stats: Sequence | None = None,
) -> TelemetryState:
    """Fold one step's buckets into the EMA state (B must match).

    ``stats`` (one :func:`correct_stats`-shaped tuple per bucket) skips the
    statistics pass entirely — the train step hands over the stats the
    fused EF-correct kernel already produced, so the telemetry update costs
    zero extra HBM sweeps.
    """
    if len(buckets) != state.counts.shape[0]:
        raise ValueError(
            f"telemetry state has {state.counts.shape[0]} buckets, got {len(buckets)}")
    d = jnp.float32(decay)
    counts, log_sums, gmaxs, means, msqs = [], [], [], [], []
    for b, g in enumerate(buckets):
        c, ls, gm, gs, gq = (stats[b] if stats is not None
                             else bucket_statistics(g, use_pallas=use_pallas))
        n = jnp.float32(max(g.size, 1))
        counts.append(d * state.counts[b] + (1.0 - d) * c)
        log_sums.append(d * state.log_sums[b] + (1.0 - d) * ls)
        gmaxs.append(jnp.maximum(gm, d * state.g_max[b]))
        means.append(d * state.mean[b] + (1.0 - d) * gs / n)
        msqs.append(d * state.msq[b] + (1.0 - d) * gq / n)
    return TelemetryState(
        counts=jnp.stack(counts),
        log_sums=jnp.stack(log_sums),
        g_max=jnp.stack(gmaxs),
        mean=jnp.stack(means),
        msq=jnp.stack(msqs),
        steps=state.steps + 1.0,
    )


def aggregate_peers(state: TelemetryState) -> TelemetryState:
    """Merge the per-data-shard stacked rows (leading axis) into one state.

    Counts/log-sums add across peers, the max envelope joins with max, and
    the moments average — all on whatever backing the arrays have (device or
    host); called by the controller at replan time, never inside the step.
    """
    return TelemetryState(
        counts=jnp.sum(state.counts, axis=0),
        log_sums=jnp.sum(state.log_sums, axis=0),
        g_max=jnp.max(state.g_max, axis=0),
        mean=jnp.mean(state.mean, axis=0),
        msq=jnp.mean(state.msq, axis=0),
        steps=jnp.max(state.steps, axis=0),
    )


def estimate_densities(state: TelemetryState) -> list[EmpiricalDensity]:
    """Per-bucket piecewise-constant |g| densities from the EMA histogram.

    The same ``core.distributions.EmpiricalDensity`` contract the offline
    ``fit_empirical_density`` produces (two-sided density over |g| bins, here
    the telemetry's log2-spaced edges — ``_cum_integral`` handles non-uniform
    widths), so the ``core.optimal`` non-uniform α solvers and the
    ``core.theory`` Q_N error model run straight off telemetry.
    """
    edges = kstats.bin_edges()
    return [density_from_histogram(state.counts[b], edges)
            for b in range(state.counts.shape[0])]


def estimate_tails(state: TelemetryState, *, gmin_quantile: float = 0.9) -> PowerLawTail:
    """Histogram-based power-law tail fit per bucket (stacked PowerLawTail).

    ``g_min`` is the upper edge of the bin where the |g| CDF crosses
    ``gmin_quantile``; γ is the Hill estimator over the whole bins above it
    (suffix count / suffix ln-sum of the EMA accumulators), ρ the matching
    one-sided tail mass.  Mirrors ``core.distributions.fit_power_law_tail``
    without touching the raw gradients.
    """
    edges = kstats.bin_edges()
    return jax.vmap(
        lambda c, ls, gm: tail_from_histogram(c, ls, gm, edges,
                                              gmin_quantile=gmin_quantile)
    )(state.counts, state.log_sums, state.g_max)
