"""Replan loop driver: compiled-step cache keyed on the bit tuple.

Bit plans are shape-static inside a compiled train step (packed-word counts
and codebook sizes depend on them), so the adaptive runtime never retraces
mid-flight: every distinct per-bucket bit tuple maps to its own
``make_train_step`` product, built on first use and reused while hot.
Between replans the stepper just dispatches to the cached step; at a replan
boundary it pulls the telemetry pytree to the host, merges the per-peer
rows, estimates tails/densities, re-solves the allocation and switches only
when the new plan's predicted error beats the *current* plan's (under the
same fresh tails) by ``switch_threshold`` — hysteresis against noisy-tail
oscillation, where each first visit to a neighbouring plan would stall on an
XLA compile.  The cache itself is LRU-bounded at ``max_cached_steps`` so a
long run cannot accumulate executables without bound.

Kept out of ``repro.adaptive.__init__`` so ``dist.train_step`` can import
the adaptive config/telemetry types without a module cycle.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist import train_step as tsmod
from repro.dist.train_step import TrainStepConfig, make_train_step

from . import telemetry
from .controller import BitPlan, allocate_bits, budget_bytes, predicted_error


class AdaptiveStepper:
    """Owns the telemetry state layout, the bit plan, and the step cache.

    ``stepper.step(params, opt_state, ef_state, tstate, batch, i)`` runs one
    training step (pass ``ef_state=None`` when EF is off) and returns
    ``(params, opt_state, ef_state, tstate, metrics)``; replans fire every
    ``ts.adaptive.replan_every`` calls once the telemetry has warmed up.
    The current plan is exposed as ``stepper.plan`` (a
    :class:`~repro.adaptive.controller.BitPlan`), ``None`` until the first
    replan — before that the uniform ``ts.compressor.bits`` plan runs.

    Observability hooks (both optional, keyword-only): ``obs`` is a
    :class:`repro.obs.SpanRecorder` — the replan path (telemetry merge +
    tail fit + allocation, the host-side stall candidate) runs under an
    ``adaptive.replan`` span; ``drift`` is a
    :class:`repro.obs.DriftMonitor` fed the freshly estimated per-bucket
    tails at every replan, so a Hill estimate railing out of the power-law
    regime raises a structured warning the moment the controller would
    have consumed it.
    """

    # class-level defaults so stepper shells built without __init__ (the
    # stubbed-builder tests) still replan cleanly
    obs = None
    drift = None
    _live_frac = 1.0
    _n_dp = 1

    def __init__(self, cfg, mesh, logical, opt, ts: TrainStepConfig, batch0,
                 opt_state_like: Any = None, params_like: Any = None,
                 *, obs: Any = None, drift: Any = None):
        if ts.adaptive is None:
            raise ValueError("AdaptiveStepper needs TrainStepConfig.adaptive set")
        if params_like is None:
            from repro.models import transformer

            # repro: allow REPRO204 (eval_shape aval-only trace; value never used)
            params_like = jax.eval_shape(lambda: transformer.init_lm(jax.random.key(0), cfg)[0])
        # The plan/telemetry hot loop never full-sorts: force the histogram
        # quantile for g_min unless the caller already chose.
        if not ts.compressor.approx_gmin:
            ts = dataclasses.replace(
                ts, compressor=dataclasses.replace(ts.compressor, approx_gmin=True))
        self.ts = ts
        self.obs, self.drift = obs, drift
        self.cfg, self.mesh, self.logical, self.opt = cfg, mesh, logical, opt
        self.batch0 = batch0
        self.opt_state_like = opt_state_like
        self.params_like = params_like
        self._cache: collections.OrderedDict[tuple[int, ...], Any] = collections.OrderedDict()
        self.plan: BitPlan | None = None
        self.tails = None  # last telemetry-estimated stacked PowerLawTail
        # Elastic: budget re-base factor — the expected live fraction last
        # adopted past ``live_hysteresis`` (1.0 = full participation).
        self._live_frac = 1.0
        self._n_dp = 1
        from repro.dist import sharding

        for a in sharding.manual_axes(mesh):
            self._n_dp *= mesh.shape[a]
        # First build fixes pspecs and the bucket layout (uniform plan).
        step0, self.pspecs = self._build(None)
        self.sizes = tsmod.local_bucket_sizes(params_like, mesh, self.pspecs, ts)
        self.bits = (ts.compressor.bits,) * len(self.sizes)
        self._cache[self.bits] = step0

    def _build(self, bits: tuple[int, ...] | None):
        ts_b = dataclasses.replace(self.ts, bits_plan=bits)
        return make_train_step(
            self.cfg, self.mesh, self.logical, self.opt, ts_b, self.batch0,
            opt_state_like=self.opt_state_like, params_like=self.params_like)

    def _step_for(self, bits: tuple[int, ...]):
        if bits not in self._cache:
            self._cache[bits], _ = self._build(bits)
        self._cache.move_to_end(bits)
        while len(self._cache) > self.ts.adaptive.max_cached_steps:
            self._cache.popitem(last=False)
        return self._cache[bits]

    def init_telemetry(self) -> Any:
        return tsmod.init_telemetry_state(self.params_like, self.mesh, self.pspecs, self.ts)

    @property
    def budget(self) -> int:
        return budget_bytes(self.ts.adaptive, self.ts.compressor, self.sizes,
                            live_frac=self._live_frac)

    def replan(self, tstate: Any, step: int = 0) -> BitPlan:
        """Host-side: merge peer telemetry, estimate tails/densities,
        re-solve bits, and adopt the new plan only past the hysteresis
        margin (the first replan away from the uniform bootstrap always
        adopts — there is nothing compiled worth protecting yet)."""
        if self.obs is not None:
            with self.obs.span("adaptive.replan"):
                return self._replan(tstate, step)
        return self._replan(tstate, step)

    def _replan(self, tstate: Any, step: int = 0) -> BitPlan:
        acfg = self.ts.adaptive
        if getattr(self.ts, "elastic", None) is not None:
            # Re-base the budget on the expected live fraction over the
            # upcoming window — host-side replay of the same counter hash
            # the compiled step evaluates, so no device round trip.  The
            # relative hysteresis keeps a single flap from thrashing the
            # compiled-step cache through a spurious budget change.
            from repro.elastic.schedule import expected_live_fraction

            frac = expected_live_fraction(self.ts.elastic, self._n_dp, step,
                                          acfg.replan_every)
            if abs(frac - self._live_frac) > acfg.live_hysteresis * self._live_frac:
                self._live_frac = frac
        merged = telemetry.aggregate_peers(jax.device_get(tstate))
        if float(merged.steps) < acfg.warmup_steps:
            return self.plan if self.plan is not None else BitPlan(
                self.bits, (), 0, self.budget)
        tails = telemetry.estimate_tails(merged, gmin_quantile=acfg.gmin_quantile)
        dens = telemetry.estimate_densities(merged)
        self.tails = tails
        if self.drift is not None:
            self.drift.check_tails(tails, step=int(merged.steps))
        plan = allocate_bits(tails, self.sizes, self.budget, self.ts.compressor,
                             dens=dens, min_bits=acfg.min_bits, max_bits=acfg.max_bits,
                             alpha_iters=self.ts.compressor.alpha_iters)
        if plan.bits != self.bits and self.plan is not None:
            e_current = predicted_error(tails, self.sizes, self.bits,
                                        self.ts.compressor, dens=dens,
                                        alpha_iters=self.ts.compressor.alpha_iters)
            if plan.err > e_current * (1.0 - acfg.switch_threshold):
                # Not enough predicted gain to risk a compile: keep the
                # current plan.
                return self.plan
        self.plan, self.bits = plan, plan.bits
        return plan

    def step(self, params, opt_state, ef_state, tstate, batch, i: int):
        acfg = self.ts.adaptive
        if i and i % acfg.replan_every == 0:
            self.replan(tstate, step=i)
        fn = self._step_for(self.bits)
        step = jnp.uint32(i)
        if self.ts.error_feedback:
            p, o, e, t, m = fn(params, opt_state, ef_state, tstate, batch, step)
        else:
            p, o, t, m = fn(params, opt_state, tstate, batch, step)
            e = None
        return p, o, e, t, m
